"""Two-phase training loop with lazy checkpoint integration (paper Fig 6).

The train step is split into ``grad_step`` (forward+backward — the *immutable
window*: params/opt state are only read) and ``update_step`` (the mutation
point — donates its buffers, the JAX analogue of in-place update). A
checkpoint requested at iteration end stages device→host concurrently with
the next iteration's grad_step; :meth:`CheckpointManager.wait_for_capture`
is called at the phase boundary so the donating update never overwrites
state still being snapshotted — exactly the paper's U-phase delay.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CheckpointManager
from repro.data.pipeline import SyntheticTokenPipeline
from repro.obs import trace as obs
from repro.models import model as M
from repro.optim.adamw import AdamWConfig, apply_updates, init_opt_state


def make_grad_step(cfg) -> Callable:
    def grad_step(params, batch):
        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, batch))(params)
        return grads, loss
    return jax.jit(grad_step)


def make_update_step(cfg, hp: AdamWConfig) -> Callable:
    def update_step(params, opt_state, grads):
        return apply_updates(params, opt_state, grads, hp)
    # donate params+opt_state: the buffers being checkpointed are reused
    # in-place here — this is what makes the capture barrier necessary.
    return jax.jit(update_step, donate_argnums=(0, 1))


def make_train_step(cfg, hp: AdamWConfig) -> Callable:
    """Fused single-jit step (used by the dry-run / roofline path)."""
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, batch))(params)
        new_params, new_opt = apply_updates(params, opt_state, grads, hp)
        return new_params, new_opt, loss
    return train_step


@dataclasses.dataclass
class IterationRecord:
    step: int
    loss: float
    iter_s: float
    ckpt_stall_s: float       # direct stall (capture barrier + save prologue)
    ckpt_requested: bool


class Trainer:
    """End-to-end driver: data → two-phase step → lazy checkpoints."""

    def __init__(self, cfg, *, batch: int, seq_len: int,
                 hp: Optional[AdamWConfig] = None,
                 manager: Optional[CheckpointManager] = None,
                 seed: int = 0):
        self.cfg = cfg
        self.hp = hp or AdamWConfig()
        self.manager = manager
        self.pipeline = SyntheticTokenPipeline(cfg, batch, seq_len, seed=seed)
        self.grad_step = make_grad_step(cfg)
        self.update_step = make_update_step(cfg, self.hp)
        rng = jax.random.PRNGKey(seed)
        self.params = M.init_params(cfg, rng)
        self.opt_state = init_opt_state(self.params)
        self.step = 0
        self.records: List[IterationRecord] = []
        self.last_resume_stats = None  # RestoreStats from the last resume()
        self.exit_drain_s = 0.0        # end-of-run persist/commit wait

    # -- checkpoint state composition (the paper's heterogeneous pytree) ----
    def state(self) -> Dict[str, Any]:
        return {
            "model": self.params,
            "optimizer": self.opt_state,
            "meta": {
                "step": self.step,
                "arch": self.cfg.name,
                "data_state": self.pipeline.state,
                "hp": self.hp._asdict(),
                "rng": {"seed": 0},
            },
        }

    def resume(self, step: Optional[int] = None,
               fallback: Optional[bool] = None,
               domains: Optional[Tuple[str, ...]] = None) -> int:
        """Resume from a checkpoint via the parallel restore engine.

        Step selection goes through the manager's checkpoint repository:
        only *committed* steps (catalog manifest present, or legacy
        directories passing the completeness probe) are eligible, so a
        crash-interrupted save can never be resumed from; with
        ``step=None`` a damaged-but-committed step falls back to the
        previous complete one, and a step evicted from the local tier is
        re-hydrated from the first remote tier that holds it. Multi-rank
        saves (``CheckpointManager(world=N)``) follow the same rule — a
        step only commits once every writer rank acked its phase-1 vote,
        so a rank killed mid-save lands this resume on the previous
        committed step — and restore is elastic across worlds: an N-rank
        save resumes onto any M-rank mesh.

        Differential checkpoints (``CheckpointManager(delta=...)``)
        resume transparently: a delta step's chain (keyframe + every
        intermediate delta) is discovered from the catalog, re-verified
        against manifest checksums, and replayed bit-exactly — including
        the data-pipeline cursor and RNG objects, which ride every save
        in full, so a run resumed from a delta step reproduces the
        uninterrupted loss trajectory exactly
        (``tests/test_delta_faults.py::test_exact_resume_from_delta_step``).

        The manager's :class:`~repro.core.restore.RestoreEngine` indexes
        the step directory once, plans shard↔target intersections, and fans
        ranged reads out over a thread pool; per-phase timings land in
        ``self.last_resume_stats`` (index/read/assemble seconds plus the
        bytes actually read — the resume-cost breakdown of arXiv
        2512.24511).

        ``domains`` forwards to the manager's selective restore: e.g.
        ``resume(domains=("model",))`` reloads parameters only — the
        optimizer/meta domains keep this trainer's current values (and
        none of their bytes are read). Serving and full resume share
        this one catalog-driven path."""
        assert self.manager is not None
        restored = self.manager.restore(self.state(), step=step,
                                        fallback=fallback, domains=domains)
        self.params = restored["model"]
        self.opt_state = restored["optimizer"]
        self.step = restored["meta"]["step"]
        self.pipeline.restore(restored["meta"]["data_state"])
        self.last_resume_stats = self.manager.last_restore_stats
        return self.step

    def run(self, n_steps: int, ckpt_interval: int = 0) -> List[IterationRecord]:
        ckpt_pending = False
        for _ in range(n_steps):
            t0 = time.perf_counter()
            batch = {k: jnp.asarray(v)
                     for k, v in self.pipeline.next_batch().items()}
            # --- immutable window: forward + backward ---------------------
            grads, loss = self.grad_step(self.params, batch)
            # --- capture barrier before the donating update ---------------
            stall = 0.0
            if ckpt_pending:
                t_b = time.perf_counter()
                stall = self.manager.wait_for_capture()
                obs.add_span("ckpt.capture_barrier", t_b, t_b + stall,
                             step=self.step)
                ckpt_pending = False
            self.params, self.opt_state = self.update_step(
                self.params, self.opt_state, grads)
            self.step += 1
            # --- checkpoint request (lazy: overlaps next fwd/bwd) ---------
            requested = False
            if ckpt_interval and self.manager is not None \
                    and self.step % ckpt_interval == 0:
                t_save = time.perf_counter()
                fut = self.manager.save(self.step, self.state())
                stall += time.perf_counter() - t_save  # blocking prologue
                ckpt_pending = True
                requested = True
            loss_val = float(loss)
            t1 = time.perf_counter()
            self.records.append(IterationRecord(
                step=self.step, loss=loss_val, iter_s=t1 - t0,
                ckpt_stall_s=stall, ckpt_requested=requested))
            obs.add_span("train.iteration", t0, t1, step=self.step,
                         stall_s=stall)
        self.exit_drain_s = 0.0
        if self.manager is not None:
            # End-of-run drain is blocking time too: without folding it
            # into the stall metric, a save requested on the last
            # iterations looks free (the old accounting stopped at the
            # save prologue, hiding the persist+commit wait here).
            t_d = time.perf_counter()
            self.manager.wait_for_persist()
            self.manager.wait_for_commit()
            self.exit_drain_s = time.perf_counter() - t_d
            obs.add_span("ckpt.exit_drain", t_d, t_d + self.exit_drain_s)
            if self.records and self.exit_drain_s > 0:
                last = self.records[-1]
                self.records[-1] = dataclasses.replace(
                    last, ckpt_stall_s=last.ckpt_stall_s
                    + self.exit_drain_s)
        return self.records
