"""Architecture configs. Importing this package registers all configs."""
from .base import (INPUT_SHAPES, InputShape, LayerGroups, ModelConfig,
                   get_config, list_configs, pattern_groups, register,
                   smoke_variant, uniform_groups)

# import all arch modules so the registry is populated
from . import (dbrx_132b, rwkv6_7b, starcoder2_7b, recurrentgemma_2b,
               musicgen_medium, gemma3_27b, llama3_2_1b, paligemma_3b,
               llama4_maverick_400b_a17b, command_r_35b, llama2_7b)

__all__ = ["INPUT_SHAPES", "InputShape", "LayerGroups", "ModelConfig",
           "get_config", "list_configs", "pattern_groups", "register",
           "smoke_variant", "uniform_groups"]
