"""Llama-2-7B: the paper's own evaluation model family (Table II).
Used by the checkpointing benchmarks to mirror the paper's setup.
[arXiv:2307.09288]"""
from .base import ModelConfig, register, uniform_groups

register(ModelConfig(
    name="llama2-7b", arch_type="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=11008, vocab=32_000,
    layer_groups=uniform_groups("full", 32),
    rope_theta=10_000.0, norm="rmsnorm", act="silu",
    source="arXiv:2307.09288 (paper Table II)",
    long_context_ok=False,
))
