"""PaliGemma-3B: SigLIP vision tower (STUB: precomputed patch embeddings) +
Gemma decoder with prefix-LM masking over the image prefix. [arXiv:2407.07726]"""
from .base import ModelConfig, register, uniform_groups

register(ModelConfig(
    name="paligemma-3b", arch_type="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
    d_ff=16384, vocab=257_216,
    layer_groups=uniform_groups("full", 18),
    head_dim=256, rope_theta=10_000.0,
    tie_embeddings=True, norm="rmsnorm", act="gelu",
    n_prefix_embeds=256,  # SigLIP 224px/14 -> 256 patches (stubbed)
    source="arXiv:2407.07726",
    long_context_ok=False,  # full attention -> long_500k skipped
))
