"""MusicGen-medium: decoder-only over EnCodec tokens (4 codebooks), with
cross-attention to text-conditioning memory. Frontend (EnCodec) is a stub:
``input_specs`` supplies precomputed conditioning embeddings.
[arXiv:2306.05284]"""
from .base import ModelConfig, register, uniform_groups

register(ModelConfig(
    name="musicgen-medium", arch_type="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab=2048,
    layer_groups=uniform_groups("xattn", 48),
    rope_theta=10_000.0, norm="layernorm", act="gelu_mlp",
    use_bias=True,
    n_codebooks=4, n_memory_embeds=64,
    source="arXiv:2306.05284",
    long_context_ok=False,  # full attention decoder -> long_500k skipped
))
