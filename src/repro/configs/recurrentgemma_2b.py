"""RecurrentGemma-2B (Griffin): RG-LRU + local attention, 2 rec : 1 attn.
[arXiv:2402.19427]"""
from .base import ModelConfig, register, pattern_groups

register(ModelConfig(
    name="recurrentgemma-2b", arch_type="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab=256_000,
    # 26 = 8*(rec,rec,window) + (rec,rec)
    layer_groups=pattern_groups(("rec", "rec", "window"), 26),
    window=2048, rope_theta=10_000.0,
    tie_embeddings=True, norm="rmsnorm", act="gelu",
    lru_width=2560, conv_width=4,
    source="arXiv:2402.19427",
    long_context_ok=True,  # recurrent + local attention
))
