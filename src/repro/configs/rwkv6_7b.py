"""RWKV6 (Finch) 7B: attention-free, data-dependent decay. [arXiv:2404.05892]"""
from .base import ModelConfig, register, uniform_groups

register(ModelConfig(
    name="rwkv6-7b", arch_type="ssm",
    n_layers=32, d_model=4096, n_heads=0, n_kv_heads=0,
    d_ff=14336, vocab=65536,
    layer_groups=uniform_groups("rwkv", 32),
    rwkv_head_size=64, rwkv_chunk=16, rwkv_decay_lora=64,
    norm="layernorm", act="relu_sq",  # rwkv channel-mix uses relu^2
    source="arXiv:2404.05892",
    long_context_ok=True,  # O(1) recurrent state
))
