"""Command-R 35B: dense GQA, no-bias, parallel-block-style large FFN.
[hf:CohereForAI/c4ai-command-r-v01]"""
from .base import ModelConfig, register, uniform_groups

register(ModelConfig(
    name="command-r-35b", arch_type="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22528, vocab=256_000,
    layer_groups=uniform_groups("full", 40),
    rope_theta=8_000_000.0,
    use_bias=False, tie_embeddings=True, norm="layernorm", act="silu",
    source="hf:CohereForAI/c4ai-command-r-v01",
    long_context_ok=False,  # pure full attention -> long_500k skipped
))
