"""StarCoder2-7B: dense GQA, RoPE, native 4k sliding window. [arXiv:2402.19173]"""
from .base import ModelConfig, register, uniform_groups

register(ModelConfig(
    name="starcoder2-7b", arch_type="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
    d_ff=18432, vocab=49152,
    layer_groups=uniform_groups("window", 32),
    window=4096, rope_theta=1_000_000.0,
    use_bias=True, norm="layernorm", act="gelu_mlp",
    source="arXiv:2402.19173",
    long_context_ok=True,  # sliding-window attention
))
