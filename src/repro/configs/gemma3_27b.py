"""Gemma3-27B: 5:1 local(1024):global attention, 128k context, GQA.
[hf:google/gemma-3-1b-pt]"""
from .base import ModelConfig, register, pattern_groups

register(ModelConfig(
    name="gemma3-27b", arch_type="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16,
    d_ff=21504, vocab=262_144,
    # 62 = 10*(5 local + 1 global) + 2 local
    layer_groups=pattern_groups(
        ("window",) * 5 + ("full",), 62),
    window=1024, rope_theta=1_000_000.0,
    head_dim=128,  # gemma3 uses explicit head_dim 128 (32*128 != d_model)
    tie_embeddings=True, norm="rmsnorm", act="gelu",
    source="hf:google/gemma-3-1b-pt",
    long_context_ok=True,  # 5/6 sliding window; global layers decode O(S)
))
