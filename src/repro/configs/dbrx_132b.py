"""DBRX-132B: fine-grained MoE, 16 experts top-4, GQA. [hf:databricks/dbrx-base]"""
from .base import ModelConfig, register, uniform_groups

register(ModelConfig(
    name="dbrx-132b", arch_type="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab=100352,
    layer_groups=uniform_groups("full_moe", 40),
    n_experts=16, top_k=4,
    rope_theta=500_000.0, norm="layernorm", act="silu",
    source="hf:databricks/dbrx-base",
    long_context_ok=False,  # pure full attention -> long_500k skipped
))
