"""Model/config system.

``ModelConfig`` is the single declarative description a model is built from.
Layer stacks are expressed as *layer groups*: ``((pattern, count), ...)`` where
``pattern`` is a tuple of block-type strings and ``count`` repetitions of that
pattern are executed under one ``lax.scan`` with stacked parameters (keeps the
HLO small and compile times bounded). Block types:

``full``        self-attention, full causal                      + FFN
``window``      sliding-window causal self-attention             + FFN
``chunked``     chunked (block-local) causal self-attention      + FFN
``*_moe``       same attention, FFN replaced by MoE
``xattn``       full self-attention + cross-attention (memory)   + FFN
``rec``         RG-LRU recurrent block (Griffin/RecurrentGemma)
``rwkv``        RWKV6 time-mix + channel-mix block
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

LayerGroups = Tuple[Tuple[Tuple[str, ...], int], ...]


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                     # 0 for attention-free (rwkv)
    n_kv_heads: int
    d_ff: int
    vocab: int
    layer_groups: LayerGroups
    head_dim: int = 0                # 0 -> d_model // n_heads
    rope_theta: float = 10_000.0
    window: int = 0                  # sliding-window size for "window" blocks
    chunk: int = 0                   # chunk size for "chunked" blocks
    use_bias: bool = False
    tie_embeddings: bool = False
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "silu"                # silu (gated) | gelu (gated) | gelu_mlp
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 256        # tokens per dispatch group
    shared_expert: bool = False
    router_aux_coef: float = 0.01
    # --- recurrent (rwkv / rg-lru) ---
    rwkv_head_size: int = 64
    rwkv_chunk: int = 16
    rwkv_decay_lora: int = 64
    lru_width: int = 0               # 0 -> d_model
    conv_width: int = 4
    # --- modality frontends (stubs: precomputed embeddings) ---
    n_prefix_embeds: int = 0         # vlm: SigLIP patch embeds prepended
    n_memory_embeds: int = 0         # audio: cross-attention memory length
    n_codebooks: int = 0             # audio: parallel codebook streams
    # --- source citation ---
    source: str = ""
    # --- runtime ---
    dtype: str = "bfloat16"
    sharding_mode: str = "2d"        # "2d" (beyond-paper) | "tp_zero1" (paper)
    remat: bool = True
    analysis_unroll: bool = False  # unroll scans so cost_analysis counts true FLOPs
    attn_kv_block: int = 1024      # KV block size for blocked attention
    # beyond-paper §Perf: shard the decode KV cache on the sequence dim over
    # the 'model' axis (keeps heads/hd whole → no per-layer cache all-gather;
    # softmax over the sharded seq dim costs only tiny stat collectives).
    decode_kv_seq_shard: bool = False
    # beyond-paper §Perf: DeepSpeed-Ulysses-style sequence-parallel attention
    # — shard the *sequence* dim over 'model' inside attention (all-to-all on
    # entry/exit) instead of splitting KV heads / head_dim, which forces
    # partial-logit all-reduces every flash block when KV-heads < mesh size.
    ulysses_attention: bool = False
    # beyond-paper §Perf: Megatron-style sequence parallelism — keep the
    # residual stream sequence-sharded over 'model' between blocks, so TP
    # boundary collectives become reduce-scatter + all-gather (about half
    # the volume of the classic full all-reduce pair).
    seq_parallel_residual: bool = False
    max_decode_len: int = 0          # 0 -> use input shape seq_len
    long_context_ok: bool = False    # may run long_500k

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_rnn(self) -> int:
        return self.lru_width or self.d_model

    def n_params(self) -> int:
        """Approximate parameter count (used for 6ND model-FLOPs)."""
        from repro.models.model import count_params_analytic
        return count_params_analytic(self)

    def n_active_params(self) -> int:
        from repro.models.model import count_params_analytic
        return count_params_analytic(self, active_only=True)


def uniform_groups(block: str, n_layers: int, scan_span: int = 0
                   ) -> LayerGroups:
    """All layers identical: one scan group."""
    return (((block,), n_layers),)


def pattern_groups(pattern: Tuple[str, ...], n_layers: int) -> LayerGroups:
    """Repeat ``pattern``; a remainder prefix of the pattern becomes a second
    group (e.g. gemma3: 62 = 10*(5 local + 1 global) + 2 local)."""
    p = len(pattern)
    reps, rem = divmod(n_layers, p)
    groups: LayerGroups = ()
    if reps:
        groups += ((tuple(pattern), reps),)
    if rem:
        groups += ((tuple(pattern[:rem]), 1),)
    return groups


_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str, **overrides) -> ModelConfig:
    # import side-effect: populate registry
    from repro import configs as _c  # noqa: F401
    import importlib
    if name not in _REGISTRY:
        try:
            importlib.import_module(f"repro.configs.{name.replace('-', '_').replace('.', '_')}")
        except ImportError:
            pass
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    cfg = _REGISTRY[name]
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def list_configs() -> Tuple[str, ...]:
    import importlib, pkgutil
    import repro.configs as pkg
    for m in pkgutil.iter_modules(pkg.__path__):
        if m.name not in ("base",):
            importlib.import_module(f"repro.configs.{m.name}")
    return tuple(sorted(_REGISTRY))


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: 2 layers, d_model<=512, <=4 experts."""
    d = min(cfg.d_model, 256)
    heads = 4 if cfg.n_heads else 0
    kv = min(cfg.n_kv_heads, heads) or (1 if heads else 0)
    if heads and cfg.n_kv_heads > 1:
        kv = 2
    # preserve the layer-type mix in 2 layers
    first_pattern = cfg.layer_groups[0][0]
    types = []
    for g_pattern, _cnt in cfg.layer_groups:
        for t in g_pattern:
            if t not in types:
                types.append(t)
    pattern = tuple(types[:2]) if len(types) >= 2 else (first_pattern[0],) * 2
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=len(pattern),
        d_model=d,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=0,
        d_ff=min(cfg.d_ff, 512),
        vocab=min(cfg.vocab, 512),
        layer_groups=((pattern, 1),),
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        window=min(cfg.window, 16) if cfg.window else 0,
        chunk=min(cfg.chunk, 16) if cfg.chunk else 0,
        lru_width=0,
        moe_group_size=16,
        n_prefix_embeds=min(cfg.n_prefix_embeds, 4),
        n_memory_embeds=min(cfg.n_memory_embeds, 4),
        rwkv_chunk=4,
        remat=False,
    )
