"""Llama-3.2-1B: small dense llama3, GQA. [hf:meta-llama/Llama-3.2-1B]"""
from .base import ModelConfig, register, uniform_groups

register(ModelConfig(
    name="llama3.2-1b", arch_type="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8,
    d_ff=8192, vocab=128_256,
    layer_groups=uniform_groups("full", 16),
    rope_theta=500_000.0,
    tie_embeddings=True, norm="rmsnorm", act="silu",
    source="hf:meta-llama/Llama-3.2-1B",
    long_context_ok=False,  # pure full attention -> long_500k skipped
))
