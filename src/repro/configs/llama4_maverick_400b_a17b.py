"""Llama-4 Maverick 400B-A17B: interleaved MoE (128 experts top-1 + shared
expert on alternating layers), chunked local attention (8192) on 3/4 layers
with full ("NoPE") attention every 4th layer, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E]"""
from .base import ModelConfig, register, pattern_groups

register(ModelConfig(
    name="llama4-maverick-400b-a17b", arch_type="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202_048,
    # alternating dense/MoE FFN; every 4th layer full attention:
    # pattern of 4: (chunked+dense, chunked+moe, chunked+dense, full+moe)
    layer_groups=pattern_groups(
        ("chunked", "chunked_moe", "chunked", "full_moe"), 48),
    chunk=8192, rope_theta=500_000.0,
    n_experts=128, top_k=1, shared_expert=True,
    norm="rmsnorm", act="silu",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    long_context_ok=True,  # chunked attention on 3/4 of layers
))
