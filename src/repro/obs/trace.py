"""Thread-aware span tracing with Chrome trace-event (Perfetto) export.

Design constraints (ISSUE 7):

* **Off by default, near-free when off.** ``span(...)`` reads one module
  global; when no tracer is installed it returns a shared no-op context
  manager. The enforced budget is <1% of iteration time for the training
  loop's instrumentation density (``tests/test_obs.py``).
* **Lock-free-ish hot path.** Each thread appends to its *own* ring buffer
  (plain list mutation — no lock, no contention). The only lock —
  ``obs.trace_registry`` (rank 80, above every runtime lock, see
  ``repro.analysis.locks``) — guards buffer registration (once per thread)
  and export snapshots. It is therefore always legal to record a span while
  holding any engine/repository/cache lock, and ckptlint's blocking-under-
  lock rule holds: export snapshots under the lock, file I/O happens after
  it is released.
* **Bounded.** Rings have a fixed per-thread capacity; on overflow the
  oldest events are overwritten and a drop counter is kept (exported in the
  trace metadata) — tracing can be left on for a long run without growing
  without bound.
* **Lanes.** Every event carries a *lane* — by default the recording
  thread's name (the engine already names its lanes: ``dsllm-stage``,
  ``dsllm-producer-i``, ``dsllm-flush-i``, ``ckpt-commit``, …); call sites
  may override (the coordinator tags per-rank work ``rank00000``…). Export
  emits one Chrome track per lane via ``thread_name`` metadata events.
* **Flows.** Cross-lane causality (capture→D2H→encode→flush→commit;
  restore index→plan→read→assemble) is linked with Chrome flow events
  (``ph: s/t/f``) keyed by :func:`flow_id`.

Usage::

    from repro.obs import span, tracing

    with tracing("out.json"):          # enable + export on exit
        with span("encode", step=3, rank=0, bytes=1 << 20):
            ...
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, Iterator, List, Optional, Tuple

from repro.analysis.locks import declares_lock

__all__ = [
    "Tracer", "span", "add_span", "instant", "counter", "flow_id",
    "enable", "disable", "enabled", "get_tracer", "tracing",
]

# Event tuple layout (kept a plain tuple — hot-path allocation cost):
#   (ph, name, t0, dur, lane, tid, args, flow, flow_phase)
# ph: "X" complete span | "i" instant | "C" counter
# t0/dur: time.perf_counter() seconds; export converts to µs vs. origin.
_Event = Tuple[str, str, float, float, str, int, Optional[Dict[str, Any]],
               Optional[str], str]

DEFAULT_CAPACITY = 1 << 16  # events per thread


class _ThreadBuffer:
    """Fixed-capacity ring owned by exactly one writer thread."""

    __slots__ = ("events", "capacity", "head", "dropped", "lane", "tid")

    def __init__(self, capacity: int, lane: str, tid: int):
        self.events: List[_Event] = []
        self.capacity = capacity
        self.head = 0           # overwrite cursor once full (oldest event)
        self.dropped = 0
        self.lane = lane        # thread name at registration = default lane
        self.tid = tid

    def add(self, ev: _Event) -> None:
        if len(self.events) < self.capacity:
            self.events.append(ev)
        else:
            self.events[self.head] = ev
            self.head = (self.head + 1) % self.capacity
            self.dropped += 1

    def snapshot(self) -> Tuple[List[_Event], int]:
        """Copy in ring order (oldest first). Safe to call from any thread:
        the owner only appends/overwrites single slots (atomic under the
        GIL), and the copy tolerates a concurrently-moving head."""
        evs = list(self.events)
        head = self.head
        if len(evs) >= self.capacity and head:
            evs = evs[head:] + evs[:head]
        return evs, self.dropped


@declares_lock("obs.trace_registry", rank=80, attrs=("_lock",))
class Tracer:
    """Per-process span recorder. Install via :func:`enable`."""

    def __init__(self, capacity_per_thread: int = DEFAULT_CAPACITY):
        self.capacity = int(capacity_per_thread)
        self.t_origin = time.perf_counter()
        self._lock = threading.Lock()
        self._buffers: List[_ThreadBuffer] = []
        self._tls = threading.local()

    # ------------------------------------------------------------- recording
    def _buffer(self) -> _ThreadBuffer:
        buf = getattr(self._tls, "buf", None)
        if buf is None:
            th = threading.current_thread()
            buf = _ThreadBuffer(self.capacity, th.name, th.ident or 0)
            with self._lock:
                self._buffers.append(buf)
            self._tls.buf = buf
        return buf

    def add_complete(self, name: str, t0: float, t1: float,
                     lane: Optional[str] = None,
                     args: Optional[Dict[str, Any]] = None,
                     flow: Optional[str] = None,
                     flow_phase: str = "step") -> None:
        buf = self._buffer()
        buf.add(("X", name, t0, t1 - t0, lane or buf.lane, buf.tid,
                 args or None, flow, flow_phase))

    def add_instant(self, name: str, lane: Optional[str] = None,
                    args: Optional[Dict[str, Any]] = None,
                    flow: Optional[str] = None,
                    flow_phase: str = "start") -> None:
        buf = self._buffer()
        buf.add(("i", name, time.perf_counter(), 0.0, lane or buf.lane,
                 buf.tid, args or None, flow, flow_phase))

    def add_counter(self, name: str, value: float,
                    lane: Optional[str] = None) -> None:
        buf = self._buffer()
        buf.add(("C", name, time.perf_counter(), 0.0, lane or buf.lane,
                 buf.tid, {"value": value}, None, "step"))

    def ingest(self, events: List[Dict[str, Any]], *,
               clock_offset: float = 0.0,
               default_lane: Optional[str] = None) -> None:
        """Merge foreign events (another process's ``Tracer.events()``)
        into this tracer's timeline.

        The process-per-rank runtime ships each child's spans back over
        the pipe; ``clock_offset`` (parent ``perf_counter`` minus the
        child's, measured at the ready handshake) maps their timestamps
        onto this process's clock so one export shows every rank.
        Lanes the child didn't name explicitly (its ``MainThread``)
        are relabeled to ``default_lane`` — the rank's lane — so child
        tracks sort with the rank's engine lanes in Perfetto.
        """
        for ev in events:
            if ev.get("ph") != "X":
                continue
            lane = ev.get("lane")
            if default_lane is not None and \
                    (not lane or lane == "MainThread"):
                lane = default_lane
            self.add_complete(
                ev["name"], ev["t0"] + clock_offset,
                ev["t1"] + clock_offset, lane=lane,
                args=ev.get("args") or None, flow=ev.get("flow"),
                flow_phase=ev.get("flow_phase") or "step")

    # --------------------------------------------------------------- reading
    def events(self) -> List[Dict[str, Any]]:
        """All recorded events as dicts (tests / breakdown analysis)."""
        out: List[Dict[str, Any]] = []
        for evs, _dropped in self._snapshots():
            for ph, name, t0, dur, lane, tid, args, flow, fph in evs:
                out.append({"ph": ph, "name": name, "t0": t0, "dur": dur,
                            "t1": t0 + dur, "lane": lane, "tid": tid,
                            "args": args or {}, "flow": flow,
                            "flow_phase": fph})
        out.sort(key=lambda e: e["t0"])
        return out

    def spans(self, name: Optional[str] = None) -> List[Dict[str, Any]]:
        """Complete spans only, optionally filtered by name prefix."""
        evs = [e for e in self.events() if e["ph"] == "X"]
        if name is not None:
            evs = [e for e in evs if e["name"] == name
                   or e["name"].startswith(name + ".")]
        return evs

    def dropped(self) -> int:
        return sum(d for _evs, d in self._snapshots())

    def _snapshots(self) -> List[Tuple[List[_Event], int]]:
        with self._lock:
            buffers = list(self._buffers)
        return [b.snapshot() for b in buffers]

    # ---------------------------------------------------------------- export
    def to_chrome(self) -> Dict[str, Any]:
        """Chrome trace-event JSON object (Perfetto / chrome://tracing)."""
        pid = os.getpid()
        origin = self.t_origin
        events = self.events()
        # One track per lane: stable synthetic tids in first-seen order.
        lane_tid: Dict[str, int] = {}
        for ev in events:
            lane_tid.setdefault(ev["lane"], len(lane_tid) + 1)
        out: List[Dict[str, Any]] = [{
            "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
            "args": {"name": "repro-ckpt"},
        }]
        for lane, tid in sorted(lane_tid.items(), key=lambda kv: kv[1]):
            out.append({"name": "thread_name", "ph": "M", "pid": pid,
                        "tid": tid, "args": {"name": lane}})
        for ev in events:
            ts = max(0.0, (ev["t0"] - origin) * 1e6)
            tid = lane_tid[ev["lane"]]
            if ev["ph"] == "X":
                rec = {"name": ev["name"], "ph": "X", "cat": "ckpt",
                       "ts": ts, "dur": max(0.0, ev["dur"] * 1e6),
                       "pid": pid, "tid": tid}
                if ev["args"]:
                    rec["args"] = ev["args"]
                out.append(rec)
                if ev["flow"] is not None:
                    fph = {"start": "s", "step": "t", "end": "f"}.get(
                        ev["flow_phase"], "t")
                    frec = {"name": "ckpt-flow", "ph": fph, "cat": "flow",
                            "id": ev["flow"], "ts": ts, "pid": pid,
                            "tid": tid}
                    if fph == "f":
                        frec["bp"] = "e"  # bind to enclosing slice
                    out.append(frec)
            elif ev["ph"] == "i":
                rec = {"name": ev["name"], "ph": "i", "cat": "ckpt",
                       "ts": ts, "pid": pid, "tid": tid, "s": "t"}
                if ev["args"]:
                    rec["args"] = ev["args"]
                out.append(rec)
                if ev["flow"] is not None:
                    fph = {"start": "s", "step": "t", "end": "f"}.get(
                        ev["flow_phase"], "t")
                    out.append({"name": "ckpt-flow", "ph": fph,
                                "cat": "flow", "id": ev["flow"], "ts": ts,
                                "pid": pid, "tid": tid})
            elif ev["ph"] == "C":
                out.append({"name": ev["name"], "ph": "C", "cat": "ckpt",
                            "ts": ts, "pid": pid, "tid": 0,
                            "args": ev["args"]})
        return {"traceEvents": out, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped()}}

    def export(self, path: str) -> str:
        """Write the Chrome JSON to ``path`` (no lock held during I/O)."""
        doc = self.to_chrome()  # snapshots under the lock, then releases
        tmp = path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as fh:
            json.dump(doc, fh)
        os.replace(tmp, path)
        return path


class _SpanHandle:
    __slots__ = ("_tracer", "_name", "_lane", "_flow", "_flow_phase",
                 "_args", "_t0")

    def __init__(self, tracer, name, lane, flow, flow_phase, args):
        self._tracer = tracer
        self._name = name
        self._lane = lane
        self._flow = flow
        self._flow_phase = flow_phase
        self._args = args

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        self._tracer.add_complete(self._name, self._t0, time.perf_counter(),
                                  lane=self._lane, args=self._args or None,
                                  flow=self._flow,
                                  flow_phase=self._flow_phase)
        return False


class _NoopSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NOOP = _NoopSpan()
_ACTIVE: Optional[Tracer] = None


# ------------------------------------------------------------- module API
def enabled() -> bool:
    return _ACTIVE is not None


def get_tracer() -> Optional[Tracer]:
    return _ACTIVE


def enable(capacity_per_thread: int = DEFAULT_CAPACITY) -> Tracer:
    """Install a fresh process-wide tracer and return it."""
    global _ACTIVE
    _ACTIVE = Tracer(capacity_per_thread)
    return _ACTIVE


def disable() -> Optional[Tracer]:
    """Uninstall the tracer; returns it so callers can still export."""
    global _ACTIVE
    t = _ACTIVE
    _ACTIVE = None
    return t


def span(name: str, lane: Optional[str] = None, flow: Optional[str] = None,
         flow_phase: str = "step", **args: Any):
    """Context manager recording one complete span (no-op when disabled)."""
    t = _ACTIVE
    if t is None:
        return _NOOP
    return _SpanHandle(t, name, lane, flow, flow_phase, args)


def add_span(name: str, t0: float, t1: float, lane: Optional[str] = None,
             flow: Optional[str] = None, flow_phase: str = "step",
             **args: Any) -> None:
    """Record a span from an existing perf_counter pair (no-op when
    disabled) — lets code that must keep wall-clock stats emit the same
    interval as a trace span without timing twice."""
    t = _ACTIVE
    if t is not None:
        t.add_complete(name, t0, t1, lane=lane, args=args or None,
                       flow=flow, flow_phase=flow_phase)


def instant(name: str, lane: Optional[str] = None,
            flow: Optional[str] = None, flow_phase: str = "start",
            **args: Any) -> None:
    t = _ACTIVE
    if t is not None:
        t.add_instant(name, lane=lane, args=args or None, flow=flow,
                      flow_phase=flow_phase)


def counter(name: str, value: float) -> None:
    """Record a counter sample (rendered as a counter track in Perfetto)."""
    t = _ACTIVE
    if t is not None:
        t.add_counter(name, value)


def flow_id(kind: str, step: int, rank: Optional[int] = None) -> str:
    """Stable flow-link id for one logical operation (e.g. one save)."""
    if rank is None:
        return f"{kind}-{step}"
    return f"{kind}-{step}-r{rank}"


class tracing:
    """``with tracing("out.json") as t:`` — enable, export+disable on exit.

    ``path=None`` enables without exporting (tests inspect ``t.events()``).
    Nesting-safe: on exit the previously-active tracer (if any) is
    restored, so a benchmark that records its own trace under a harness
    that already called ``tracing`` doesn't silently kill the outer one.
    """

    def __init__(self, path: Optional[str] = None,
                 capacity_per_thread: int = DEFAULT_CAPACITY):
        self.path = path
        self.capacity = capacity_per_thread
        self.tracer: Optional[Tracer] = None
        self._prev: Optional[Tracer] = None

    def __enter__(self) -> Tracer:
        self._prev = get_tracer()
        self.tracer = enable(self.capacity)
        return self.tracer

    def __exit__(self, exc_type, exc, tb):
        global _ACTIVE
        t = self.tracer
        if get_tracer() is t:
            _ACTIVE = self._prev
        self._prev = None
        if t is not None and self.path is not None:
            t.export(self.path)
        return False
