"""ckpttrace: zero-dependency tracing + metrics for the checkpoint lifecycle.

Two halves, both stdlib-only:

* :mod:`repro.obs.trace` — thread-aware spans recorded into per-thread ring
  buffers, exportable as Chrome trace-event JSON (loadable in Perfetto /
  ``chrome://tracing``). Off by default; ``span(...)`` is a near-free no-op
  when disabled.
* :mod:`repro.obs.metrics` — a process-wide registry of counters / gauges /
  histograms plus :class:`~repro.obs.metrics.SaveReport` /
  :class:`~repro.obs.metrics.RestoreReport`, the unified per-operation
  report schema over the engine's divergent stats objects.

Both modules pass the full ckptlint rule set (their internal locks are
declared at ranks 80/82 — *above* every runtime lock, so recording from any
instrumented seam is rank-legal — and no I/O happens under them).
"""

from .trace import (Tracer, add_span, counter, disable, enable, enabled,
                    flow_id, get_tracer, instant, span, tracing)
from .metrics import (MetricsRegistry, RestoreReport, SaveReport, metrics)

__all__ = [
    "Tracer", "add_span", "counter", "disable", "enable", "enabled",
    "flow_id", "get_tracer", "instant", "span", "tracing",
    "MetricsRegistry", "RestoreReport", "SaveReport", "metrics",
]
