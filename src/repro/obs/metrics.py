"""Process-wide metrics registry + the unified per-operation report schema.

The registry holds counters (monotonic totals: bytes staged / encoded /
written per codec, GC reclaim), gauges (host-cache occupancy), and
histograms (reservation wait, barrier wait, commit latency). Everything is
updated under one declared lock — ``obs.metrics`` at rank 82, above every
runtime lock — and ``snapshot()`` returns plain data, so recording is legal
from any instrumented seam and never does I/O.

:class:`SaveReport` / :class:`RestoreReport` put the engine's divergent
stats objects (``CheckpointFuture.stats``, ``RestoreStats``,
``CascadeEvent``) behind one dict schema::

    {"kind": "save" | "restore" | "cascade",
     "step": int | None,
     "phases": {phase_name: seconds, ...},
     "bytes": {name: int, ...},
     "counts": {name: int, ...},
     "extra": {...}}

Benchmarks and the ``storage.cli stats`` subcommand consume this shape
instead of reaching into each stats object's ad-hoc attributes.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Dict, List, Optional

from repro.analysis.locks import declares_lock

__all__ = ["MetricsRegistry", "SaveReport", "RestoreReport",
           "cascade_report", "metrics"]

_HIST_SAMPLE_CAP = 512  # bounded reservoir per histogram


class _Hist:
    __slots__ = ("count", "total", "vmin", "vmax", "samples")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")
        self.samples: List[float] = []

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)
        if len(self.samples) < _HIST_SAMPLE_CAP:
            self.samples.append(v)

    def snapshot(self) -> Dict[str, float]:
        return {"count": self.count, "sum": self.total,
                "min": self.vmin if self.count else 0.0,
                "max": self.vmax if self.count else 0.0,
                "mean": (self.total / self.count) if self.count else 0.0}


@declares_lock("obs.metrics", rank=82, attrs=("_lock",))
class MetricsRegistry:
    """Thread-safe counters / gauges / histograms with dict snapshots."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, _Hist] = {}

    def inc(self, name: str, value: float = 1.0) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0.0) + value

    def set_gauge(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = value

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = _Hist()
            h.observe(value)

    def get_counter(self, name: str) -> float:
        with self._lock:
            return self._counters.get(name, 0.0)

    def snapshot(self) -> Dict[str, Any]:
        """Plain-data view: {"counters": {...}, "gauges": {...},
        "histograms": {name: {count,sum,min,max,mean}}}."""
        with self._lock:
            return {
                "counters": dict(self._counters),
                "gauges": dict(self._gauges),
                "histograms": {k: h.snapshot()
                               for k, h in self._hists.items()},
            }

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()


#: The process-wide registry every instrumented seam records into.
metrics = MetricsRegistry()


def _clean(d: Dict[str, Any]) -> Dict[str, Any]:
    return {k: v for k, v in d.items() if v is not None}


@dataclasses.dataclass
class SaveReport:
    """One save, as the unified report schema (see module docstring)."""

    step: Optional[int]
    phases: Dict[str, float]
    bytes: Dict[str, int]
    counts: Dict[str, int]
    extra: Dict[str, Any]
    kind: str = "save"

    @classmethod
    def from_future(cls, future: Any) -> "SaveReport":
        """Build from a :class:`~repro.core.engine.CheckpointFuture` (or the
        coordinator's aggregate future) — any object with a
        ``CheckpointStats``-shaped ``.stats``."""
        st = future.stats
        phases = {
            "blocking_s": st.blocking_s,
            "stage_s": st.stage_s,
            "serialize_s": st.serialize_s,
            "flush_s": st.flush_s,
        }
        if st.t_captured:
            phases["capture_s"] = st.capture_latency_s
        if st.t_persisted:
            phases["persist_s"] = st.persist_latency_s
        commit_s = getattr(st, "commit_s", 0.0)
        if commit_s:
            phases["commit_s"] = commit_s
        return cls(
            step=getattr(future, "step", None),
            phases=phases,
            bytes={"tensors": st.bytes_tensors, "objects": st.bytes_objects,
                   "total": st.total_bytes},
            counts={"files": st.n_files, "tensors": st.n_tensors},
            extra=dict(st.extra),
        )

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "step": self.step,
                "phases": _clean(self.phases), "bytes": dict(self.bytes),
                "counts": dict(self.counts), "extra": dict(self.extra)}


@dataclasses.dataclass
class RestoreReport:
    """One restore, as the unified report schema."""

    step: Optional[int]
    phases: Dict[str, float]
    bytes: Dict[str, int]
    counts: Dict[str, int]
    extra: Dict[str, Any]
    kind: str = "restore"

    @classmethod
    def from_stats(cls, stats: Any,
                   step: Optional[int] = None) -> "RestoreReport":
        """Build from a :class:`~repro.core.restore.RestoreStats`."""
        return cls(
            step=step,
            phases={"index_s": stats.index_s, "plan_s": stats.plan_s,
                    "read_s": stats.read_s, "assemble_s": stats.assemble_s},
            bytes={"read": stats.bytes_read},
            counts={"tensors": getattr(stats, "n_tensors", 0)},
            extra={},
        )

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "step": self.step,
                "phases": _clean(self.phases), "bytes": dict(self.bytes),
                "counts": dict(self.counts), "extra": dict(self.extra)}


def cascade_report(event: Any) -> Dict[str, Any]:
    """A :class:`~repro.storage.repository.CascadeEvent` in the same
    schema (``kind="cascade"``)."""
    return {"kind": "cascade", "step": event.step,
            "phases": {"upload_s": event.t_end - event.t_start},
            "bytes": {"uploaded": event.nbytes},
            "counts": {}, "extra": {"tier": event.tier}}
