"""Runtime lock-order witness: validates the declared hierarchy against
real executions.

The static pass (:mod:`repro.analysis.lockorder`) proves properties of the
*source*; this module proves the declared ranks match what threads
actually do. While a recording is active, every lock declared with
:func:`repro.analysis.locks.declares_lock` / ``named_lock`` is replaced by
a :class:`WitnessLock` proxy that maintains a per-thread stack of held
(name, rank) pairs. Acquiring a lock whose rank is not strictly greater
than every rank already held records a :class:`Violation` (it never
raises mid-test — a deadlock-prone ordering should fail the assertion at
the end of the test, not crash a worker thread halfway through a save).

The fault-injection suites run under a recording and assert zero
violations at teardown, so the hierarchy table in ``locks.py`` can never
silently drift from the code.

Usage::

    from repro.analysis import witness
    with witness.recording() as w:
        ...  # construct engines/managers and exercise them
    assert not w.violations
"""

from __future__ import annotations

import contextlib
import threading
import traceback
from typing import Any, Iterator, List, Optional, Set, Tuple

__all__ = ["Violation", "LockWitness", "WitnessLock", "install",
           "uninstall", "current", "recording"]


class Violation:
    """One out-of-order acquisition observed at runtime."""

    def __init__(self, thread: str, held: List[Tuple[str, int]],
                 name: str, rank: int, stack: str):
        self.thread = thread
        self.held = list(held)
        self.name = name
        self.rank = rank
        self.stack = stack

    def __repr__(self) -> str:
        held = ", ".join(f"{n}(r{r})" for n, r in self.held)
        return (f"<lock-order violation in {self.thread}: acquired "
                f"{self.name}(r{self.rank}) while holding [{held}]>")

    def describe(self) -> str:
        return f"{self!r}\nacquired at:\n{self.stack}"


class LockWitness:
    """Collects per-thread acquisition order and hierarchy violations."""

    def __init__(self) -> None:
        self.violations: List[Violation] = []
        #: observed (held-name, acquired-name) nesting edges — useful for
        #: auditing which static edges real executions actually exercise
        self.edges: Set[Tuple[str, str]] = set()
        self.acquisitions = 0
        self._tls = threading.local()
        self._mu = threading.Lock()

    def _stack(self) -> List[Tuple[str, int]]:
        st = getattr(self._tls, "held", None)
        if st is None:
            st = self._tls.held = []
        return st

    def note_acquire(self, name: str, rank: int) -> None:
        held = self._stack()
        with self._mu:
            self.acquisitions += 1
        if held:
            top_name, top_rank = held[-1]
            with self._mu:
                self.edges.add((top_name, name))
            if name != top_name and rank <= max(r for _n, r in held):
                v = Violation(threading.current_thread().name, held,
                              name, rank,
                              "".join(traceback.format_stack(limit=12)))
                with self._mu:
                    self.violations.append(v)
        held.append((name, rank))

    def note_release(self, name: str) -> None:
        held = self._stack()
        for i in range(len(held) - 1, -1, -1):
            if held[i][0] == name:
                del held[i]
                return

    def assert_clean(self) -> None:
        if self.violations:
            raise AssertionError(
                "lock-order witness recorded hierarchy violations:\n"
                + "\n".join(v.describe() for v in self.violations))


class WitnessLock:
    """Recording proxy over a ``Lock``/``RLock``/``Condition``.

    Acquisition via ``with``/``acquire`` is recorded against the witness;
    everything else (``wait``, ``notify_all``, ...) delegates to the
    wrapped primitive. A ``Condition.wait`` releases the underlying lock
    internally but the proxy keeps it on the held stack — conceptually the
    lock is held around the wait, which is exactly the window lock-order
    reasoning cares about.
    """

    def __init__(self, name: str, rank: int, inner: Any,
                 witness: LockWitness):
        self._ckpt_name = name
        self._ckpt_rank = rank
        self._ckpt_inner = inner
        self._ckpt_witness = witness

    def acquire(self, *a: Any, **k: Any) -> Any:
        got = self._ckpt_inner.acquire(*a, **k)
        if got:
            self._ckpt_witness.note_acquire(self._ckpt_name,
                                            self._ckpt_rank)
        return got

    def release(self, *a: Any, **k: Any) -> Any:
        self._ckpt_witness.note_release(self._ckpt_name)
        return self._ckpt_inner.release(*a, **k)

    def __enter__(self) -> Any:
        got = self._ckpt_inner.__enter__()
        self._ckpt_witness.note_acquire(self._ckpt_name, self._ckpt_rank)
        return got

    def __exit__(self, *exc: Any) -> Any:
        self._ckpt_witness.note_release(self._ckpt_name)
        return self._ckpt_inner.__exit__(*exc)

    def __getattr__(self, item: str) -> Any:
        return getattr(self._ckpt_inner, item)


_current: Optional[LockWitness] = None
_install_mu = threading.Lock()


def current() -> Optional[LockWitness]:
    """The active witness, or None when not recording (the common case)."""
    return _current


def install() -> LockWitness:
    """Start recording. Locks constructed *after* this point are
    instrumented; objects built earlier keep their plain locks."""
    global _current
    with _install_mu:
        if _current is None:
            _current = LockWitness()
        return _current


def uninstall() -> Optional[LockWitness]:
    global _current
    with _install_mu:
        w, _current = _current, None
        return w


@contextlib.contextmanager
def recording() -> Iterator[LockWitness]:
    """Record for the duration of a ``with`` block (test fixture form)."""
    w = install()
    try:
        yield w
    finally:
        uninstall()
