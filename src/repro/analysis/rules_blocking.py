"""CKPT201: blocking calls lexically inside a held-lock scope.

Holding a declared lock across file I/O, sleeps, barrier waits, future
results, thread joins, or storage-backend calls serializes every other
lane behind that I/O — the exact failure mode the engine's overlap design
exists to avoid (and a classic deadlock amplifier when the blocked-on
resource itself needs the lock).

Waiting on a condition variable that *aliases the held lock* (e.g.
``self._freed.wait()`` under ``HostCache._lock``) is the sanctioned
pattern and is never flagged; the alias is resolved through the
``declares_lock`` attr list.
"""

from __future__ import annotations

import ast
from typing import Iterator, List, Tuple

from .linter import (Finding, Project, Rule, SourceModule, call_name,
                     dotted)
from .lockorder import FunctionCtx, HeldScopeWalker, receiver_lastname

# plain-name or dotted-suffix calls that block
_BLOCKING_FUNCS = {
    "sleep", "open", "fsync", "file_checksum", "probe_step_complete",
}
_BLOCKING_OS = {
    "replace", "rename", "remove", "unlink", "makedirs", "listdir",
    "scandir", "stat", "rmdir", "fsync",
}
_BLOCKING_SHUTIL = {
    "copy", "copy2", "copyfile", "copytree", "move", "rmtree",
    "disk_usage",
}
# backend/tier storage operations (blocking network or disk I/O)
_BACKEND_METHODS = {
    "put", "get", "put_file", "get_file", "delete", "list", "exists",
    "size",
}
_BACKENDISH = ("backend", "_local", "local", "tier", "remote", "store")
_THREADISH = ("thread", "worker", "flusher", "committer", "proc",
              "process", "cascade")
_QUEUEISH = ("queue", "_q", ".q")


def _is_backendish(name: str) -> bool:
    low = name.lower()
    return any(tag in low for tag in _BACKENDISH)


def _is_threadish(name: str) -> bool:
    low = name.lower()
    return low == "t" or any(tag in low for tag in _THREADISH)


def _is_queueish(name: str) -> bool:
    low = name.lower()
    return low in ("q", "jobs", "work") or "queue" in low


class BlockingUnderLockRule(Rule):
    id = "CKPT201"
    summary = "blocking call while holding a declared lock"

    def check(self, module: SourceModule,
              project: Project) -> Iterator[Finding]:
        findings: List[Finding] = []

        def flag(node: ast.Call, what: str,
                 held: List[Tuple[str, int]]) -> None:
            held_s = ", ".join(h for h, _r in held)
            findings.append(Finding(
                rule=self.id, path=module.rel, line=node.lineno,
                col=node.col_offset,
                message=f"{what} while holding [{held_s}]"))

        def on_call(node: ast.Call, held: List[Tuple[str, int]],
                    ctx: FunctionCtx) -> None:
            fn = call_name(node)
            d = dotted(node.func)
            recv = receiver_lastname(node)
            if fn in _BLOCKING_FUNCS and (d == fn or "." not in d
                                          or d.startswith("time.")
                                          or d.startswith("os.")):
                flag(node, f"blocking call {d or fn}()", held)
            elif d.startswith("os.") and fn in _BLOCKING_OS:
                flag(node, f"blocking call {d}()", held)
            elif d.startswith("os.path.") and fn in ("getsize",
                                                     "exists"):
                flag(node, f"blocking call {d}()", held)
            elif d.startswith("shutil.") and fn in _BLOCKING_SHUTIL:
                flag(node, f"blocking call {d}()", held)
            elif fn == "result":
                flag(node, f"future {d or 'result'}() wait", held)
            elif fn == "join" and _is_threadish(recv):
                flag(node, f"thread join {d}()", held)
            elif fn == "get" and _is_queueish(recv):
                flag(node, f"queue get {d}()", held)
            elif fn == "wait":
                # own-condition wait resolves as an acquiring/alias call
                # and never reaches on_call; anything else (events,
                # foreign conditions, futures) blocks under the lock
                flag(node, f"blocking wait {d}()", held)
            elif fn in _BACKEND_METHODS and _is_backendish(recv):
                flag(node, f"storage backend call {d}()", held)

        HeldScopeWalker(module, project, on_call=on_call).walk()
        return iter(findings)


def RULES() -> List[Rule]:
    return [BlockingUnderLockRule()]
