"""``ckptlint`` rule engine: AST loading, suppressions, rule running.

The analyzer is project-native and stdlib-only (``ast`` + ``os``): it
knows this codebase's concurrency and commit-protocol conventions and
checks them mechanically on every PR. Rules live in sibling modules
(:mod:`.lockorder`, :mod:`.rules_blocking`, :mod:`.rules_commit`,
:mod:`.rules_snapshot`, :mod:`.rules_hygiene`); each rule yields
:class:`Finding` objects with precise file:line anchors.

Suppression: append ``# ckptlint: disable=RULE`` (comma-separated for
several rules, or ``all``) to the offending line, or put the comment on
its own line directly above the statement. Every suppression in this
repository must carry an inline justification — the clean-tree test and
reviewers hold that line.
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re
import tokenize
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set, \
    Tuple

_SUPPRESS_RE = re.compile(
    r"#\s*ckptlint:\s*disable=([A-Za-z0-9_,\s]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation anchored to a source location."""

    rule: str
    path: str           # display (relative) path
    line: int
    col: int
    message: str
    suppressed: bool = False

    def format(self) -> str:
        tag = " (suppressed)" if self.suppressed else ""
        return f"{self.path}:{self.line}:{self.col}: {self.rule}{tag} " \
               f"{self.message}"


class SourceModule:
    """One parsed file: source, AST (with parent links), suppressions."""

    def __init__(self, path: str, rel: str, source: str):
        self.path = path
        self.rel = rel.replace(os.sep, "/")
        self.source = source
        self.tree = ast.parse(source, filename=path)
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                child.parent = node  # type: ignore[attr-defined]
        self.suppressions = self._parse_suppressions(source)

    @staticmethod
    def _parse_suppressions(source: str) -> Dict[int, Set[str]]:
        """line number -> suppressed rule ids (``{"all"}`` disables all).

        Comments are found with the tokenizer, not a substring scan, so a
        ``# ckptlint:`` inside a string literal is never a suppression.
        """
        out: Dict[int, Set[str]] = {}
        lines = source.splitlines()
        try:
            tokens = list(tokenize.generate_tokens(
                iter(lines).__next__ if False else
                (line + "\n" for line in lines).__next__))
        except (tokenize.TokenError, IndentationError, SyntaxError):
            return out
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _SUPPRESS_RE.search(tok.string)
            if not m:
                continue
            rules = {r.strip().upper() for r in m.group(1).split(",")
                     if r.strip()}
            lineno = tok.start[0]
            target = lineno
            # a comment alone on its line applies to the next code line
            if lines[lineno - 1].lstrip().startswith("#"):
                target = lineno + 1
            out.setdefault(target, set()).update(rules)
        return out

    def is_suppressed(self, rule: str, line: int) -> bool:
        for probe in (line,):
            rules = self.suppressions.get(probe)
            if rules and (rule.upper() in rules or "ALL" in rules):
                return True
        return False


class Project:
    """All modules under analysis plus the statically-extracted lock
    declarations (``@declares_lock`` / ``named_lock`` call sites)."""

    def __init__(self, modules: Sequence[SourceModule]):
        self.modules = list(modules)
        # class name -> {attr -> (lock name, rank)}; merged project-wide
        # (class names are unique enough in this codebase; collisions
        # would merge attr maps, which is safe for alias resolution).
        self.class_lock_attrs: Dict[str, Dict[str, Tuple[str, int]]] = {}
        # class name -> base class names (for inherited lock attrs)
        self.class_bases: Dict[str, List[str]] = {}
        # lock name -> declared rank
        self.hierarchy: Dict[str, int] = {}
        # (module rel, lock name) -> declaration line (for diagnostics)
        self.decl_sites: Dict[str, Tuple[str, int]] = {}
        for mod in self.modules:
            self._collect_declarations(mod)

    # ---------------------------------------------------------- declarations
    def _collect_declarations(self, mod: SourceModule) -> None:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef):
                self.class_bases[node.name] = [
                    b.id if isinstance(b, ast.Name) else
                    b.attr if isinstance(b, ast.Attribute) else ""
                    for b in node.bases]
                for deco in node.decorator_list:
                    decl = self._parse_declares_lock(deco)
                    if decl is None:
                        continue
                    name, rank, attrs = decl
                    amap = self.class_lock_attrs.setdefault(node.name, {})
                    for attr in attrs:
                        amap[attr] = (name, rank)
                    self._note_rank(mod, name, rank, deco.lineno)
            elif isinstance(node, ast.Call):
                fn = call_name(node)
                if fn in ("named_lock", "named_condition"):
                    name = const_str(node.args[0]) if node.args else None
                    rank = kw_int(node, "rank")
                    if name is not None and rank is not None:
                        self._note_rank(mod, name, rank, node.lineno)

    def _note_rank(self, mod: SourceModule, name: str, rank: int,
                   line: int) -> None:
        self.hierarchy.setdefault(name, rank)
        self.decl_sites.setdefault(name, (mod.rel, line))

    @staticmethod
    def _parse_declares_lock(deco: ast.expr
                             ) -> Optional[Tuple[str, int, List[str]]]:
        if not isinstance(deco, ast.Call) or \
                call_name(deco) != "declares_lock":
            return None
        name = const_str(deco.args[0]) if deco.args else None
        rank = kw_int(deco, "rank")
        attrs: List[str] = []
        for kw in deco.keywords:
            if kw.arg == "attrs" and isinstance(kw.value,
                                                (ast.Tuple, ast.List)):
                attrs = [e.value for e in kw.value.elts
                         if isinstance(e, ast.Constant)
                         and isinstance(e.value, str)]
        if name is None or rank is None:
            return None
        return name, rank, attrs

    # -------------------------------------------------------------- lookups
    def lock_attrs_for_class(self, cls: str) -> Dict[str,
                                                     Tuple[str, int]]:
        """Declared lock attrs of ``cls`` including inherited ones."""
        out: Dict[str, Tuple[str, int]] = {}
        seen: Set[str] = set()
        stack = [cls]
        while stack:
            c = stack.pop()
            if c in seen:
                continue
            seen.add(c)
            for attr, decl in self.class_lock_attrs.get(c, {}).items():
                out.setdefault(attr, decl)
            stack.extend(self.class_bases.get(c, ()))
        return out


class Rule:
    """Base class: subclasses set ``id``/``summary`` and yield findings."""

    id: str = ""
    summary: str = ""

    def check(self, module: SourceModule,
              project: Project) -> Iterator[Finding]:
        return iter(())

    def finalize(self, project: Project) -> Iterator[Finding]:
        """Cross-module pass after every module was checked."""
        return iter(())


# --------------------------------------------------------------- AST helpers
def call_name(node: ast.Call) -> str:
    """Last path component of the called function (``a.b.f(...)`` -> f)."""
    f = node.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return ""


def dotted(node: ast.expr) -> str:
    """Best-effort dotted name for Name/Attribute chains, else ''."""
    parts: List[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
    elif parts:
        parts.append("")  # unknown base (call result, subscript, ...)
    return ".".join(reversed(parts))


def const_str(node: ast.expr) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def kw_int(node: ast.Call, name: str) -> Optional[int]:
    for kw in node.keywords:
        if kw.arg == name and isinstance(kw.value, ast.Constant) \
                and isinstance(kw.value.value, int):
            return kw.value.value
    return None


def enclosing_class(node: ast.AST) -> Optional[ast.ClassDef]:
    cur = getattr(node, "parent", None)
    while cur is not None:
        if isinstance(cur, ast.ClassDef):
            return cur
        cur = getattr(cur, "parent", None)
    return None


def enclosing_function(node: ast.AST
                       ) -> Optional[ast.FunctionDef]:
    cur = getattr(node, "parent", None)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return cur
        cur = getattr(cur, "parent", None)
    return None


# ------------------------------------------------------------------- running
def iter_python_files(paths: Sequence[str],
                      include_analysis: bool = False) -> Iterator[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in ("__pycache__", ".git",
                                              ".pytest_cache"))
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    yield os.path.join(dirpath, fn)


def _is_analysis_module(rel: str) -> bool:
    rel = rel.replace(os.sep, "/")
    return "repro/analysis/" in rel or rel.startswith("analysis/")


def load_modules(paths: Sequence[str], *, root: Optional[str] = None,
                 include_analysis: bool = False
                 ) -> Tuple[List[SourceModule], List[Finding]]:
    """Parse every .py under ``paths``; unparseable files become findings
    (a syntax error must fail the gate, not silently shrink coverage)."""
    root = root or os.getcwd()
    modules: List[SourceModule] = []
    errors: List[Finding] = []
    for path in iter_python_files(paths):
        rel = os.path.relpath(path, root)
        if not include_analysis and _is_analysis_module(rel):
            continue  # the linter does not lint itself
        try:
            with open(path, "r", encoding="utf-8") as f:
                source = f.read()
            modules.append(SourceModule(path, rel, source))
        except (SyntaxError, UnicodeDecodeError, OSError) as exc:
            errors.append(Finding(
                rule="CKPT000", path=rel, line=getattr(exc, "lineno", 1)
                or 1, col=0, message=f"unparseable file: {exc}"))
    return modules, errors


def all_rules() -> List[Rule]:
    from . import (lockorder, rules_blocking, rules_commit,
                   rules_hygiene, rules_snapshot)
    rules: List[Rule] = []
    for mod in (lockorder, rules_blocking, rules_commit,
                rules_snapshot, rules_hygiene):
        rules.extend(mod.RULES())
    return rules


def run(paths: Sequence[str], *, root: Optional[str] = None,
        select: Optional[Iterable[str]] = None,
        include_analysis: bool = False
        ) -> Tuple[List[Finding], List[Finding]]:
    """Analyze ``paths``; returns (active findings, suppressed findings),
    both sorted by location."""
    modules, errors = load_modules(paths, root=root,
                                   include_analysis=include_analysis)
    project = Project(modules)
    rules = all_rules()
    if select is not None:
        wanted = {s.upper() for s in select}
        rules = [r for r in rules
                 if r.id.upper() in wanted
                 or any(r.id.upper().startswith(w) for w in wanted)]
    raw: List[Finding] = list(errors)
    for rule in rules:
        for mod in modules:
            raw.extend(rule.check(mod, project))
        raw.extend(rule.finalize(project))
    by_rel = {m.rel: m for m in modules}
    active: List[Finding] = []
    suppressed: List[Finding] = []
    for f in raw:
        mod = by_rel.get(f.path)
        if mod is not None and mod.is_suppressed(f.rule, f.line):
            suppressed.append(dataclasses.replace(f, suppressed=True))
        else:
            active.append(f)
    key = lambda f: (f.path, f.line, f.col, f.rule)  # noqa: E731
    return sorted(active, key=key), sorted(suppressed, key=key)
