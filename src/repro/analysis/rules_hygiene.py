"""API-hygiene rules (CKPT5xx).

The public surface is ``CheckpointPolicy`` + the ``StateProviderRegistry``
(PR 5); internal code must not re-grow calls into the deprecated flat
kwargs or hand-build stock providers outside the routing layer, or the
policy/provider composition stops being the single source of truth.
"""

from __future__ import annotations

import ast
from typing import Iterator, List

from .linter import Finding, Project, Rule, SourceModule, call_name

#: flat CheckpointManager kwargs deprecated by CheckpointPolicy (PR 5)
LEGACY_KWARGS = {
    "mode", "host_cache_bytes", "flush_threads", "chunk_bytes",
    "throttle_mbps", "restore_threads", "tiers", "retention",
    "manifest_checksums", "world", "coordinator", "ack_timeout_s",
    "delta",
}

#: stock provider classes whose construction is routed by the registry
STOCK_PROVIDERS = {
    "TensorStateProvider", "ObjectStateProvider", "DeltaStateProvider",
    "QuantizedStateProvider", "CompositeStateProvider",
}
#: modules that ARE the routing/definition layer (may construct freely)
SANCTIONED_PROVIDER_MODULES = (
    "core/state_provider.py", "core/registry.py", "core/baselines.py",
)


class LegacyKwargsRule(Rule):
    id = "CKPT501"
    summary = ("CheckpointManager called with deprecated flat kwargs; "
               "compose a CheckpointPolicy instead")

    def check(self, module: SourceModule,
              project: Project) -> Iterator[Finding]:
        findings: List[Finding] = []
        for node in ast.walk(module.tree):
            if not (isinstance(node, ast.Call)
                    and call_name(node) == "CheckpointManager"):
                continue
            bad = sorted(kw.arg for kw in node.keywords
                         if kw.arg in LEGACY_KWARGS)
            if bad:
                findings.append(Finding(
                    rule=self.id, path=module.rel, line=node.lineno,
                    col=node.col_offset,
                    message=(f"deprecated legacy kwargs "
                             f"{', '.join(bad)}; use "
                             f"CheckpointManager.from_policy("
                             f"directory, CheckpointPolicy(...))")))
        return iter(findings)


class ProviderBypassRule(Rule):
    id = "CKPT502"
    summary = ("stock provider constructed outside the registry routing "
               "layer; use StateProviderRegistry / providers_for_state")

    def check(self, module: SourceModule,
              project: Project) -> Iterator[Finding]:
        if module.rel.endswith(SANCTIONED_PROVIDER_MODULES):
            return iter(())
        findings: List[Finding] = []
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Call) and \
                    call_name(node) in STOCK_PROVIDERS:
                findings.append(Finding(
                    rule=self.id, path=module.rel, line=node.lineno,
                    col=node.col_offset,
                    message=(f"{call_name(node)}(...) bypasses "
                             f"StateProviderRegistry routing; resolve "
                             f"providers through the registry")))
        return iter(findings)


class DeprecatedReducerRule(Rule):
    id = "CKPT503"
    summary = ("reference to deprecated DifferentialCheckpointer outside "
               "its home module; use delta providers via the engine path")

    def check(self, module: SourceModule,
              project: Project) -> Iterator[Finding]:
        if module.rel.endswith("core/reduction.py"):
            return iter(())
        findings: List[Finding] = []
        for node in ast.walk(module.tree):
            name = None
            if isinstance(node, ast.Name) and \
                    node.id == "DifferentialCheckpointer":
                name = node.id
            elif isinstance(node, ast.Attribute) and \
                    node.attr == "DifferentialCheckpointer":
                name = node.attr
            elif isinstance(node, ast.ImportFrom) and any(
                    a.name == "DifferentialCheckpointer"
                    for a in node.names):
                name = "DifferentialCheckpointer"
            if name is not None:
                findings.append(Finding(
                    rule=self.id, path=module.rel, line=node.lineno,
                    col=node.col_offset,
                    message=("DifferentialCheckpointer is deprecated; "
                             "use DeltaStateProvider through the "
                             "engine delta path")))
        return iter(findings)


def RULES() -> List[Rule]:
    return [LegacyKwargsRule(), ProviderBypassRule(),
            DeprecatedReducerRule()]
