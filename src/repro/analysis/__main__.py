"""CLI: ``python -m repro.analysis [paths...]``.

Exit status 0 when no active findings, 1 when violations remain, 2 on
usage errors. ``--format json`` emits machine-readable findings (the CI
gate archives this as an artifact).
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from . import linter


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description=("ckptlint: enforce the checkpoint engine's "
                     "concurrency and commit-protocol invariants"))
    parser.add_argument("paths", nargs="*", default=["src"],
                        help="files or directories to analyze "
                             "(default: src)")
    parser.add_argument("--select", action="append", default=None,
                        metavar="RULE",
                        help="run only these rules / rule prefixes "
                             "(e.g. CKPT1, CKPT301); repeatable")
    parser.add_argument("--format", choices=("text", "json"),
                        default="text")
    parser.add_argument("--show-suppressed", action="store_true",
                        help="also print findings silenced by "
                             "'# ckptlint: disable=...' comments")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule catalog and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in linter.all_rules():
            print(f"{rule.id}: {rule.summary}")
        return 0

    paths = args.paths or ["src"]
    active, suppressed = linter.run(paths, select=args.select)

    if args.format == "json":
        payload = {
            "findings": [vars(f) for f in active],
            "suppressed": [vars(f) for f in suppressed],
        }
        print(json.dumps(payload, indent=2, sort_keys=True))
    else:
        for f in active:
            print(f.format())
        if args.show_suppressed:
            for f in suppressed:
                print(f.format())
        tail = f"{len(active)} finding(s)"
        if suppressed:
            tail += f", {len(suppressed)} suppressed"
        print(f"ckptlint: {tail}", file=sys.stderr)

    return 1 if active else 0


if __name__ == "__main__":
    sys.exit(main())
