"""Lock-order rules (CKPT1xx) and the shared held-lock scope walker.

The hierarchy itself is *declared in the code* via
:func:`repro.analysis.locks.declares_lock` / ``named_lock`` call sites;
this module extracts nothing from a config file. The walker computes, for
every statement, the set of locks lexically held (``with`` scopes plus
bare ``acquire()``), resolving lock expressions through three stages:

1. ``self.<attr>`` against the enclosing class's declared lock attrs
   (inheritance-merged) — the precise path;
2. local names bound by ``named_lock(...)`` / ``named_condition(...)``;
3. a project-native table of *acquiring methods* — calls such as
   ``host_cache.reserve(...)`` or ``barrier.wait(...)`` that take a known
   lock internally, so cross-object acquisition edges are visible without
   interprocedural analysis.

Rules:

- **CKPT101** out-of-order acquisition: acquiring a lock whose declared
  rank is not strictly greater than every held rank.
- **CKPT102** lock-graph cycle: the project-wide acquisition graph
  (nesting edges from every file) must be acyclic.
- **CKPT103** undeclared lock: a raw ``threading.Lock/RLock/Condition``
  constructed in a hierarchy-scoped module without a ``declares_lock`` /
  ``named_lock`` declaration.
- **CKPT104** bare ``acquire()`` without a ``try/finally`` ``release()``.
"""

from __future__ import annotations

import ast
from typing import Callable, Dict, Iterator, List, Optional, Set, Tuple

from .linter import (Finding, Project, Rule, SourceModule, call_name,
                     const_str, dotted, enclosing_class, kw_int)

# Modules that must declare every lock they construct (CKPT103). Any
# module that already contains a declaration is also in scope.
SCOPED_SUFFIXES = (
    "core/engine.py", "core/host_cache.py", "core/layout.py",
    "core/state_provider.py", "core/checkpoint.py", "dist/barrier.py",
    "dist/coordinator.py", "storage/repository.py",
)

#: method name -> (lock it acquires internally, receiver last-name guard).
#: A ``None`` guard accepts any receiver; otherwise the receiver's last
#: dotted component must be in the set (so ``event.wait()`` is not
#: mistaken for a barrier wait).
ACQUIRING_METHODS: Dict[str, Tuple[str, Optional[Set[str]]]] = {
    "reserve": ("host_cache.alloc",
                {"host_cache", "_cache", "cache", "hc"}),
    "wait": ("barrier.cond", {"barrier", "_barrier"}),
    "wait_generation": ("barrier.cond", {"barrier", "_barrier"}),
    "poison": ("barrier.cond", {"barrier", "_barrier"}),
    "reset": ("barrier.cond", {"barrier", "_barrier"}),
    "append_object": ("writer.append", None),
    "append_encoded_chunk": ("writer.append", None),
    "declare_encoded_tensor": ("writer.append", None),
    "op_started": ("engine.file_state", None),
    "op_finished": ("engine.file_state", None),
    "producer_finished": ("engine.file_state", None),
    "begin_step": ("repository.state", None),
    "commit_step": ("repository.state", None),
    "abort_step": ("repository.state", None),
}


def receiver_of(call: ast.Call) -> Optional[ast.expr]:
    if isinstance(call.func, ast.Attribute):
        return call.func.value
    return None


def receiver_lastname(call: ast.Call) -> str:
    recv = receiver_of(call)
    if recv is None:
        return ""
    d = dotted(recv)
    return d.rsplit(".", 1)[-1] if d else ""


class FunctionCtx:
    """Lock-resolution context for one function body."""

    def __init__(self, module: SourceModule, project: Project,
                 fn: ast.AST):
        self.module = module
        self.project = project
        cls = enclosing_class(fn)
        self.attr_locks: Dict[str, Tuple[str, int]] = (
            project.lock_attrs_for_class(cls.name) if cls else {})
        # local name -> (lock name, rank) from named_lock assignments
        self.local_locks: Dict[str, Tuple[str, int]] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call) and \
                    call_name(node.value) in ("named_lock",
                                              "named_condition"):
                name = const_str(node.value.args[0]) \
                    if node.value.args else None
                rank = kw_int(node.value, "rank")
                if name is None or rank is None:
                    continue
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        self.local_locks[tgt.id] = (name, rank)

    def resolve(self, expr: ast.expr) -> Optional[Tuple[str, int]]:
        """Lock (name, rank) for an expression naming a lock, else None."""
        if isinstance(expr, ast.Attribute) and \
                isinstance(expr.value, ast.Name) and \
                expr.value.id in ("self", "cls"):
            return self.attr_locks.get(expr.attr)
        if isinstance(expr, ast.Name):
            return self.local_locks.get(expr.id)
        return None

    def resolve_acquiring_call(self, call: ast.Call
                               ) -> Optional[Tuple[str, int]]:
        """Lock acquired *inside* ``call``, if the call is an acquiring
        method (directly on a lock, or via the project table)."""
        fn = call_name(call)
        recv = receiver_of(call)
        if fn in ("acquire", "__enter__") and recv is not None:
            return self.resolve(recv)
        if recv is not None:
            hit = self.resolve(recv)
            if hit is not None and fn in ("wait", "wait_for", "notify",
                                          "notify_all"):
                # condition built over a declared lock: alias of it
                return hit
        entry = ACQUIRING_METHODS.get(fn)
        if entry is None:
            return None
        name, guard = entry
        if guard is not None and receiver_lastname(call) not in guard:
            return None
        rank = self.project.hierarchy.get(name)
        if rank is None:
            return None
        return name, rank


class HeldScopeWalker:
    """Drives callbacks with the lexically-held lock stack.

    ``on_acquire(name, rank, node, held)`` fires at every resolved
    acquisition (``with`` item, bare ``acquire()``, acquiring call);
    ``on_call(call, held, ctx)`` fires for every other call while at
    least one lock is held. Nested ``def``/``lambda`` bodies run on their
    own threads-of-control, so they restart with an empty held stack.
    """

    def __init__(self, module: SourceModule, project: Project,
                 on_acquire: Optional[Callable[..., None]] = None,
                 on_call: Optional[Callable[..., None]] = None):
        self.module = module
        self.project = project
        self.on_acquire = on_acquire or (lambda *a: None)
        self.on_call = on_call or (lambda *a: None)

    def walk(self) -> None:
        self._walk_body(self.module.tree.body, None, [])

    # ------------------------------------------------------------ internals
    def _walk_body(self, stmts: List[ast.stmt],
                   ctx: Optional[FunctionCtx],
                   held: List[Tuple[str, int]]) -> None:
        for stmt in stmts:
            self._walk_stmt(stmt, ctx, held)

    def _walk_stmt(self, stmt: ast.stmt, ctx: Optional[FunctionCtx],
                   held: List[Tuple[str, int]]) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            sub = FunctionCtx(self.module, self.project, stmt)
            self._walk_body(stmt.body, sub, [])  # fresh thread of control
            return
        if isinstance(stmt, ast.ClassDef):
            self._walk_body(stmt.body, ctx, [])
            return
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            pushed = 0
            for item in stmt.items:
                self._scan_expr(item.context_expr, ctx, held)
                hit = ctx.resolve(item.context_expr) if ctx else None
                if hit is None and isinstance(item.context_expr,
                                              ast.Call) and ctx:
                    hit = ctx.resolve_acquiring_call(item.context_expr)
                if hit is not None:
                    self.on_acquire(hit[0], hit[1], item.context_expr,
                                    list(held))
                    held.append(hit)
                    pushed += 1
            self._walk_body(stmt.body, ctx, held)
            for _ in range(pushed):
                held.pop()
            return
        for field in ast.iter_fields(stmt):
            _name, value = field
            for part in (value if isinstance(value, list) else [value]):
                if isinstance(part, ast.stmt):
                    self._walk_stmt(part, ctx, held)
                elif isinstance(part, ast.expr):
                    self._scan_expr(part, ctx, held)
                elif isinstance(part, ast.excepthandler):
                    self._walk_body(part.body, ctx, held)

    def _scan_expr(self, expr: ast.expr, ctx: Optional[FunctionCtx],
                   held: List[Tuple[str, int]]) -> None:
        for node in ast.walk(expr):
            if isinstance(node, (ast.Lambda,)):
                continue  # deferred body: not this thread of control, and
                # lambdas in this codebase never take locks
            if not isinstance(node, ast.Call) or ctx is None:
                continue
            hit = ctx.resolve_acquiring_call(node)
            if hit is not None:
                self.on_acquire(hit[0], hit[1], node, list(held))
            elif held:
                self.on_call(node, list(held), ctx)


class LockOrderRule(Rule):
    id = "CKPT101"
    summary = ("lock acquired out of declared rank order "
               "(risk of ABBA deadlock)")

    def __init__(self) -> None:
        # (outer, inner) -> first site, shared with the cycle rule
        self.edges: Dict[Tuple[str, str], Tuple[str, int]] = {}
        self._findings: List[Finding] = []

    def check(self, module: SourceModule,
              project: Project) -> Iterator[Finding]:
        findings: List[Finding] = []

        def on_acquire(name: str, rank: int, node: ast.AST,
                       held: List[Tuple[str, int]]) -> None:
            if not held:
                return
            if any(name == h for h, _r in held):
                return  # reentrant / alias of an already-held lock
            top_name, _ = held[-1]
            self.edges.setdefault((top_name, name),
                                  (module.rel, node.lineno))
            worst = max(r for _h, r in held)
            if rank <= worst:
                chain = " -> ".join(f"{h}(r{r})" for h, r in held)
                findings.append(Finding(
                    rule=self.id, path=module.rel, line=node.lineno,
                    col=node.col_offset,
                    message=(f"acquires {name}(r{rank}) while holding "
                             f"[{chain}]; ranks must strictly increase "
                             f"inward")))

        HeldScopeWalker(module, project, on_acquire=on_acquire).walk()
        return iter(findings)


class LockCycleRule(Rule):
    id = "CKPT102"
    summary = "cycle in the project-wide lock-acquisition graph"

    def __init__(self, order_rule: LockOrderRule):
        self._order = order_rule

    def finalize(self, project: Project) -> Iterator[Finding]:
        graph: Dict[str, Set[str]] = {}
        for (a, b) in self._order.edges:
            graph.setdefault(a, set()).add(b)
        seen: Set[str] = set()
        reported: Set[frozenset] = set()
        findings: List[Finding] = []

        def dfs(node: str, path: List[str]) -> None:
            if node in path:
                cycle = path[path.index(node):] + [node]
                key = frozenset(cycle)
                if key not in reported:
                    reported.add(key)
                    edge = (cycle[0], cycle[1])
                    rel, line = self._order.edges.get(
                        edge, ("<unknown>", 1))
                    findings.append(Finding(
                        rule=self.id, path=rel, line=line, col=0,
                        message=("lock-acquisition cycle: "
                                 + " -> ".join(cycle))))
                return
            if node in seen:
                return
            seen.add(node)
            for nxt in sorted(graph.get(node, ())):
                dfs(nxt, path + [node])
            # allow other entry points to re-explore through this node
            # only via the `node in path` cycle check above

        for start in sorted(graph):
            dfs(start, [])
        return iter(findings)


class UndeclaredLockRule(Rule):
    id = "CKPT103"
    summary = ("raw threading lock in a hierarchy-scoped module without "
               "a declares_lock/named_lock declaration")

    _CTORS = ("Lock", "RLock", "Condition")

    def _in_scope(self, module: SourceModule) -> bool:
        if module.rel.endswith(SCOPED_SUFFIXES):
            return True
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Call) and call_name(node) in (
                    "declares_lock", "named_lock", "named_condition"):
                return True
        return False

    def check(self, module: SourceModule,
              project: Project) -> Iterator[Finding]:
        if not self._in_scope(module):
            return iter(())
        findings: List[Finding] = []
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Assign):
                continue
            val = node.value
            if not (isinstance(val, ast.Call)
                    and call_name(val) in self._CTORS):
                continue
            d = dotted(val.func)
            if d and "." in d and not d.startswith("threading."):
                continue  # some other module's Lock/Condition
            for tgt in node.targets:
                if isinstance(tgt, ast.Attribute) and \
                        isinstance(tgt.value, ast.Name) and \
                        tgt.value.id == "self":
                    cls = enclosing_class(node)
                    declared = project.lock_attrs_for_class(
                        cls.name) if cls else {}
                    if tgt.attr not in declared:
                        findings.append(Finding(
                            rule=self.id, path=module.rel,
                            line=node.lineno, col=node.col_offset,
                            message=(f"self.{tgt.attr} = threading."
                                     f"{call_name(val)}() has no "
                                     f"declares_lock(...) covering "
                                     f"attr {tgt.attr!r}")))
                elif isinstance(tgt, ast.Name):
                    findings.append(Finding(
                        rule=self.id, path=module.rel, line=node.lineno,
                        col=node.col_offset,
                        message=(f"local lock {tgt.id!r} should be "
                                 f"created via named_lock(name, rank=N) "
                                 f"so it joins the declared hierarchy")))
        return iter(findings)


class BareAcquireRule(Rule):
    id = "CKPT104"
    summary = "bare acquire() without a try/finally release()"

    def check(self, module: SourceModule,
              project: Project) -> Iterator[Finding]:
        findings: List[Finding] = []
        for node in ast.walk(module.tree):
            if not (isinstance(node, ast.Expr)
                    and isinstance(node.value, ast.Call)
                    and call_name(node.value) == "acquire"):
                continue
            call = node.value
            recv = receiver_of(call)
            if recv is None:
                continue
            fn = None
            cur = getattr(node, "parent", None)
            while cur is not None and fn is None:
                if isinstance(cur, (ast.FunctionDef,
                                    ast.AsyncFunctionDef)):
                    fn = cur
                cur = getattr(cur, "parent", None)
            if fn is None:
                continue
            ctx = FunctionCtx(module, project, fn)
            if ctx.resolve(recv) is None:
                continue  # not a declared lock (e.g. a semaphore)
            recv_src = dotted(recv)
            if self._released_in_finally(node, recv_src):
                continue
            findings.append(Finding(
                rule=self.id, path=module.rel, line=node.lineno,
                col=node.col_offset,
                message=(f"{recv_src}.acquire() has no try/finally "
                         f"{recv_src}.release(); prefer `with`")))
        return iter(findings)

    @staticmethod
    def _released_in_finally(node: ast.AST, recv_src: str) -> bool:
        cur = getattr(node, "parent", None)
        while cur is not None:
            if isinstance(cur, ast.Try):
                for stmt in ast.walk(ast.Module(body=cur.finalbody,
                                                type_ignores=[])):
                    if isinstance(stmt, ast.Call) and \
                            call_name(stmt) == "release" and \
                            isinstance(stmt.func, ast.Attribute) and \
                            dotted(stmt.func.value) == recv_src:
                        return True
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                break
            cur = getattr(cur, "parent", None)
        return False


def RULES() -> List[Rule]:
    order = LockOrderRule()
    return [order, LockCycleRule(order), UndeclaredLockRule(),
            BareAcquireRule()]
