"""Inline lock-hierarchy declarations (the source of truth ``ckptlint``
and the runtime witness both consume).

Every lock that participates in the committer / cascade / rank lanes is
declared *next to the code it governs* with :func:`declares_lock` (class
attributes) or :func:`named_lock` (locals/closures). A declaration names
the lock and assigns it a **rank**: a thread may only acquire a lock whose
rank is *strictly greater* than every lock it already holds, so the
acquisition order over the whole system is a DAG by construction.

The declared hierarchy, outermost (lowest rank) to innermost:

======  =====================  ==========================================
rank    lock                   owner
======  =====================  ==========================================
10      coordinator.job        ``dist.coordinator._SaveJob.lock``
12      coordinator.dead       ``dist.coordinator.Coordinator._dead_lock``
15      coordinator.node       ``dist.coordinator._NodeCommit.lock``
16      ipc.proc               ``dist.process_runtime.ProcessRankRuntime._lock``
20      barrier.cond           ``dist.barrier.CollectiveBarrier._cond``
30      manager.delta_tracker  ``core.checkpoint._DeltaChainTracker._lock``
40      repository.state       ``storage.repository.CheckpointRepository._lock``
42      fleet.fabric           ``fleet.fabric.FleetFabric._lock``
44      fleet.cache            ``fleet.cache.FleetCache._lock``
46      fleet.exchange         ``fleet.peer.PeerExchange._lock``
48      fleet.session          ``fleet.peer._SwapSession._cond``
50      engine.save_progress   per-save closure lock in ``DataMovementEngine.submit``
52      engine.file_state      ``core.engine._FileState.lock``
54      snapshot.cache         ``core.state_provider.SnapshotCache._lock``
56      encode.budget          ``core.state_provider.EncodeBudget._cond``
58      provider.stage         ``core.state_provider.TensorStateProvider._cond``
60      writer.append          ``core.layout.FileWriter._append_lock``
70      host_cache.alloc       ``core.host_cache.HostCache._lock`` / ``._freed``
======  =====================  ==========================================

This module is stdlib-only and imported by the concurrency-bearing runtime
modules; it must never import anything heavy (numpy/jax) or anything from
``repro`` outside :mod:`repro.analysis`.
"""

from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Any, Callable, Dict, Optional, Tuple

__all__ = ["LockDecl", "LOCK_REGISTRY", "declared_hierarchy",
           "declares_lock", "named_lock", "named_condition"]


@dataclasses.dataclass(frozen=True)
class LockDecl:
    """One declared lock: its global name, rank, and where it lives."""

    name: str
    rank: int
    attrs: Tuple[str, ...]   # instance attributes materializing this lock
    owner: str               # "module.QualName" of the declaring class


#: "module.QualName" -> LockDecl for every class-level declaration, plus
#: "<name>" entries for named_lock/named_condition call sites.
LOCK_REGISTRY: Dict[str, LockDecl] = {}


def declared_hierarchy() -> Dict[str, int]:
    """Lock name -> rank for every declaration registered at import time."""
    out: Dict[str, int] = {}
    for decl in LOCK_REGISTRY.values():
        prev = out.setdefault(decl.name, decl.rank)
        if prev != decl.rank:
            raise ValueError(
                f"lock {decl.name!r} declared with conflicting ranks "
                f"{prev} and {decl.rank}")
    return out


def _register(decl: LockDecl) -> None:
    existing = LOCK_REGISTRY.get(decl.owner)
    if existing is not None and existing != decl:
        raise ValueError(
            f"{decl.owner}: conflicting lock declarations "
            f"{existing} vs {decl}")
    LOCK_REGISTRY[decl.owner] = decl
    # surface rank conflicts at declaration time, not first use
    declared_hierarchy()


def _maybe_wrap(name: str, rank: int, obj: Any) -> Any:
    """Instrument ``obj`` when a witness is recording (no-op otherwise)."""
    from . import witness  # deferred: avoid cycles at import time
    w = witness.current()
    if w is None or isinstance(obj, witness.WitnessLock):
        return obj
    return witness.WitnessLock(name, rank, obj, w)


def declares_lock(name: str, *, rank: int,
                  attrs: Tuple[str, ...]) -> Callable[[type], type]:
    """Class decorator declaring that instances own the lock ``name``.

    ``attrs`` lists every instance attribute that materializes the lock —
    the ``threading.Lock`` itself plus any ``Condition`` built over it
    (aliases of one lock share its name and rank, so waiting on your own
    condition variable is never a hierarchy violation).

    Zero runtime cost unless a :mod:`repro.analysis.witness` recording is
    active, in which case the declared attributes are replaced with
    recording proxies after ``__init__`` returns.
    """
    attrs = tuple(attrs)

    def deco(cls: type) -> type:
        decl = LockDecl(name=name, rank=rank, attrs=attrs,
                        owner=f"{cls.__module__}.{cls.__qualname__}")
        _register(decl)
        cls.__ckpt_lock_decl__ = decl  # type: ignore[attr-defined]
        orig_init = cls.__init__

        @functools.wraps(orig_init)
        def __init__(self, *a: Any, **k: Any) -> None:
            orig_init(self, *a, **k)
            from . import witness
            if witness.current() is None:
                return
            for attr in attrs:
                obj = getattr(self, attr, None)
                if obj is not None:
                    setattr(self, attr, _maybe_wrap(name, rank, obj))

        cls.__init__ = __init__  # type: ignore[assignment]
        return cls

    return deco


def named_lock(name: str, *, rank: int) -> Any:
    """A declared ``threading.Lock`` for locals/closures a class decorator
    cannot reach (e.g. the per-save aggregation lock in
    ``DataMovementEngine.submit``)."""
    _register(LockDecl(name=name, rank=rank, attrs=(), owner=name))
    return _maybe_wrap(name, rank, threading.Lock())


def named_condition(name: str, *, rank: int,
                    lock: Optional[Any] = None) -> Any:
    """A declared ``threading.Condition`` (over ``lock`` if given)."""
    _register(LockDecl(name=name, rank=rank, attrs=(), owner=name))
    return _maybe_wrap(name, rank, threading.Condition(lock))
