"""Commit-protocol rules (CKPT3xx).

The durable-state discipline (see README "Correctness tooling"): every
byte under the repository root — ``.catalog/`` entries and
``global_step*`` directories — is produced either by ``FileWriter``
(tensor shards, with ``abort()`` unlinking partials) or by the atomic
tmp-then-``os.replace`` helpers in ``storage/backend.py`` /
``storage/manifest.py``, and the ``StepManifest`` is always written
*last*. Raw ``open(..., "w")`` or bare ``os.rename``/``os.replace`` on
such paths can leave half-committed state that restore then trusts —
the dominant production failure mode this repo's fault suites replay.

Taint: a path expression is "repository-owned" when it derives from the
key/path helpers (``step_dir``, ``catalog_key``, ``_marker_path``, ...),
contains the ``.catalog``/``global_step`` markers, or flows from such a
value through local assignments (intra-function, flow-insensitive).
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Set

from .linter import (Finding, Project, Rule, SourceModule, call_name,
                     const_str, dotted)

#: modules allowed to do raw writes/renames on repository-owned paths —
#: they ARE the sanctioned atomic helpers.
SANCTIONED_WRITE_MODULES = (
    "storage/backend.py", "storage/manifest.py", "core/layout.py",
)
#: modules allowed to construct FileWriter directly (the engine's flush
#: lane and the shard consolidator, both of which abort() on failure).
SANCTIONED_WRITER_MODULES = (
    "core/layout.py", "core/engine.py", "core/consolidate.py",
)

_PATH_HELPERS = {
    "step_dir", "step_dirname", "catalog_key", "data_key", "entry_name",
    "marker_name", "rank_file", "_entry_path", "_marker_path",
    "_catalog_path", "_step_path",
}
_TAINT_MARKERS = (".catalog", "global_step")
_TAINT_NAMES = {"sdir", "staging", "step_path", "marker_path"}


def _function_taint(fn: ast.AST) -> Set[str]:
    """Names in ``fn`` bound (directly or transitively) to
    repository-owned paths."""
    tainted: Set[str] = set(_TAINT_NAMES)
    assigns: List[ast.Assign] = [n for n in ast.walk(fn)
                                 if isinstance(n, ast.Assign)]
    for _ in range(3):  # tiny fixpoint; chains here are short
        changed = False
        for node in assigns:
            if not _expr_tainted(node.value, tainted):
                continue
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) and tgt.id not in tainted:
                    tainted.add(tgt.id)
                    changed = True
        if not changed:
            break
    return tainted


def _expr_tainted(expr: ast.expr, tainted: Set[str]) -> bool:
    for node in ast.walk(expr):
        if isinstance(node, ast.Name) and node.id in tainted:
            return True
        if isinstance(node, ast.Constant) and \
                isinstance(node.value, str) and \
                any(m in node.value for m in _TAINT_MARKERS):
            return True
        if isinstance(node, ast.Call) and \
                call_name(node) in _PATH_HELPERS:
            return True
        if isinstance(node, ast.Attribute):
            d = dotted(node)
            if "catalog" in d or d.endswith(".directory"):
                return True
    return False


def _enclosing_fn(node: ast.AST) -> ast.AST:
    cur = getattr(node, "parent", None)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return cur
        cur = getattr(cur, "parent", None)
    return node  # module scope


class RawWriteRule(Rule):
    id = "CKPT301"
    summary = ("raw open(..., 'w') on a repository-owned path; use the "
               "atomic helpers (backend.put / StepManifest / FileWriter)")

    def check(self, module: SourceModule,
              project: Project) -> Iterator[Finding]:
        if module.rel.endswith(SANCTIONED_WRITE_MODULES):
            return iter(())
        findings: List[Finding] = []
        taint_cache: Dict[int, Set[str]] = {}
        for node in ast.walk(module.tree):
            if not (isinstance(node, ast.Call)
                    and call_name(node) == "open"
                    and isinstance(node.func, ast.Name)):
                continue
            mode = ""
            if len(node.args) > 1:
                mode = const_str(node.args[1]) or ""
            for kw in node.keywords:
                if kw.arg == "mode":
                    mode = const_str(kw.value) or ""
            if not any(c in mode for c in "wax+"):
                continue
            if not node.args:
                continue
            fn = _enclosing_fn(node)
            tainted = taint_cache.setdefault(id(fn), _function_taint(fn))
            if _expr_tainted(node.args[0], tainted):
                findings.append(Finding(
                    rule=self.id, path=module.rel, line=node.lineno,
                    col=node.col_offset,
                    message=(f"raw open(..., {mode!r}) writes a "
                             f"repository-owned path; route through the "
                             f"atomic backend/manifest helpers")))
        return iter(findings)


class RawRenameRule(Rule):
    id = "CKPT302"
    summary = ("bare os.rename/os.replace on a repository-owned path "
               "outside the sanctioned helpers")

    def check(self, module: SourceModule,
              project: Project) -> Iterator[Finding]:
        if module.rel.endswith(SANCTIONED_WRITE_MODULES):
            return iter(())
        findings: List[Finding] = []
        taint_cache: Dict[int, Set[str]] = {}
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted(node.func)
            if d not in ("os.rename", "os.replace"):
                continue
            fn = _enclosing_fn(node)
            tainted = taint_cache.setdefault(id(fn), _function_taint(fn))
            if any(_expr_tainted(a, tainted) for a in node.args):
                findings.append(Finding(
                    rule=self.id, path=module.rel, line=node.lineno,
                    col=node.col_offset,
                    message=(f"{d} on a repository-owned path; commits "
                             f"must go through the manifest-last "
                             f"protocol helpers")))
        return iter(findings)


class WriterConstructionRule(Rule):
    id = "CKPT303"
    summary = ("FileWriter constructed outside the flush/consolidate "
               "lanes (abort-on-failure discipline not guaranteed)")

    def check(self, module: SourceModule,
              project: Project) -> Iterator[Finding]:
        if module.rel.endswith(SANCTIONED_WRITER_MODULES):
            return iter(())
        findings: List[Finding] = []
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Call) and \
                    call_name(node) == "FileWriter":
                findings.append(Finding(
                    rule=self.id, path=module.rel, line=node.lineno,
                    col=node.col_offset,
                    message=("FileWriter constructed outside the "
                             "sanctioned lanes; wrap in the engine "
                             "flush path or consolidator (both abort() "
                             "and unlink partials on failure)")))
        return iter(findings)


class FinalizeInExceptRule(Rule):
    id = "CKPT304"
    summary = ("finalize() inside an except handler — abort paths must "
               "unlink partials, not seal them")

    def check(self, module: SourceModule,
              project: Project) -> Iterator[Finding]:
        findings: List[Finding] = []
        for node in ast.walk(module.tree):
            if not (isinstance(node, ast.Call)
                    and call_name(node) == "finalize"):
                continue
            cur = getattr(node, "parent", None)
            inside_handler = False
            while cur is not None:
                if isinstance(cur, ast.ExceptHandler):
                    inside_handler = True
                    break
                if isinstance(cur, (ast.FunctionDef,
                                    ast.AsyncFunctionDef)):
                    break
                cur = getattr(cur, "parent", None)
            if inside_handler:
                findings.append(Finding(
                    rule=self.id, path=module.rel, line=node.lineno,
                    col=node.col_offset,
                    message=("finalize() called in an except handler; "
                             "error paths must abort() so partial "
                             "files are unlinked, never sealed")))
        return iter(findings)


def RULES() -> List[Rule]:
    return [RawWriteRule(), RawRenameRule(), WriterConstructionRule(),
            FinalizeInExceptRule()]
