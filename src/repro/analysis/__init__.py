"""``ckptlint``: project-native static analysis + runtime lock witness.

Static CLI: ``python -m repro.analysis [paths]`` (default ``src``).
Runtime: :mod:`repro.analysis.locks` declarations +
:mod:`repro.analysis.witness` recordings in the fault suites.
"""

from .linter import Finding, run
from .locks import LOCK_REGISTRY, declared_hierarchy, declares_lock, \
    named_condition, named_lock

__all__ = ["Finding", "run", "LOCK_REGISTRY", "declared_hierarchy",
           "declares_lock", "named_lock", "named_condition"]
