"""CKPT401: snapshot-immutability.

The paper's lazy async snapshot premise: once device state is captured
into a pinned host-cache reservation, those bytes are immutable until the
flush lane has drained them — any in-place mutation races the writer and
silently corrupts the checkpoint (no crash, wrong bytes on disk).

The rule taints every name bound to a ``reserve(...)`` result (or a
``.buf``/``.data``/``view()`` of one) and flags subscript stores or
augmented assignments through tainted names. Sanctioned lanes — the
capture path itself — are exempt: all of ``core/state_provider.py``
(providers own the capture protocol) and ``_stage_worker`` in
``core/engine.py`` (the D2H copy target).
"""

from __future__ import annotations

import ast
from typing import Iterator, List, Set

from .linter import Finding, Project, Rule, SourceModule, call_name, \
    dotted

SANCTIONED_MODULES = ("core/state_provider.py",)
SANCTIONED_FUNCTIONS = {"_stage_worker"}
_RESERVATION_ATTRS = ("buf", "data", "view", "memoryview")


def _reservation_taint(fn: ast.AST) -> Set[str]:
    tainted: Set[str] = set()
    assigns = [n for n in ast.walk(fn) if isinstance(n, ast.Assign)]
    for _ in range(3):
        changed = False
        for node in assigns:
            if not _value_tainted(node.value, tainted):
                continue
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) and tgt.id not in tainted:
                    tainted.add(tgt.id)
                    changed = True
        if not changed:
            break
    return tainted


def _value_tainted(expr: ast.expr, tainted: Set[str]) -> bool:
    for node in ast.walk(expr):
        if isinstance(node, ast.Call) and call_name(node) == "reserve":
            return True
        if isinstance(node, ast.Name) and node.id in tainted:
            return True
        if isinstance(node, ast.Attribute) and \
                node.attr in _RESERVATION_ATTRS:
            base = dotted(node.value)
            last = base.rsplit(".", 1)[-1] if base else ""
            if last in tainted or "reservation" in last.lower() or \
                    last in ("res", "rsv"):
                return True
    return False


def _base_name(expr: ast.expr) -> str:
    """Leftmost-ish name a subscript/attribute store goes through."""
    cur = expr
    while isinstance(cur, (ast.Subscript, ast.Attribute)):
        cur = cur.value
    if isinstance(cur, ast.Name):
        return cur.id
    return ""


class SnapshotMutationRule(Rule):
    id = "CKPT401"
    summary = ("in-place mutation of a pinned snapshot reservation "
               "outside the capture lane")

    def check(self, module: SourceModule,
              project: Project) -> Iterator[Finding]:
        if module.rel.endswith(SANCTIONED_MODULES):
            return iter(())
        findings: List[Finding] = []
        for fn in ast.walk(module.tree):
            if not isinstance(fn, (ast.FunctionDef,
                                   ast.AsyncFunctionDef)):
                continue
            if fn.name in SANCTIONED_FUNCTIONS:
                continue
            tainted = _reservation_taint(fn)
            if not tainted:
                continue
            for node in ast.walk(fn):
                if isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)) and \
                        node is not fn:
                    continue  # nested fns get their own taint pass
                targets: List[ast.expr] = []
                if isinstance(node, ast.Assign):
                    targets = [t for t in node.targets
                               if isinstance(t, ast.Subscript)]
                elif isinstance(node, ast.AugAssign) and \
                        isinstance(node.target, ast.Subscript):
                    targets = [node.target]
                for tgt in targets:
                    base = _base_name(tgt)
                    if base and base in tainted:
                        findings.append(Finding(
                            rule=self.id, path=module.rel,
                            line=node.lineno, col=node.col_offset,
                            message=(f"store into reservation-backed "
                                     f"buffer {base!r}; staged bytes "
                                     f"are immutable between capture "
                                     f"and flush")))
        return iter(findings)


def RULES() -> List[Rule]:
    return [SnapshotMutationRule()]
