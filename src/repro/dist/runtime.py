"""Rank-runtime interface shared by the thread and process backends.

A *rank runtime* is one writer rank of the coordinator's simulated world:
it owns a private engine + host-cache lane, drains the shard records
assigned to it, casts its phase-1 vote, and meets the ack collective
through the :class:`~repro.dist.coordinator._SaveJob` callbacks. Two
backends implement the interface:

* ``ThreadRankRuntime`` (``dist.coordinator``) — a thread in this
  process. Deterministic, cheap, and fault-injectable with closures:
  the test double every protocol test runs against.
* ``ProcessRankRuntime`` (``dist.process_runtime``) — a spawned child
  process per rank, the real isolation domain: a SIGKILL kills exactly
  one rank, the way a node loss would on a cluster.

This module holds the pieces both backends (and the child-side worker)
need without importing the coordinator, so ``worker.py`` can be imported
by a spawned child without dragging the whole protocol module in first.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional

from repro.core.baselines import DataStatesEngine, DataStatesOldEngine

#: Engine classes a rank lane may run. Coordinator ranks need a
#: DataMovementEngine-family engine (own host cache + flush lanes).
RANK_ENGINES = {
    "datastates": DataStatesEngine,
    "datastates-old": DataStatesOldEngine,
}


class BaseRankRuntime:
    """Interface every rank backend implements (see module docstring)."""

    rank: int
    world: int
    lane: str

    #: The thread backend exposes its engine's host cache for tests and
    #: benchmarks; process backends have no in-process cache to expose.
    host_cache: Optional[Any] = None

    def submit(self, job: Any, records: List[Any],
               objects: Dict[str, Any], delta: Optional[Any] = None) -> None:
        """Enqueue one save's partition for this rank (non-blocking)."""
        raise NotImplementedError

    def alive(self) -> bool:
        """False once the rank's execution domain is gone (process died).

        The coordinator polls this before partitioning a save so a rank
        that died *between* saves is evicted from the writer set without
        waiting for a watchdog timeout.
        """
        return True

    def drain(self) -> None:
        """Block until every submitted save has left this rank's queue."""
        raise NotImplementedError

    def close(self) -> None:
        """Tear the rank down (idempotent; never raises on a dead rank)."""
        raise NotImplementedError
