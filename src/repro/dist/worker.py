"""Child-process entry point for the process-per-rank runtime.

One spawned process per writer rank runs :func:`worker_main`: build this
rank's engine, report ``ready`` (with a ``perf_counter`` sample for the
parent's trace clock alignment), then serve ``save`` requests until
``close`` or pipe EOF. The *protocol brain* — barriers, node manifests,
watchdog, the aggregated future — stays in the parent; the child only
does the work a real rank would do locally: drain its shards through its
own engine, write its rank file, and cast its phase-1 vote. The ack is
the ``prepared`` reply itself — the parent-side proxy meets the
collective on the child's behalf.

Fault injection (:class:`~repro.dist.ipc.ProcessFaultSpec`) is fired
*here*, child-side, with ``os.kill(os.getpid(), SIGKILL)`` — uncatchable
and instant, exactly the failure mode a preempted node presents. The
``mid_file`` point tears the rank's own file first (``os.truncate`` to
half size) so the orphaned step carries real on-disk damage.

Module-top imports stay light (stdlib only): the spawn bootstrap imports
this module before the parent learns whether the child even started, and
the heavy stack (numpy/jax/engine) loads inside :func:`worker_main` where
failures are reportable over the pipe.
"""

from __future__ import annotations

import os
import signal
import time
import traceback
from typing import Any, Dict, List, Optional


def _fire_fault(fault: Any, point: str, rank: int, step: int,
                directory: str, filenames: List[str]) -> None:
    if fault is None or not fault.should_fire(point, rank, step):
        return
    if fault.action == "stall":
        time.sleep(fault.stall_s)
        return
    if point == "mid_file":
        # torn write: the file exists at a plausible-but-short size, as
        # if the node died with flush buffers in flight
        for name in filenames:
            path = os.path.join(directory, name)
            try:
                size = os.path.getsize(path)
                os.truncate(path, max(size // 2, 1))
            except OSError:
                pass
    os.kill(os.getpid(), signal.SIGKILL)


def worker_main(conn: Any, rank: int, world: int, mode: str,
                engine_kw: Dict[str, Any], checksum_files: bool,
                fault: Optional[Any] = None,
                jax_distributed: bool = False) -> None:
    """Serve one rank's saves over ``conn`` until close/EOF."""
    if jax_distributed:
        # multi-host deployments initialize the jax collective runtime so
        # device meshes span processes; the single-host simulation runs
        # without it, and an unconfigured coordinator must not be fatal
        try:
            import jax
            jax.distributed.initialize()
        except Exception:
            pass
    from repro.core.baselines import rank_file
    from repro.core.engine import CheckpointFuture
    from repro.dist.ipc import decode_record, encode_stats
    from repro.dist.runtime import RANK_ENGINES
    from repro.obs import trace as obs
    from repro.storage.manifest import RankManifest

    lane = f"rank{rank:05d}"
    engine = RANK_ENGINES[mode](label=lane, **engine_kw)
    conn.send(("ready", os.getpid(), time.perf_counter()))
    try:
        while True:
            try:
                msg = conn.recv()
            except EOFError:
                return
            if msg[0] == "close":
                return
            if msg[0] != "save":
                continue
            _, step, directory, payload, objects, delta, trace = msg
            tracer = obs.enable() if trace else None
            try:
                records = [decode_record(p) for p in payload]
                fut = CheckpointFuture(step, directory)
                engine.save(directory, {rank: records}, objects, fut,
                            delta=delta)
                fut.wait_captured()
                fut.wait_persisted()
                files = [os.path.basename(rank_file(directory, rank))]
                _fire_fault(fault, "mid_file", rank, step, directory,
                            files)
                _fire_fault(fault, "after_upload", rank, step, directory,
                            files)
                with obs.span("vote", lane=lane, step=step, rank=rank):
                    vote = RankManifest.build(
                        directory, rank=rank, world=world, step=step,
                        filenames=files, checksum=checksum_files,
                        precomputed=fut.stats.extra.get("file_checksums"))
                    vote.write(directory)
                _fire_fault(fault, "after_vote", rank, step, directory,
                            files)
                _fire_fault(fault, "before_ack", rank, step, directory,
                            files)
                events = tracer.events() if tracer is not None else []
                conn.send(("prepared", step, encode_stats(fut.stats),
                           events))
            except BaseException as exc:  # noqa: BLE001 — report, don't die
                events = tracer.events() if tracer is not None else []
                try:
                    conn.send(("failed", step, repr(exc),
                               traceback.format_exc(), events))
                except (OSError, ValueError, BrokenPipeError):
                    return
            finally:
                if tracer is not None:
                    obs.disable()
    finally:
        try:
            engine.close()
        except Exception:
            pass
        try:
            conn.send(("closed",))
        except (OSError, ValueError, BrokenPipeError):
            pass
        conn.close()
