"""Multi-rank checkpoint coordinator: hierarchical two-phase commit.

The paper's evaluation (§VI) is fundamentally multi-writer — every rank of
the DP×TP×PP mesh drains its own shards concurrently, and the throughput
gain comes from all ranks' I/O lanes running at once. This module owns the
save *protocol*; the execution domain behind each rank is pluggable
(:mod:`repro.dist.runtime`):

* :class:`ThreadRankRuntime` — one thread per rank in this process. The
  deterministic test double every protocol test runs against.
* :class:`~repro.dist.process_runtime.ProcessRankRuntime` — one spawned
  OS process per rank (``runtime="process"``): a SIGKILL'd rank takes
  down exactly one process, the way a preempted node would.

The save protocol, per step:

1. **partition** — :func:`partition_records` maps the (already
   replica-balanced, see ``core.distributed.plan_shards``) shard records
   onto writer ranks, preserving device locality when there are at least
   as many devices as ranks and balancing by byte count otherwise. Ranks
   known dead are evicted first and their slice is re-spread over the
   survivors by byte balance (:func:`assign_replica_writers` with the
   survivors' loads as the initial fill), so the *next* save after a rank
   loss still commits with every shard present.
2. **phase 1 (prepare)** — each rank persists its ``rankNNNNN.dsllm``
   file through its own engine lane, then atomically writes its
   :class:`~repro.storage.manifest.RankManifest` vote.
3. **hierarchical ack collective** — ranks meet their *node-local*
   barrier first (:class:`_NodeCommit`, one per ``node_size`` block of
   ranks); each node's aggregator (its lowest rank) then writes the
   node's :class:`~repro.storage.manifest.NodeManifest` — the subtree
   vote — and meets the *global* barrier. Fan-in at any barrier is
   O(node_size) or O(n_nodes), never O(world); a dead or stalled rank is
   isolated and reported at its own aggregator (its node barrier is
   poisoned with the victim named), while surviving subtrees drain
   cleanly and observe the failure at the global barrier.
4. **phase 2 (commit)** — only once the global collective completes does
   the aggregated :class:`~repro.core.engine.CheckpointFuture` report
   ``persisted``; the manager's committer lane then writes the global
   ``StepManifest`` atomically last, re-validating every rank vote *and*
   every node manifest before making the step visible.

A crash, stall, or lie at *any* point before phase 2 leaves the step as an
in-flight orphan the catalog never selects — the single-writer crash
consistency of the repository, preserved under N concurrent writers.

``fault_hook`` is the thread runtime's deterministic fault-injection seam
(``tests/test_fault_injection.py``): called at named protocol points
(``"mid_file"``, ``"after_upload"``, ``"before_ack"``) with the rank and
save context, it may raise (kill) or block (stall) the rank there. The
process runtime takes a picklable
:class:`~repro.dist.ipc.ProcessFaultSpec` via ``fault=`` instead — a
closure cannot cross a process boundary, and a *real* SIGKILL needs no
cooperation from the victim.
"""

from __future__ import annotations

import os
import queue
import threading
import time
from typing import (Any, Callable, Dict, Iterable, List, Optional, Sequence,
                    Set, Tuple)

from repro.analysis.locks import declares_lock
from repro.obs import trace as obs
from repro.obs.metrics import metrics as obs_metrics
from repro.core.baselines import rank_file
from repro.core.distributed import ShardRecord, assign_replica_writers
from repro.core.engine import CheckpointFuture
from repro.core.state_provider import DeltaSaveSpec
from repro.storage.manifest import NodeManifest, RankManifest

from .barrier import BarrierBroken, CollectiveBarrier
from .ipc import ProcessFaultSpec
from .runtime import RANK_ENGINES, BaseRankRuntime

# Named fault-injection points of the thread runtime, in protocol order.
FAULT_POINTS = ("mid_file", "after_upload", "before_ack")

#: Rank-runtime backends (see module docstring).
RUNTIME_KINDS = ("thread", "process")

#: Default commit-tree fan-in: ranks per node when ``node_size`` is not
#: given. Worlds up to this size behave exactly like the flat (PR-3)
#: protocol — one node, one aggregator — so small-world tests see the
#: same barrier membership they always did.
DEFAULT_NODE_SIZE = 8

FaultHook = Callable[[str, int, Dict[str, Any]], None]


def partition_records(records: Sequence[ShardRecord], world: int,
                      *, dead: Iterable[int] = ()
                      ) -> Dict[int, List[ShardRecord]]:
    """Map shard records onto ``world`` writer ranks.

    With at least as many owning devices as ranks, whole device groups are
    kept together (rank ← sorted-device-position mod world) — each rank
    drains "its" devices' shards, the paper's locality. With fewer devices
    than ranks (e.g. a single-host simulation), individual records are
    spread greedily by byte count, largest first, onto the least-loaded
    rank, so every lane gets ~1/world of the bytes.

    ``dead`` names ranks evicted from the writer set (watchdog-confirmed
    process deaths). The base partition is computed over the *full* world
    first — so surviving ranks keep exactly the slice they always had
    (their per-rank delta bases stay valid) — and only the dead ranks'
    orphaned records are re-spread over the survivors, by byte balance
    seeded with the survivors' existing loads
    (:func:`~repro.core.distributed.assign_replica_writers`). Every
    surviving rank appears in the result (possibly with an empty list):
    each must write its file and cast its phase-1 vote, or the step
    cannot commit.
    """
    if world < 1:
        raise ValueError(f"world must be >= 1, got {world}")
    dead_set = {int(d) for d in dead}
    if not dead_set.issubset(range(world)):
        raise ValueError(
            f"dead ranks {sorted(dead_set - set(range(world)))} outside "
            f"world {world}")
    survivors = [r for r in range(world) if r not in dead_set]
    if not survivors:
        raise RuntimeError(
            f"no surviving writer ranks (world={world}, "
            f"dead={sorted(dead_set)})")
    out: Dict[int, List[ShardRecord]] = {r: [] for r in range(world)}
    by_dev: Dict[int, List[ShardRecord]] = {}
    for rec in records:
        by_dev.setdefault(rec.rank, []).append(rec)
    if len(by_dev) >= world:
        for pos, dev in enumerate(sorted(by_dev)):
            out[pos % world].extend(by_dev[dev])
    else:
        load = {r: 0 for r in range(world)}
        for rec in sorted(records,
                          key=lambda r: (-r.nbytes, r.tensor_name)):
            r = min(load, key=lambda k: (load[k], k))
            out[r].append(rec)
            load[r] += rec.nbytes
    if not dead_set:
        return out
    orphaned: List[ShardRecord] = []
    for d in sorted(dead_set):
        orphaned.extend(out.pop(d))
    live_load = {r: sum(rec.nbytes for rec in out[r]) for r in survivors}
    owners = assign_replica_writers(
        [(rec.tensor_name, rec.nbytes, {s: None for s in survivors})
         for rec in orphaned],
        initial_load=live_load)
    for rec in orphaned:
        out[owners[rec.tensor_name]].append(rec)
    return out


def node_topology(world: int, node_size: Optional[int] = None
                  ) -> Dict[int, List[int]]:
    """Commit-tree layout: ``{node_id: [member ranks]}``, contiguous
    blocks of ``node_size`` ranks (mirroring how ranks land on hosts)."""
    size = DEFAULT_NODE_SIZE if node_size is None else int(node_size)
    if size < 1:
        raise ValueError(f"node_size must be >= 1, got {node_size}")
    size = min(size, world)
    return {nid: list(range(nid * size, min((nid + 1) * size, world)))
            for nid in range((world + size - 1) // size)}


@declares_lock("coordinator.node", rank=15, attrs=("lock",))
class _NodeCommit:
    """One node of the commit tree: members, aggregator, local barrier.

    The aggregator (the node's lowest rank) is the only member that
    proceeds past the node barrier: it writes the node's subtree vote
    (:class:`~repro.storage.manifest.NodeManifest`) and represents the
    node at the global barrier. ``arrived`` (under ``lock``) names who
    reached the ack point, so a watchdog firing can poison each straggler
    node with exactly its missing members.
    """

    def __init__(self, node_id: int, ranks: Sequence[int]):
        self.node_id = node_id
        self.ranks: Tuple[int, ...] = tuple(sorted(ranks))
        self.aggregator = self.ranks[0]
        self.lock = threading.Lock()
        self.arrived: Set[int] = set()
        self.barrier = CollectiveBarrier(len(self.ranks))


# Outermost lock: rank callbacks fire with no repo/engine lock held, and
# all barrier/repository work happens after this lock is dropped.
@declares_lock("coordinator.job", rank=10, attrs=("lock",))
class _SaveJob:
    """Shared per-save state: capture/ack aggregation onto one future,
    through the node-local → global barrier hierarchy."""

    def __init__(self, step: int, directory: str, world: int,
                 writers: Sequence[int], nodes: Dict[int, Sequence[int]],
                 future: CheckpointFuture,
                 ack_timeout_s: Optional[float],
                 checksum_votes: bool = True):
        self.step = step
        self.directory = directory
        self.world = world
        self.writers: Tuple[int, ...] = tuple(sorted(writers))
        self.future = future
        self.ack_timeout_s = ack_timeout_s
        self.checksum_votes = checksum_votes
        self.nodes: Dict[int, _NodeCommit] = {
            nid: _NodeCommit(nid, ranks)
            for nid, ranks in sorted(nodes.items()) if ranks}
        self.node_of: Dict[int, _NodeCommit] = {
            r: nc for nc in self.nodes.values() for r in nc.ranks}
        if set(self.node_of) != set(self.writers):
            raise ValueError(
                f"node topology {sorted(self.node_of)} does not cover "
                f"writers {list(self.writers)}")
        # fan-in at the root is O(n_nodes), not O(world)
        self.global_barrier = CollectiveBarrier(len(self.nodes))
        self.lock = threading.Lock()
        self.n_captured = 0
        self.failed = False
        self.settled = False
        self.watchdog_done = False
        self.timer: Optional[threading.Timer] = None

    # -- rank-side callbacks -------------------------------------------------
    def rank_captured(self, rank: int, fut: Optional[CheckpointFuture]
                      ) -> None:
        with self.lock:
            self.n_captured += 1
            done = (self.n_captured == len(self.writers)
                    and not self.failed)
        if done and not self.future.captured:
            self.future._set_captured()

    def _merge_stats(self, fut: CheckpointFuture) -> None:
        from repro.core.baselines import merge_domains_meta
        s, d = fut.stats, self.future.stats
        with self.lock:
            d.n_files += s.n_files
            d.n_tensors += s.n_tensors
            d.bytes_tensors += s.bytes_tensors
            d.bytes_objects += s.bytes_objects
            d.serialize_s += s.serialize_s
            d.stage_s += s.stage_s
            d.flush_s += s.flush_s
            doms = s.extra.get("domains")
            if doms:
                # per-rank engines derive their domain routing summaries
                # from their own provider instances; the aggregate future
                # carries the union for the step-level manifest record
                merge_domains_meta(d.extra.setdefault("domains", {}), doms)
            fdoms = s.extra.get("file_domains")
            if fdoms:
                # filenames are unique per rank, so a plain update merges
                d.extra.setdefault("file_domains", {}).update(fdoms)

    def rank_acked(self, rank: int, fut: Optional[CheckpointFuture]
                   ) -> None:
        """Phase-1 vote cast: meet the hierarchical ack collective.

        Every rank meets its *node* barrier; only the node's aggregator
        continues — it writes the node manifest (the subtree's vote) and
        meets the global barrier. The save's future turns ``persisted``
        only when every node's aggregator reaches the root — the gate
        the committer (phase 2) waits behind."""
        if fut is not None:
            self._merge_stats(fut)
        node = self.node_of[rank]
        with node.lock:
            node.arrived.add(rank)
        node.barrier.wait(timeout=self.ack_timeout_s)
        if rank != node.aggregator:
            return
        # whole subtree prepared: cast the node vote, then meet the root
        with obs.span("node.vote", lane=f"rank{node.aggregator:05d}",
                      step=self.step, node=node.node_id):
            nm = NodeManifest.build(
                self.directory, node=node.node_id,
                ranks=list(node.ranks), step=self.step, world=self.world,
                checksum=self.checksum_votes)
            nm.write(self.directory)
        self.global_barrier.wait(timeout=self.ack_timeout_s)
        with self.lock:
            # mark done *before* cancel: a Timer whose callback already
            # started survives .cancel(), and _on_timeout re-checks this
            # flag under the same lock — closing the fire-vs-cancel race
            self.watchdog_done = True
            settle = not self.failed and not self.settled
            self.settled = self.settled or settle
        if settle:
            self._cancel_watchdog()
            self.future._set_persisted()

    def rank_failed(self, rank: int, exc: BaseException) -> None:
        with self.lock:
            first = not self.failed and not self.settled
            self.failed = True
        if not first:
            return
        node = self.node_of.get(rank)
        if node is not None:
            # isolate the failure at the victim's own aggregator: only
            # this node's members wake with the cause; sibling subtrees
            # finish phase 1 + their node vote, then observe the poisoned
            # root
            node.barrier.poison(
                f"rank {rank} failed during save of step {self.step}: "
                f"{exc!r}", rank=rank)
            root_cause = (f"node {node.node_id} (rank {rank}) failed "
                          f"during save of step {self.step}: {exc!r}")
        else:
            # watchdog (rank=-1): name each straggler node's missing
            # members at its own barrier
            root_cause = (f"save of step {self.step} failed: {exc!r}")
            for nc in self.nodes.values():
                with nc.lock:
                    missing = sorted(set(nc.ranks) - nc.arrived)
                if missing:
                    nc.barrier.poison(
                        f"node {nc.node_id}: ranks {missing} never "
                        f"acked step {self.step}: {exc!r}")
        self.global_barrier.poison(root_cause,
                                   rank=rank if rank >= 0 else None)
        self._cancel_watchdog()
        self.future._set_error(exc)

    # -- coordinator side ----------------------------------------------------
    def start_watchdog(self) -> None:
        """Arm the ack timeout. Called by the *first rank to dequeue* the
        job, not at submit: the manager pipelines saves, and a job can sit
        behind an earlier step in the rank FIFOs for longer than the
        timeout — the watchdog must bound save latency (first rank
        starting → last ack), never queue wait."""
        if self.ack_timeout_s is None:
            return
        with self.lock:
            if self.timer is not None or self.settled or self.failed:
                return
            self.timer = threading.Timer(self.ack_timeout_s,
                                         self._on_timeout)
            self.timer.daemon = True
            self.timer.start()

    def _on_timeout(self) -> None:
        with self.lock:
            # the done flag is the authority, not Timer.cancel(): cancel
            # cannot stop a callback that has already been scheduled, so
            # a save that fully acked in the cancel window must not be
            # retro-failed here
            if self.watchdog_done or self.settled or self.failed:
                return
        self.rank_failed(-1, TimeoutError(
            f"step {self.step}: not all ranks acked within "
            f"{self.ack_timeout_s}s — a writer is stalled or dead"))

    def _cancel_watchdog(self) -> None:
        with self.lock:
            timer = self.timer
        if timer is not None:
            timer.cancel()


class ThreadRankRuntime(BaseRankRuntime):
    """One simulated writer rank: a thread + its own engine/cache lane.

    The protocol test double — same :class:`_SaveJob` callbacks as the
    process backend, but faults are injected with in-process closures
    (``fault_hook``) and a "killed" rank is an exception, not a corpse.
    """

    def __init__(self, rank: int, world: int, *, mode: str = "datastates",
                 host_cache_bytes: int = 1 << 30, flush_threads: int = 2,
                 chunk_bytes: int = 4 << 20,
                 throttle_mbps: Optional[float] = None,
                 checksum_files: bool = True,
                 fault_hook: Optional[FaultHook] = None):
        if mode not in RANK_ENGINES:
            raise ValueError(
                f"coordinator ranks require a DataMovementEngine mode, "
                f"got {mode!r} (choose from {sorted(RANK_ENGINES)})")
        self.rank = rank
        self.world = world
        self.checksum_files = checksum_files
        self.fault_hook = fault_hook
        # distinct lane-name prefix per rank: traces get one set of engine
        # tracks (stage/producer/flush) per rank lane
        self.lane = f"rank{rank:05d}"
        self.engine = RANK_ENGINES[mode](
            host_cache_bytes=host_cache_bytes, flush_threads=flush_threads,
            chunk_bytes=chunk_bytes, throttle_mbps=throttle_mbps,
            label=self.lane, checksum_files=checksum_files)
        self._q: "queue.Queue[Optional[tuple]]" = queue.Queue()
        self._thread = threading.Thread(
            target=self._worker, daemon=True, name=f"dsllm-rank-{rank}")
        self._thread.start()

    @property
    def host_cache(self):
        return self.engine.host_cache

    def submit(self, job: _SaveJob, records: List[ShardRecord],
               objects: Dict[str, Any],
               delta: Optional[DeltaSaveSpec] = None) -> None:
        self._q.put((job, records, objects, delta))

    # ------------------------------------------------------------- internals
    def _fault(self, point: str, job: _SaveJob, files: List[str]) -> None:
        if self.fault_hook is not None:
            self.fault_hook(point, self.rank, {
                "step": job.step, "directory": job.directory,
                "files": [os.path.join(job.directory, n) for n in files]})

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            job, records, objects, delta = item
            try:
                self._run_save(job, records, objects, delta)
            except BaseException as exc:  # noqa: BLE001
                job.rank_failed(self.rank, exc)
            finally:
                self._q.task_done()

    def _run_save(self, job: _SaveJob, records: List[ShardRecord],
                  objects: Dict[str, Any],
                  delta: Optional[DeltaSaveSpec] = None) -> None:
        job.start_watchdog()  # first rank to dequeue arms the ack timeout
        fut = CheckpointFuture(job.step, job.directory)
        flow = obs.flow_id("save", job.step, rank=self.rank)
        # phase 1a: drain this rank's shards through this rank's lane.
        # Differential saves keep *per-rank* delta bases: each rank's
        # engine retains the previous snapshot of exactly the shards it
        # writes (the partition is deterministic for an unchanged shard
        # set, and any reshard forces a keyframe upstream).
        self.engine.save(job.directory, {self.rank: records}, objects, fut,
                        delta=delta)
        with obs.span("rank.capture_wait", lane=self.lane, step=job.step,
                      rank=self.rank, flow=flow, flow_phase="start"):
            fut.wait_captured()
        job.rank_captured(self.rank, fut)
        with obs.span("rank.persist_wait", lane=self.lane, step=job.step,
                      rank=self.rank, flow=flow):
            fut.wait_persisted()
        files = [os.path.basename(rank_file(job.directory, self.rank))]
        self._fault("mid_file", job, files)
        self._fault("after_upload", job, files)
        # phase 1b: the vote — sizes + checksums hashed on this lane
        with obs.span("vote", lane=self.lane, step=job.step,
                      rank=self.rank, flow=flow):
            vote = RankManifest.build(
                job.directory, rank=self.rank, world=job.world,
                step=job.step, filenames=files,
                checksum=self.checksum_files,
                precomputed=fut.stats.extra.get("file_checksums"))
            vote.write(job.directory)
        self._fault("before_ack", job, files)
        t_ack = time.perf_counter()
        job.rank_acked(self.rank, fut)
        t_done = time.perf_counter()
        obs_metrics.observe("barrier.wait_s", t_done - t_ack)
        obs.add_span("ack.barrier", t_ack, t_done, lane=self.lane,
                     step=job.step, rank=self.rank, flow=flow,
                     flow_phase="end")

    def drain(self) -> None:
        self._q.join()
        self.engine.drain()

    def close(self) -> None:
        self._q.put(None)
        self.engine.close()
        self._thread.join(timeout=10)


#: Backwards-compatible name: before the process backend existed, the
#: thread runtime *was* "the" RankRuntime.
RankRuntime = ThreadRankRuntime


@declares_lock("coordinator.dead", rank=12, attrs=("_dead_lock",))
class Coordinator:
    """Owns N rank runtimes and the save protocol across them."""

    def __init__(self, world: int, *, mode: str = "datastates",
                 runtime: str = "thread",
                 node_size: Optional[int] = None,
                 host_cache_bytes: int = 1 << 30, flush_threads: int = 2,
                 chunk_bytes: int = 4 << 20,
                 throttle_mbps: Optional[float] = None,
                 checksum_files: bool = True,
                 ack_timeout_s: Optional[float] = None,
                 fault_hook: Optional[FaultHook] = None,
                 fault: Optional[ProcessFaultSpec] = None,
                 start_method: str = "spawn",
                 jax_distributed: bool = False):
        if world < 1:
            raise ValueError(f"world must be >= 1, got {world}")
        if runtime not in RUNTIME_KINDS:
            raise ValueError(f"unknown runtime {runtime!r} "
                             f"(choose from {RUNTIME_KINDS})")
        self.world = world
        self.mode = mode
        self.runtime = runtime
        self.node_size = node_size
        self.nodes = node_topology(world, node_size)
        self.ack_timeout_s = ack_timeout_s
        self.checksum_files = checksum_files
        self._dead_lock = threading.Lock()
        self.dead_ranks: Set[int] = set()
        if runtime == "thread":
            if fault is not None:
                raise ValueError(
                    "fault= (ProcessFaultSpec) requires runtime="
                    "'process'; the thread runtime injects faults with "
                    "fault_hook= closures")
            self.ranks: List[BaseRankRuntime] = [
                ThreadRankRuntime(
                    r, world, mode=mode,
                    host_cache_bytes=host_cache_bytes,
                    flush_threads=flush_threads, chunk_bytes=chunk_bytes,
                    throttle_mbps=throttle_mbps,
                    checksum_files=checksum_files, fault_hook=fault_hook)
                for r in range(world)]
        else:
            if fault_hook is not None:
                raise ValueError(
                    "fault_hook= closures cannot cross a process "
                    "boundary; use fault= (a ProcessFaultSpec) with "
                    "runtime='process'")
            from .process_runtime import ProcessRankRuntime
            self.ranks = [
                ProcessRankRuntime(
                    r, world, mode=mode,
                    host_cache_bytes=host_cache_bytes,
                    flush_threads=flush_threads, chunk_bytes=chunk_bytes,
                    throttle_mbps=throttle_mbps,
                    checksum_files=checksum_files,
                    fault=fault if fault is not None
                    and fault.rank == r else None,
                    on_dead=self._note_dead, start_method=start_method,
                    jax_distributed=jax_distributed)
                for r in range(world)]

    # ------------------------------------------------------- writer census
    def _note_dead(self, rank: int) -> None:
        with self._dead_lock:
            self.dead_ranks.add(rank)

    def _prune_dead(self) -> Set[int]:
        for rt in self.ranks:
            live = rt.alive()
            if not live:
                with self._dead_lock:
                    self.dead_ranks.add(rt.rank)
        with self._dead_lock:
            return set(self.dead_ranks)

    def active_writers(self) -> Tuple[int, ...]:
        """Surviving writer ranks, re-checking liveness first. The
        manager consults this before planning a delta save: a changed
        writer set moves shard slices between engines, which invalidates
        every per-rank delta base (forced keyframe)."""
        dead = self._prune_dead()
        return tuple(r for r in range(self.world) if r not in dead)

    def submit(self, step: int, directory: str,
               records: Sequence[ShardRecord], objects: Dict[str, Any],
               future: CheckpointFuture,
               delta: Optional[DeltaSaveSpec] = None) -> Dict[str, Any]:
        """Fan one save out across the surviving ranks. Returns
        immediately with the save's commit topology — ``{"writers":
        [...], "nodes": {node_id: [ranks]}}`` — which the manager stashes
        on the future so phase 2 validates exactly the votes this save
        was built to cast. The aggregated ``future`` captures when every
        writer has captured and persists only when every node's
        aggregator has met the global barrier (phase 1 complete — the
        committer performs phase 2 behind it). ``delta`` (a
        :class:`DeltaSaveSpec`) puts the save on the differential path:
        every rank streams XOR deltas against its own retained bases, and
        the step commits through the same hierarchical vote.

        Per-domain provider routing (the manager's
        :class:`~repro.core.registry.StateProviderRegistry`) needs no
        extra plumbing here: each record carries its resolved
        :class:`~repro.core.registry.ProviderRoute`, so every rank lane
        builds the same tensor/delta/quantized/custom providers for its
        partition that a single-writer engine would."""
        dead = self._prune_dead()
        writers = [r for r in range(self.world) if r not in dead]
        by_rank = partition_records(records, self.world, dead=dead)
        # objects ride with the least-loaded rank (deterministic tie-break)
        loads = {r: sum(rec.nbytes for rec in by_rank[r]) for r in writers}
        obj_rank = min(loads, key=lambda r: (loads[r], r))
        nodes = {nid: [r for r in ranks if r not in dead]
                 for nid, ranks in self.nodes.items()}
        nodes = {nid: ranks for nid, ranks in nodes.items() if ranks}
        # One barrier tree per save: the manager pipelines steps, and
        # ranks reach the ack point of different steps at different
        # times — shared barriers would mix generations across steps.
        job = _SaveJob(step, directory, self.world, writers, nodes,
                       future, self.ack_timeout_s,
                       checksum_votes=self.checksum_files)
        for r in writers:
            self.ranks[r].submit(job, by_rank[r],
                                 objects if r == obj_rank else {},
                                 delta=delta)
        return {"writers": list(writers),
                "nodes": {nid: list(ranks)
                          for nid, ranks in sorted(nodes.items())}}

    def drain(self) -> None:
        for rank in self.ranks:
            rank.drain()

    def close(self) -> None:
        for rank in self.ranks:
            rank.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
