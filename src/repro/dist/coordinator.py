"""Multi-rank checkpoint coordinator: balanced writers, two-phase commit.

The paper's evaluation (§VI) is fundamentally multi-writer — every rank of
the DP×TP×PP mesh drains its own shards concurrently, and the throughput
gain comes from all ranks' I/O lanes running at once. This module simulates
that world inside one process:

* :class:`RankRuntime` — one writer rank as a dedicated thread owning its
  *own* :class:`~repro.core.engine.DataMovementEngine` +
  :class:`~repro.core.host_cache.HostCache` lane (via a per-rank
  :class:`~repro.core.baselines.DataStatesEngine`), draining only the
  shards assigned to it, concurrently with every other rank;
* :class:`Coordinator` — owns N rank runtimes and runs the save protocol:

  1. **partition** — :func:`partition_records` maps the (already
     replica-balanced, see ``core.distributed.plan_shards``) shard records
     onto writer ranks, preserving device locality when there are at least
     as many devices as ranks and balancing by byte count otherwise;
  2. **phase 1 (prepare)** — each rank persists its ``rankNNNNN.dsllm``
     file through its engine, then atomically writes its
     :class:`~repro.storage.manifest.RankManifest` vote (sizes + checksums
     hashed on the rank's own lane, in parallel);
  3. **ack collective** — ranks meet at a :class:`CollectiveBarrier`; a
     dead rank poisons it, a stalled rank times it out, and either failure
     propagates to the save's aggregated future as an error;
  4. **phase 2 (commit)** — only once the collective completes does the
     aggregated :class:`~repro.core.engine.CheckpointFuture` report
     ``persisted``; the manager's committer lane then writes the global
     ``StepManifest`` atomically last, with ``expect_ranks=N`` so the
     catalog re-validates every vote before making the step visible.

A crash, stall, or lie at *any* point before phase 2 leaves the step as an
in-flight orphan the catalog never selects — the single-writer crash
consistency of the repository, preserved under N concurrent writers.

``fault_hook`` is the deterministic fault-injection seam used by
``tests/test_fault_injection.py``: it is called at named protocol points
(``"mid_file"``, ``"after_upload"``, ``"before_ack"``) with the rank and
save context, and may raise (kill) or block (stall) the rank there.
"""

from __future__ import annotations

import os
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.analysis.locks import declares_lock
from repro.obs import trace as obs
from repro.obs.metrics import metrics as obs_metrics
from repro.core.baselines import (DataStatesEngine, DataStatesOldEngine,
                                  rank_file)
from repro.core.distributed import ShardRecord
from repro.core.engine import CheckpointFuture
from repro.core.state_provider import DeltaSaveSpec
from repro.storage.manifest import RankManifest

from .barrier import BarrierBroken, CollectiveBarrier

RANK_ENGINES = {
    "datastates": DataStatesEngine,
    "datastates-old": DataStatesOldEngine,
}

# Named fault-injection points, in protocol order.
FAULT_POINTS = ("mid_file", "after_upload", "before_ack")

FaultHook = Callable[[str, int, Dict[str, Any]], None]


def partition_records(records: Sequence[ShardRecord], world: int
                      ) -> Dict[int, List[ShardRecord]]:
    """Map shard records onto ``world`` writer ranks.

    With at least as many owning devices as ranks, whole device groups are
    kept together (rank ← sorted-device-position mod world) — each rank
    drains "its" devices' shards, the paper's locality. With fewer devices
    than ranks (e.g. a single-host simulation), individual records are
    spread greedily by byte count, largest first, onto the least-loaded
    rank, so every lane gets ~1/world of the bytes. Every rank appears in
    the result (possibly with an empty list): each must write its file and
    cast its phase-1 vote, or the step cannot commit.
    """
    if world < 1:
        raise ValueError(f"world must be >= 1, got {world}")
    out: Dict[int, List[ShardRecord]] = {r: [] for r in range(world)}
    by_dev: Dict[int, List[ShardRecord]] = {}
    for rec in records:
        by_dev.setdefault(rec.rank, []).append(rec)
    if len(by_dev) >= world:
        for pos, dev in enumerate(sorted(by_dev)):
            out[pos % world].extend(by_dev[dev])
        return out
    load = {r: 0 for r in range(world)}
    for rec in sorted(records, key=lambda r: (-r.nbytes, r.tensor_name)):
        r = min(load, key=lambda k: (load[k], k))
        out[r].append(rec)
        load[r] += rec.nbytes
    return out


# Outermost lock: rank callbacks fire with no repo/engine lock held, and
# all barrier/repository work happens after this lock is dropped.
@declares_lock("coordinator.job", rank=10, attrs=("lock",))
class _SaveJob:
    """Shared per-save state: capture/ack aggregation onto one future."""

    def __init__(self, step: int, directory: str, world: int,
                 future: CheckpointFuture, barrier: CollectiveBarrier,
                 ack_timeout_s: Optional[float]):
        self.step = step
        self.directory = directory
        self.world = world
        self.future = future
        self.barrier = barrier
        self.ack_timeout_s = ack_timeout_s
        self.lock = threading.Lock()
        self.n_captured = 0
        self.failed = False
        self.settled = False
        self.timer: Optional[threading.Timer] = None

    # -- rank-side callbacks -------------------------------------------------
    def rank_captured(self, rank: int, fut: CheckpointFuture) -> None:
        with self.lock:
            self.n_captured += 1
            done = self.n_captured == self.world and not self.failed
        if done and not self.future.captured:
            self.future._set_captured()

    def _merge_stats(self, fut: CheckpointFuture) -> None:
        from repro.core.baselines import merge_domains_meta
        s, d = fut.stats, self.future.stats
        with self.lock:
            d.n_files += s.n_files
            d.n_tensors += s.n_tensors
            d.bytes_tensors += s.bytes_tensors
            d.bytes_objects += s.bytes_objects
            d.serialize_s += s.serialize_s
            d.stage_s += s.stage_s
            d.flush_s += s.flush_s
            doms = s.extra.get("domains")
            if doms:
                # per-rank engines derive their domain routing summaries
                # from their own provider instances; the aggregate future
                # carries the union for the step-level manifest record
                merge_domains_meta(d.extra.setdefault("domains", {}), doms)
            fdoms = s.extra.get("file_domains")
            if fdoms:
                # filenames are unique per rank, so a plain update merges
                d.extra.setdefault("file_domains", {}).update(fdoms)

    def rank_acked(self, rank: int, fut: CheckpointFuture) -> None:
        """Phase-1 vote cast: meet the ack collective. The save's future
        turns ``persisted`` only when *every* rank reaches this point —
        the gate the committer (phase 2) waits behind."""
        self._merge_stats(fut)
        self.barrier.wait(timeout=self.ack_timeout_s)
        with self.lock:
            settle = not self.failed and not self.settled
            self.settled = self.settled or settle
        if settle:
            self._cancel_watchdog()
            self.future._set_persisted()

    def rank_failed(self, rank: int, exc: BaseException) -> None:
        with self.lock:
            first = not self.failed and not self.settled
            self.failed = True
        if first:
            self.barrier.poison(
                f"rank {rank} failed during save of step {self.step}: "
                f"{exc!r}", rank=rank)
            self._cancel_watchdog()
            self.future._set_error(exc)

    # -- coordinator side ----------------------------------------------------
    def start_watchdog(self) -> None:
        """Arm the ack timeout. Called by the *first rank to dequeue* the
        job, not at submit: the manager pipelines saves, and a job can sit
        behind an earlier step in the rank FIFOs for longer than the
        timeout — the watchdog must bound save latency (first rank
        starting → last ack), never queue wait."""
        if self.ack_timeout_s is None:
            return
        with self.lock:
            if self.timer is not None or self.settled or self.failed:
                return
            self.timer = threading.Timer(self.ack_timeout_s,
                                         self._on_timeout)
            self.timer.daemon = True
            self.timer.start()

    def _on_timeout(self) -> None:
        if self.future.persisted:
            return
        self.rank_failed(-1, TimeoutError(
            f"step {self.step}: not all ranks acked within "
            f"{self.ack_timeout_s}s — a writer is stalled or dead"))

    def _cancel_watchdog(self) -> None:
        if self.timer is not None:
            self.timer.cancel()


class RankRuntime:
    """One simulated writer rank: a thread + its own engine/cache lane."""

    def __init__(self, rank: int, world: int, *, mode: str = "datastates",
                 host_cache_bytes: int = 1 << 30, flush_threads: int = 2,
                 chunk_bytes: int = 4 << 20,
                 throttle_mbps: Optional[float] = None,
                 checksum_files: bool = True,
                 fault_hook: Optional[FaultHook] = None):
        if mode not in RANK_ENGINES:
            raise ValueError(
                f"coordinator ranks require a DataMovementEngine mode, "
                f"got {mode!r} (choose from {sorted(RANK_ENGINES)})")
        self.rank = rank
        self.world = world
        self.checksum_files = checksum_files
        self.fault_hook = fault_hook
        # distinct lane-name prefix per rank: traces get one set of engine
        # tracks (stage/producer/flush) per rank lane
        self.lane = f"rank{rank:05d}"
        self.engine = RANK_ENGINES[mode](
            host_cache_bytes=host_cache_bytes, flush_threads=flush_threads,
            chunk_bytes=chunk_bytes, throttle_mbps=throttle_mbps,
            label=self.lane)
        self._q: "queue.Queue[Optional[tuple]]" = queue.Queue()
        self._thread = threading.Thread(
            target=self._worker, daemon=True, name=f"dsllm-rank-{rank}")
        self._thread.start()

    @property
    def host_cache(self):
        return self.engine.host_cache

    def submit(self, job: _SaveJob, records: List[ShardRecord],
               objects: Dict[str, Any],
               delta: Optional[DeltaSaveSpec] = None) -> None:
        self._q.put((job, records, objects, delta))

    # ------------------------------------------------------------- internals
    def _fault(self, point: str, job: _SaveJob, files: List[str]) -> None:
        if self.fault_hook is not None:
            self.fault_hook(point, self.rank, {
                "step": job.step, "directory": job.directory,
                "files": [os.path.join(job.directory, n) for n in files]})

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            job, records, objects, delta = item
            try:
                self._run_save(job, records, objects, delta)
            except BaseException as exc:  # noqa: BLE001
                job.rank_failed(self.rank, exc)
            finally:
                self._q.task_done()

    def _run_save(self, job: _SaveJob, records: List[ShardRecord],
                  objects: Dict[str, Any],
                  delta: Optional[DeltaSaveSpec] = None) -> None:
        job.start_watchdog()  # first rank to dequeue arms the ack timeout
        fut = CheckpointFuture(job.step, job.directory)
        flow = obs.flow_id("save", job.step, rank=self.rank)
        # phase 1a: drain this rank's shards through this rank's lane.
        # Differential saves keep *per-rank* delta bases: each rank's
        # engine retains the previous snapshot of exactly the shards it
        # writes (the partition is deterministic for an unchanged shard
        # set, and any reshard forces a keyframe upstream).
        self.engine.save(job.directory, {self.rank: records}, objects, fut,
                        delta=delta)
        with obs.span("rank.capture_wait", lane=self.lane, step=job.step,
                      rank=self.rank, flow=flow, flow_phase="start"):
            fut.wait_captured()
        job.rank_captured(self.rank, fut)
        with obs.span("rank.persist_wait", lane=self.lane, step=job.step,
                      rank=self.rank, flow=flow):
            fut.wait_persisted()
        files = [os.path.basename(rank_file(job.directory, self.rank))]
        self._fault("mid_file", job, files)
        self._fault("after_upload", job, files)
        # phase 1b: the vote — sizes + checksums hashed on this lane
        with obs.span("vote", lane=self.lane, step=job.step,
                      rank=self.rank, flow=flow):
            vote = RankManifest.build(
                job.directory, rank=self.rank, world=job.world,
                step=job.step, filenames=files,
                checksum=self.checksum_files)
            vote.write(job.directory)
        self._fault("before_ack", job, files)
        t_ack = time.perf_counter()
        job.rank_acked(self.rank, fut)
        t_done = time.perf_counter()
        obs_metrics.observe("barrier.wait_s", t_done - t_ack)
        obs.add_span("ack.barrier", t_ack, t_done, lane=self.lane,
                     step=job.step, rank=self.rank, flow=flow,
                     flow_phase="end")

    def drain(self) -> None:
        self._q.join()
        self.engine.drain()

    def close(self) -> None:
        self._q.put(None)
        self.engine.close()
        self._thread.join(timeout=10)


class Coordinator:
    """Owns N rank runtimes and the save protocol across them."""

    def __init__(self, world: int, *, mode: str = "datastates",
                 host_cache_bytes: int = 1 << 30, flush_threads: int = 2,
                 chunk_bytes: int = 4 << 20,
                 throttle_mbps: Optional[float] = None,
                 checksum_files: bool = True,
                 ack_timeout_s: Optional[float] = None,
                 fault_hook: Optional[FaultHook] = None):
        if world < 1:
            raise ValueError(f"world must be >= 1, got {world}")
        self.world = world
        self.mode = mode
        self.ack_timeout_s = ack_timeout_s
        self.ranks = [
            RankRuntime(r, world, mode=mode,
                        host_cache_bytes=host_cache_bytes,
                        flush_threads=flush_threads, chunk_bytes=chunk_bytes,
                        throttle_mbps=throttle_mbps,
                        checksum_files=checksum_files, fault_hook=fault_hook)
            for r in range(world)]

    def submit(self, step: int, directory: str,
               records: Sequence[ShardRecord], objects: Dict[str, Any],
               future: CheckpointFuture,
               delta: Optional[DeltaSaveSpec] = None) -> None:
        """Fan one save out across all ranks. Returns immediately; the
        aggregated ``future`` captures when every rank has captured and
        persists only when every rank has voted *and* acked (phase 1
        complete — the committer performs phase 2 behind it).
        ``delta`` (a :class:`DeltaSaveSpec`) puts the save on the
        differential path: every rank streams XOR deltas against its own
        retained bases, and the step commits through the same two-phase
        vote.

        Per-domain provider routing (the manager's
        :class:`~repro.core.registry.StateProviderRegistry`) needs no
        extra plumbing here: each record carries its resolved
        :class:`~repro.core.registry.ProviderRoute`, so every rank lane
        builds the same tensor/delta/quantized/custom providers for its
        partition that a single-writer engine would."""
        by_rank = partition_records(records, self.world)
        # objects ride with the least-loaded rank (deterministic tie-break)
        loads = {r: sum(rec.nbytes for rec in recs)
                 for r, recs in by_rank.items()}
        obj_rank = min(loads, key=lambda r: (loads[r], r))
        # One collective per save: the manager pipelines steps, and ranks
        # reach the ack point of different steps at different times — a
        # shared barrier would mix generations across steps.
        job = _SaveJob(step, directory, self.world, future,
                       CollectiveBarrier(self.world), self.ack_timeout_s)
        for rank in self.ranks:
            rank.submit(job, by_rank[rank.rank],
                        objects if rank.rank == obj_rank else {},
                        delta=delta)

    def drain(self) -> None:
        for rank in self.ranks:
            rank.drain()

    def close(self) -> None:
        for rank in self.ranks:
            rank.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
