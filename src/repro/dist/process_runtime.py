"""Process-per-rank backend: one spawned OS process per writer rank.

The thread runtime shares one address space, so a "dead rank" there is a
raised exception — python cannot actually kill a thread, and a real rank
loss (preemption, OOM-kill, node crash) kills a *process* with no chance
to run cleanup. This backend gives every rank its own spawned child
(:mod:`repro.dist.worker`) and keeps a parent-side **proxy thread** per
rank that speaks the save protocol on the child's behalf:

* ``submit`` enqueues; the proxy ships the encoded partition over the
  pipe, and calls ``rank_captured`` as soon as ``send()`` returns — the
  payload is fully serialized out of the training buffers at that point,
  which is exactly what the capture barrier promises;
* the proxy then waits on **both** the pipe and the child's process
  sentinel (``multiprocessing.connection.wait``): a ``prepared`` reply
  becomes ``rank_acked`` (the proxy meets the barriers in-parent), a
  ``failed`` reply becomes :class:`~repro.dist.ipc.RemoteRankError`, and
  the sentinel firing — the SIGKILL case — becomes
  :class:`~repro.dist.ipc.ProcessDied`, reported to the job like any
  rank failure and to the coordinator's dead-rank set via ``on_dead``;
* child trace spans ship back in each reply and are ingested into the
  parent tracer with a clock offset measured at the ``ready`` handshake,
  so one Perfetto export shows every process's lanes on one timeline.

A save abandoned by the watchdog (stalled child) leaves its reply
in-flight; replies are tagged with their step and stale ones are drained
before the next ship, so a late ``prepared`` can never ack the wrong
save.
"""

from __future__ import annotations

import multiprocessing
import queue
import threading
import time
from multiprocessing import connection as mp_connection
from typing import Any, Callable, Dict, List, Optional

from repro.analysis.locks import declares_lock
from repro.obs import trace as obs
from repro.obs.metrics import metrics as obs_metrics
from repro.core.engine import CheckpointFuture

from .ipc import (ProcessDied, ProcessFaultSpec, RemoteRankError,
                  apply_stats, encode_record)
from .runtime import RANK_ENGINES, BaseRankRuntime
from .worker import worker_main

#: How often the proxy re-checks job state / child liveness while waiting
#: for a reply, and how long a graceful shutdown waits before close()
#: escalates to terminate/kill.
_POLL_S = 0.2
_SHUTDOWN_GRACE_S = 5.0


@declares_lock("ipc.proc", rank=16, attrs=("_lock",))
class ProcessRankRuntime(BaseRankRuntime):
    """One writer rank as a spawned child + parent-side proxy thread."""

    def __init__(self, rank: int, world: int, *, mode: str = "datastates",
                 host_cache_bytes: int = 1 << 30, flush_threads: int = 2,
                 chunk_bytes: int = 4 << 20,
                 throttle_mbps: Optional[float] = None,
                 checksum_files: bool = True,
                 fault: Optional[ProcessFaultSpec] = None,
                 on_dead: Optional[Callable[[int], None]] = None,
                 start_method: str = "spawn",
                 jax_distributed: bool = False):
        if mode not in RANK_ENGINES:
            raise ValueError(
                f"coordinator ranks require a DataMovementEngine mode, "
                f"got {mode!r} (choose from {sorted(RANK_ENGINES)})")
        self.rank = rank
        self.world = world
        self.checksum_files = checksum_files
        self.lane = f"rank{rank:05d}"
        self._on_dead = on_dead
        self._dead = threading.Event()
        self._lock = threading.Lock()   # guards _closed vs teardown races
        self._closed = False
        self._clock_offset = 0.0
        self._pid: Optional[int] = None
        engine_kw = dict(host_cache_bytes=host_cache_bytes,
                         flush_threads=flush_threads,
                         chunk_bytes=chunk_bytes,
                         throttle_mbps=throttle_mbps,
                         checksum_files=checksum_files)
        ctx = multiprocessing.get_context(start_method)
        self._conn, child_conn = ctx.Pipe(duplex=True)
        self._proc = ctx.Process(
            target=worker_main,
            args=(child_conn, rank, world, mode, engine_kw,
                  checksum_files, fault, jax_distributed),
            daemon=True, name=f"dsllm-rankproc-{rank}")
        self._proc.start()
        child_conn.close()  # parent keeps exactly one end
        self._q: "queue.Queue[Optional[tuple]]" = queue.Queue()
        self._proxy = threading.Thread(
            target=self._proxy_loop, daemon=True,
            name=f"dsllm-rankproxy-{rank}")
        self._proxy.start()

    # ------------------------------------------------------------ interface
    def submit(self, job: Any, records: List[Any],
               objects: Dict[str, Any], delta: Optional[Any] = None
               ) -> None:
        self._q.put((job, records, objects, delta))

    def alive(self) -> bool:
        with self._lock:
            closed = self._closed
        return (not closed and not self._dead.is_set()
                and self._proc.is_alive())

    def drain(self) -> None:
        self._q.join()

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._q.put(None)
        self._proxy.join(timeout=_SHUTDOWN_GRACE_S * 3)
        if self._proc.is_alive():
            self._proc.terminate()
            self._proc.join(timeout=_SHUTDOWN_GRACE_S)
            if self._proc.is_alive():
                self._proc.kill()
                self._proc.join(timeout=_SHUTDOWN_GRACE_S)
        try:
            self._conn.close()
        except OSError:
            pass

    # ---------------------------------------------------------- proxy loop
    def _proxy_loop(self) -> None:
        try:
            self._handshake()
        except (ProcessDied, EOFError, OSError):
            self._mark_dead()
        while True:
            item = self._q.get()
            if item is None:
                self._shutdown_child()
                self._q.task_done()
                return
            job, records, objects, delta = item
            try:
                self._run_remote_save(job, records, objects, delta)
            except BaseException as exc:  # noqa: BLE001
                job.rank_failed(self.rank, exc)
            finally:
                self._q.task_done()

    def _handshake(self) -> None:
        """Wait for the child's ``ready`` and align its trace clock."""
        while True:
            ready = mp_connection.wait(
                [self._conn, self._proc.sentinel], timeout=None)
            if self._conn in ready:
                try:
                    msg = self._conn.recv()
                except EOFError:
                    raise self._died()
                if msg[0] == "ready":
                    self._pid = msg[1]
                    # perf_counter is per-process on some OSes; the
                    # offset maps child span times onto this process's
                    # timeline (≈ pipe latency where clocks are shared)
                    self._clock_offset = time.perf_counter() - msg[2]
                    return
                continue
            if self._proc.sentinel in ready:
                raise self._died()

    def _run_remote_save(self, job: Any, records: List[Any],
                         objects: Dict[str, Any], delta: Optional[Any]
                         ) -> None:
        if not self.alive():
            raise self._died()
        job.start_watchdog()  # first rank to dequeue arms the ack timeout
        while self._conn.poll(0):  # drop stale replies of abandoned saves
            try:
                self._conn.recv()
            except EOFError:
                raise self._died()
        flow = obs.flow_id("save", job.step, rank=self.rank)
        t0 = time.perf_counter()
        payload = [encode_record(r) for r in records]
        try:
            self._conn.send(("save", job.step, job.directory, payload,
                             objects, delta, obs.enabled()))
        except (OSError, ValueError, BrokenPipeError):
            raise self._died()
        t1 = time.perf_counter()
        obs.add_span("rank.ship", t0, t1, lane=self.lane, step=job.step,
                     rank=self.rank, flow=flow, flow_phase="start")
        # payload fully serialized out of the training buffers: the
        # capture promise holds even though the child hasn't staged yet
        job.rank_captured(self.rank, None)
        reply = self._await_reply(job)
        if reply is None:
            return  # job already failed (watchdog); wait abandoned
        if reply[0] == "failed":
            _, _step, exc_repr, tb, events = reply
            self._ingest_events(events)
            raise RemoteRankError(self.rank, exc_repr, tb)
        _, _step, stats, events = reply
        self._ingest_events(events)
        fut = CheckpointFuture(job.step, job.directory)
        apply_stats(fut.stats, stats)
        t_ack = time.perf_counter()
        job.rank_acked(self.rank, fut)
        t_done = time.perf_counter()
        obs_metrics.observe("barrier.wait_s", t_done - t_ack)
        obs.add_span("ack.barrier", t_ack, t_done, lane=self.lane,
                     step=job.step, rank=self.rank, flow=flow,
                     flow_phase="end")

    def _await_reply(self, job: Any) -> Optional[tuple]:
        """Reply for ``job``, ``None`` if the job failed first, or raise
        :class:`ProcessDied` when the sentinel/EOF says the child is
        gone."""
        while True:
            ready = mp_connection.wait(
                [self._conn, self._proc.sentinel], timeout=_POLL_S)
            if self._conn in ready:
                try:
                    msg = self._conn.recv()
                except EOFError:
                    raise self._died()
                if msg[0] in ("prepared", "failed") \
                        and msg[1] != job.step:
                    continue  # stale reply from an abandoned save
                return msg
            if self._proc.sentinel in ready:
                self._proc.join(timeout=1.0)
                raise self._died()
            if job.future.persisted:
                # the job settled without this rank's reply, which can
                # only mean it settled with an error (this rank is a
                # party to its node barrier): the watchdog fired. Stop
                # waiting so the queue drains; the reply, if it ever
                # arrives, is dropped as stale by the next save.
                return None

    def _died(self) -> ProcessDied:
        self._mark_dead()
        return ProcessDied(self.rank, self._proc.exitcode)

    def _mark_dead(self) -> None:
        if not self._dead.is_set():
            self._dead.set()
            if self._on_dead is not None:
                self._on_dead(self.rank)

    def _ingest_events(self, events: List[Dict[str, Any]]) -> None:
        tracer = obs.get_tracer()
        if tracer is None or not events:
            return
        tracer.ingest(events, clock_offset=self._clock_offset,
                      default_lane=self.lane)

    def _shutdown_child(self) -> None:
        if self._dead.is_set() or not self._proc.is_alive():
            return
        try:
            self._conn.send(("close",))
        except (OSError, ValueError, BrokenPipeError):
            return
        deadline = time.monotonic() + _SHUTDOWN_GRACE_S
        while time.monotonic() < deadline:
            ready = mp_connection.wait(
                [self._conn, self._proc.sentinel], timeout=_POLL_S)
            if self._proc.sentinel in ready:
                break
            if self._conn in ready:
                try:
                    if self._conn.recv()[0] == "closed":
                        break
                except EOFError:
                    break
        self._proc.join(timeout=_SHUTDOWN_GRACE_S)
