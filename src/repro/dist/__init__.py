"""Multi-rank checkpoint coordination (N-writer world).

See :mod:`repro.dist.coordinator` for the save protocol (balanced writer
partition → per-rank engine lanes → phase-1 rank-manifest votes →
hierarchical node→global ack collective → phase-2 global commit),
:mod:`repro.dist.barrier` for the failure-aware collective primitive
underneath it, and :mod:`repro.dist.process_runtime` for the
process-per-rank backend (``runtime="process"``) where a dead rank is a
dead OS process, SIGKILL and all.
"""

from .barrier import BarrierBroken, CollectiveBarrier
from .coordinator import (Coordinator, DEFAULT_NODE_SIZE, FAULT_POINTS,
                          RANK_ENGINES, RUNTIME_KINDS, RankRuntime,
                          ThreadRankRuntime, node_topology,
                          partition_records)
from .ipc import (PROCESS_FAULT_POINTS, ProcessDied, ProcessFaultSpec,
                  RemoteRankError)
from .runtime import BaseRankRuntime

__all__ = [
    "BarrierBroken", "BaseRankRuntime", "CollectiveBarrier",
    "Coordinator", "DEFAULT_NODE_SIZE", "FAULT_POINTS",
    "PROCESS_FAULT_POINTS", "ProcessDied", "ProcessFaultSpec",
    "RANK_ENGINES", "RUNTIME_KINDS", "RankRuntime", "RemoteRankError",
    "ThreadRankRuntime", "node_topology", "partition_records",
]
