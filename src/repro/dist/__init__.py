"""Multi-rank checkpoint coordination (simulated N-writer world).

See :mod:`repro.dist.coordinator` for the save protocol (balanced writer
partition → per-rank engine lanes → phase-1 rank-manifest votes → ack
collective → phase-2 global commit) and :mod:`repro.dist.barrier` for the
failure-aware collective primitive underneath it.
"""

from .barrier import BarrierBroken, CollectiveBarrier
from .coordinator import (Coordinator, FAULT_POINTS, RANK_ENGINES,
                          RankRuntime, partition_records)

__all__ = [
    "BarrierBroken", "CollectiveBarrier",
    "Coordinator", "FAULT_POINTS", "RANK_ENGINES", "RankRuntime",
    "partition_records",
]
