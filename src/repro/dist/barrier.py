"""Failure-aware collective barrier for simulated multi-rank checkpointing.

``threading.Barrier`` almost fits, but a checkpoint barrier has two extra
requirements the stdlib one handles poorly:

* **poisoning with a cause** — when one rank dies mid-save, every peer
  (and the coordinator) must wake immediately with the *originating*
  exception, not a bare ``BrokenBarrierError``;
* **external observers** — the coordinator is not a party to the barrier
  but needs to wait for a generation to complete (or break) with its own
  timeout, so a stalled rank turns into a clean ``TimeoutError`` instead
  of a wedged training loop.

The barrier is reusable (generation-counted) like the stdlib one; once
poisoned it stays broken until :meth:`reset`, because a collective whose
membership already failed cannot silently heal.
"""

from __future__ import annotations

import threading
import time
from typing import Optional

from repro.analysis.locks import declares_lock


class BarrierBroken(RuntimeError):
    """The collective failed: some party poisoned the barrier."""

    def __init__(self, reason: str, rank: Optional[int] = None):
        super().__init__(reason)
        self.rank = rank


@declares_lock("barrier.cond", rank=20, attrs=("_cond",))
class CollectiveBarrier:
    """Reusable N-party barrier with poisoning and observer waits."""

    def __init__(self, parties: int):
        if parties < 1:
            raise ValueError(f"parties must be >= 1, got {parties}")
        self.parties = parties
        self._cond = threading.Condition()
        self._arrived = 0
        self._generation = 0
        self._broken: Optional[BarrierBroken] = None

    # ------------------------------------------------------------- parties
    def wait(self, timeout: Optional[float] = None) -> int:
        """Block until all parties arrive; returns the generation that
        completed. Raises :class:`BarrierBroken` if poisoned (before or
        while waiting) and ``TimeoutError`` on timeout — a timeout also
        poisons the barrier, since the collective can no longer complete
        with one party gone."""
        # Single monotonic deadline for the whole wait: Condition.wait()
        # restarts its clock on every wakeup, and wakeups that change
        # nothing (poison→reset cycles, adjacent generations completing)
        # would otherwise extend the total wait without bound.
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            if self._broken is not None:
                raise self._broken
            gen = self._generation
            self._arrived += 1
            if self._arrived == self.parties:
                self._arrived = 0
                self._generation += 1
                self._cond.notify_all()
                return gen
            while self._generation == gen and self._broken is None:
                remaining = None if deadline is None \
                    else deadline - time.monotonic()
                if remaining is not None and remaining <= 0 \
                        or not self._cond.wait(remaining):
                    self._broken = BarrierBroken(
                        f"barrier timed out in generation {gen} "
                        f"({self._arrived}/{self.parties} arrived)")
                    self._cond.notify_all()
                    raise TimeoutError(str(self._broken))
            if self._broken is not None:
                raise self._broken
            return gen

    def poison(self, reason: str, rank: Optional[int] = None) -> None:
        """Break the collective: every current and future waiter raises
        :class:`BarrierBroken` carrying ``reason`` until :meth:`reset`."""
        with self._cond:
            if self._broken is None:
                self._broken = BarrierBroken(reason, rank=rank)
            self._cond.notify_all()

    # ----------------------------------------------------------- observers
    def wait_generation(self, generation: int,
                        timeout: Optional[float] = None) -> None:
        """Observer wait (coordinator side): block until ``generation`` has
        completed. Raises :class:`BarrierBroken` if poisoned, or
        ``TimeoutError`` (without poisoning — the observer is not a party;
        the caller decides whether a late collective is fatal)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while self._generation <= generation:
                if self._broken is not None:
                    raise self._broken
                remaining = None if deadline is None \
                    else deadline - time.monotonic()
                if remaining is not None and remaining <= 0 \
                        or not self._cond.wait(remaining):
                    raise TimeoutError(
                        f"generation {generation} did not complete "
                        f"({self._arrived}/{self.parties} arrived)")
            if self._broken is not None:
                raise self._broken

    # ------------------------------------------------------------- control
    @property
    def broken(self) -> bool:
        with self._cond:
            return self._broken is not None

    @property
    def generation(self) -> int:
        with self._cond:
            return self._generation

    def reset(self) -> None:
        """Heal a poisoned barrier (tests / rank-replacement recovery)."""
        with self._cond:
            self._broken = None
            self._arrived = 0
            self._cond.notify_all()
