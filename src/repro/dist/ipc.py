"""IPC layer for the process-per-rank runtime: faults, errors, payloads.

Everything that crosses the parent↔child pipe lives here, so both sides
agree on the wire shapes without importing each other's modules:

* :class:`ProcessFaultSpec` — a *picklable* fault to ship to a child
  (the closure-based ``fault_hook`` of the thread runtime cannot cross a
  process boundary); the child fires it at named protocol points with a
  real ``SIGKILL``, which is the whole point of the process runtime —
  the blast radius of a dying rank is one OS process, not a thread that
  python cannot actually kill.
* :class:`ProcessDied` / :class:`RemoteRankError` — parent-side
  exceptions distinguishing "the process vanished" (sentinel fired /
  pipe EOF) from "the child caught an exception and reported it".
* :func:`encode_record` / :func:`decode_record` — ShardRecord transport.
  Encoding materializes device shards to numpy (the D2H copy that the
  in-process engine would do on its stage lane happens at ship time
  instead), and reduces a :class:`~repro.core.registry.ProviderRoute`
  to its picklable fields. Registry-attached provider *factories* are
  refused: a callable cannot cross the boundary, and silently dropping
  it would change what the child writes.

Wire protocol (tuples, pickled by ``multiprocessing.Connection``):

parent → child::

    ("save", step, directory, [record_payload...], objects, delta, trace)
    ("close",)

child → parent::

    ("ready", pid, perf_counter_at_ready)
    ("prepared", step, stats_dict, trace_events)
    ("failed", step, exc_repr, traceback_str, trace_events)
    ("closed",)

Replies carry ``step`` so the parent can discard stale messages from a
save it already abandoned (watchdog timeout) without misattributing them
to the next save.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

#: Protocol points a ProcessFaultSpec may name, in order. ``after_vote``
#: and ``before_ack`` are the same window (vote durable, ack never sent)
#: seen from the two phases' perspectives; both names are accepted.
PROCESS_FAULT_POINTS = ("mid_file", "after_upload", "after_vote",
                        "before_ack")

#: Fault actions: ``sigkill`` delivers an uncatchable SIGKILL to the
#: child itself; ``stall`` sleeps (watchdog-timeout territory).
PROCESS_FAULT_ACTIONS = ("sigkill", "stall")


@dataclasses.dataclass(frozen=True)
class ProcessFaultSpec:
    """A deterministic fault one child process fires on itself.

    ``step=None`` fires on the first save that reaches ``point``;
    otherwise only the named step triggers. ``mid_file`` first truncates
    the rank's own ``.dsllm`` file (torn write) before the kill, so the
    on-disk damage matches a node dying mid-flush, not just mid-protocol.
    """

    point: str
    rank: int
    step: Optional[int] = None
    action: str = "sigkill"
    stall_s: float = 600.0

    def __post_init__(self):
        if self.point not in PROCESS_FAULT_POINTS:
            raise ValueError(
                f"unknown fault point {self.point!r} "
                f"(choose from {PROCESS_FAULT_POINTS})")
        if self.action not in PROCESS_FAULT_ACTIONS:
            raise ValueError(
                f"unknown fault action {self.action!r} "
                f"(choose from {PROCESS_FAULT_ACTIONS})")

    def should_fire(self, point: str, rank: int, step: int) -> bool:
        return (point == self.point and rank == self.rank
                and (self.step is None or step == self.step))


class ProcessDied(RuntimeError):
    """A rank's worker process vanished (SIGKILL, OOM-kill, crash)."""

    def __init__(self, rank: int, exitcode: Optional[int]):
        super().__init__(
            f"rank {rank} worker process died (exitcode={exitcode})")
        self.rank = rank
        self.exitcode = exitcode


class RemoteRankError(RuntimeError):
    """An exception raised *inside* a worker, re-raised parent-side."""

    def __init__(self, rank: int, exc_repr: str, tb: str = ""):
        super().__init__(f"rank {rank} save failed: {exc_repr}")
        self.rank = rank
        self.exc_repr = exc_repr
        self.tb = tb


def encode_route(route: Any, tensor_name: str
                 ) -> Optional[Dict[str, Any]]:
    """Reduce a ProviderRoute to picklable fields (refusing factories)."""
    if route is None:
        return None
    if getattr(route, "factory", None) is not None:
        raise ValueError(
            f"record {tensor_name!r}: registry-attached provider "
            f"factories cannot cross the process boundary; run "
            f"factory-routed state under the thread runtime")
    return {"provider": route.provider,
            "options": tuple(route.options or ()),
            "rule_index": route.rule_index}


def encode_record(rec: Any) -> Dict[str, Any]:
    """ShardRecord → picklable payload (device shards → numpy here)."""
    import numpy as np
    data = rec.data
    if not isinstance(data, np.ndarray):
        data = np.asarray(data)  # D2H for device-resident jax shards
    return {
        "leaf_path": rec.leaf_path,
        "tensor_name": rec.tensor_name,
        "rank": rec.rank,
        "index": tuple(rec.index),
        "global_shape": tuple(rec.global_shape),
        "shape": tuple(rec.shape),
        "dtype": rec.dtype,
        "nbytes": int(rec.nbytes),
        "data": data,
        "domain": rec.domain,
        "route": encode_route(rec.route, rec.tensor_name),
    }


def decode_record(payload: Dict[str, Any]) -> Any:
    """Payload → ShardRecord (child side; data is already host-resident)."""
    from repro.core.distributed import ShardRecord
    from repro.core.registry import ProviderRoute
    rp = payload.get("route")
    route = None
    if rp is not None:
        route = ProviderRoute(provider=rp["provider"],
                              options=tuple(rp["options"]),
                              rule_index=rp["rule_index"])
    return ShardRecord(
        leaf_path=payload["leaf_path"],
        tensor_name=payload["tensor_name"],
        rank=payload["rank"],
        index=payload["index"],
        global_shape=payload["global_shape"],
        shape=payload["shape"],
        dtype=payload["dtype"],
        nbytes=payload["nbytes"],
        data=payload["data"],
        device_resident=False,
        domain=payload["domain"],
        route=route)


#: CheckpointStats fields shipped back in ``prepared`` replies; the
#: parent replays them onto a fresh future for _SaveJob._merge_stats.
STATS_FIELDS: Tuple[str, ...] = (
    "n_files", "n_tensors", "bytes_tensors", "bytes_objects",
    "serialize_s", "stage_s", "flush_s")

#: stats.extra keys worth shipping (step-manifest meta inputs).
STATS_EXTRA_KEYS: Tuple[str, ...] = ("domains", "file_domains")


def encode_stats(stats: Any) -> Dict[str, Any]:
    out = {k: getattr(stats, k) for k in STATS_FIELDS}
    out["extra"] = {k: v for k, v in stats.extra.items()
                    if k in STATS_EXTRA_KEYS}
    return out


def apply_stats(stats: Any, payload: Dict[str, Any]) -> None:
    for k in STATS_FIELDS:
        if k in payload:
            setattr(stats, k, payload[k])
    stats.extra.update(payload.get("extra") or {})
