"""Peer-to-peer slice exchange between concurrent restorers.

The second layer of the fleet warm-start fabric, for objects too large to
funnel through one cache leader: every replica currently restoring the
same object joins the object's *swap session*, claims disjoint byte
slices (dealt by the restore engine's ranged-read planner,
:func:`repro.core.restore.plan_ranged_slices`), fetches only its claimed
slices from the remote tier, and publishes them to the session's slice
table. Replicas then assemble the full object from each other's slices —
bittorrent-style — so the remote tier serves each byte once no matter how
many replicas are warming up.

Integrity: a claimer publishes each slice with its
:func:`~repro.core.codecs.payload_digest`; every *consumer* of an
exchanged slice recomputes the digest before trusting the bytes, and a
mismatch (bit-flip in peer memory, torn publish) causes that consumer to
discard the slice and fetch it directly from the remote tier. The
repository's whole-file manifest checksum still gates final admission, so
the exchange can only ever degrade performance, never correctness.

Fault model: a peer dying mid-exchange simply stops publishing. Claims
carry a deadline; once expired, any live replica re-claims the slice and
fetches it itself, so the session degrades to plain remote reads instead
of hanging.

Locking: ``fleet.exchange`` (rank 46) guards the session table;
``fleet.session`` (rank 48, a condition per session) guards one session's
claim/slice state. Remote reads and digest computation happen outside
both; waiting happens only on the session's own condition.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.analysis.locks import declares_lock
from repro.obs import trace as obs
from repro.obs.metrics import metrics as obs_metrics

from repro.core.codecs import payload_digest
from repro.core.restore import plan_ranged_slices
from repro.storage.backend import BackendError

__all__ = ["PeerExchange", "ExchangeStats"]


def _digest(data: bytes) -> int:
    return int(payload_digest(np.frombuffer(data, dtype=np.uint8))) \
        if data else 0


class ExchangeStats:
    """Per-replica accounting for one exchanged object."""

    __slots__ = ("remote_bytes", "peer_bytes", "refetched_slices",
                 "reclaimed_slices", "n_slices")

    def __init__(self) -> None:
        self.remote_bytes = 0      # bytes this replica pulled from remote
        self.peer_bytes = 0        # bytes this replica got from peers
        self.refetched_slices = 0  # digest-mismatch remote refetches
        self.reclaimed_slices = 0  # expired claims this replica took over
        self.n_slices = 0


@declares_lock("fleet.session", rank=48, attrs=("_cond",))
class _SwapSession:
    """One object's swap session: slice claims and the published table."""

    def __init__(self, key: str, nbytes: int, slice_bytes: int,
                 claim_timeout_s: float):
        self.key = key
        self.nbytes = nbytes
        self.slices: List[Tuple[int, int]] = \
            plan_ranged_slices(nbytes, slice_bytes)
        self.claim_timeout_s = claim_timeout_s
        self._cond = threading.Condition()  # declared: fleet.session (r48)
        self._unclaimed: List[int] = list(range(len(self.slices)))
        self._claims: Dict[int, float] = {}   # idx -> deadline (monotonic)
        self._parts: Dict[int, Tuple[bytes, int]] = {}  # idx -> (data, dig)
        self.joined = 0

    # ------------------------------------------------------------- claiming
    def next_claim(self) -> Optional[int]:
        """Claim a slice to fetch, reclaiming expired claims; ``None``
        when every slice is published or claimed by a live peer (the
        caller should then wait for completion)."""
        with self._cond:
            while True:
                if self._unclaimed:
                    idx = self._unclaimed.pop()
                    self._claims[idx] = time.monotonic() \
                        + self.claim_timeout_s
                    return idx
                now = time.monotonic()
                expired = [i for i, dl in self._claims.items()
                           if dl <= now]
                if expired:
                    idx = expired[0]
                    self._claims[idx] = now + self.claim_timeout_s
                    return -idx - 1  # reclaim marker (same slice index)
                if len(self._parts) == len(self.slices):
                    return None
                # all outstanding claims are live: wait for a publish or
                # the nearest claim expiry, whichever is sooner
                timeout = min((dl - now for dl in self._claims.values()),
                              default=0.05)
                self._cond.wait(timeout=max(0.01, min(timeout, 0.5)))

    def publish(self, idx: int, data: bytes, digest: int) -> None:
        with self._cond:
            self._parts[idx] = (data, digest)
            self._claims.pop(idx, None)
            self._cond.notify_all()

    def abandon(self, idx: int) -> None:
        """Give a failed claim back (the claimer's remote read raised)."""
        with self._cond:
            if idx not in self._parts:
                self._claims.pop(idx, None)
                self._unclaimed.append(idx)
                self._cond.notify_all()

    def complete(self) -> bool:
        with self._cond:
            return len(self._parts) == len(self.slices)

    def part(self, idx: int) -> Optional[Tuple[bytes, int]]:
        with self._cond:
            return self._parts.get(idx)


@declares_lock("fleet.exchange", rank=46, attrs=("_lock",))
class PeerExchange:
    """Swap-session broker shared by every replica in the process."""

    def __init__(self, slice_bytes: int = 4 << 20,
                 claim_timeout_s: float = 5.0):
        self.slice_bytes = int(slice_bytes)
        self.claim_timeout_s = float(claim_timeout_s)
        self._lock = threading.Lock()  # declared: fleet.exchange (r46)
        self._sessions: Dict[str, _SwapSession] = {}

    def _session(self, key: str, nbytes: int) -> _SwapSession:
        with self._lock:
            sess = self._sessions.get(key)
            if sess is None or sess.nbytes != nbytes:
                sess = _SwapSession(key, nbytes, self.slice_bytes,
                                    self.claim_timeout_s)
                self._sessions[key] = sess
            sess.joined += 1
            return sess

    def discard(self, key: str) -> None:
        """Drop a finished session so its slice table can be collected
        (late arrivals after a discard simply start a fresh session)."""
        with self._lock:
            self._sessions.pop(key, None)

    # ------------------------------------------------------------------ fetch
    def fetch(self, key: str, nbytes: int,
              read_range: Callable[[int, int], bytes],
              stats: Optional[ExchangeStats] = None) -> bytes:
        """Assemble ``key`` (``nbytes`` long) cooperatively.

        ``read_range(offset, n)`` reads one remote slice. The calling
        replica claims and fetches unclaimed slices until none remain,
        then assembles the object from the session table, verifying the
        publisher's digest on every slice it did not fetch itself and
        falling back to a direct remote read for any slice that fails
        verification."""
        stats = stats if stats is not None else ExchangeStats()
        sess = self._session(key, nbytes)
        stats.n_slices = len(sess.slices)
        t0 = time.perf_counter()
        own = self._contribute(sess, read_range, stats)
        data = self._assemble(sess, read_range, stats, own)
        obs.add_span("fleet.swap", t0, time.perf_counter(),
                     lane="fleet.swap", key=key, bytes=nbytes,
                     remote_bytes=stats.remote_bytes,
                     peer_bytes=stats.peer_bytes,
                     slices=stats.n_slices)
        obs_metrics.inc("fleet.remote_bytes", stats.remote_bytes)
        obs_metrics.inc("fleet.peer_bytes", stats.peer_bytes)
        return data

    def _contribute(self, sess: _SwapSession,
                    read_range: Callable[[int, int], bytes],
                    stats: ExchangeStats) -> set:
        """Claim-fetch-publish until the session has every slice; returns
        the slice indices this replica fetched itself."""
        own: set = set()
        while True:
            claim = sess.next_claim()
            if claim is None:
                return own
            idx = claim if claim >= 0 else -claim - 1
            if claim < 0:
                stats.reclaimed_slices += 1
            off, nb = sess.slices[idx]
            try:
                data = read_range(off, nb)
            except (BackendError, OSError):
                sess.abandon(idx)
                raise
            if len(data) != nb:
                sess.abandon(idx)
                raise BackendError(
                    f"{sess.key}: remote returned {len(data)} B for slice "
                    f"[{off}:{off + nb})")
            stats.remote_bytes += nb
            own.add(idx)
            sess.publish(idx, data, _digest(data))

    def _assemble(self, sess: _SwapSession,
                  read_range: Callable[[int, int], bytes],
                  stats: ExchangeStats, own: set) -> bytes:
        """Stitch the replica's copy together from the session table."""
        parts: List[bytes] = []
        for idx, (off, nb) in enumerate(sess.slices):
            entry = sess.part(idx)
            data: Optional[bytes] = None
            exchanged = idx not in own
            if entry is not None:
                data, digest = entry
                if exchanged and (len(data) != nb
                                  or _digest(data) != digest):
                    data = None  # corrupt exchange: fall back to remote
                    stats.refetched_slices += 1
            if data is None:
                data = read_range(off, nb)
                if len(data) != nb:
                    raise BackendError(
                        f"{sess.key}: remote returned {len(data)} B for "
                        f"slice [{off}:{off + nb})")
                stats.remote_bytes += nb
            elif exchanged:
                stats.peer_bytes += nb
            parts.append(data)
        return b"".join(parts)
