"""Fleet warm-start fabric: the checkpoint distribution front-end.

:class:`FleetFabric` is what serving replicas attach to a
:class:`~repro.storage.repository.CheckpointRepository`
(:meth:`~repro.storage.repository.CheckpointRepository.attach_fleet`):
restore resolution then routes every remote re-hydration through the
fabric instead of issuing a direct per-replica tier read. Per object the
fabric picks the cheapest source:

1. **cache** — small objects (≤ one exchange slice) go through the
   shared read-through :class:`~repro.fleet.cache.FleetCache`
   (single-flight: K replicas → one remote read);
2. **peer exchange** — large objects are assembled cooperatively through
   :class:`~repro.fleet.peer.PeerExchange` (each replica reads a disjoint
   slice set from remote, swaps for the rest), and the assembled bytes
   are offered back to the cache for stragglers;
3. **delta pull** — a replica already holding a step's chain prefix never
   re-reads it: chain members complete on the local tier short-circuit in
   ``resolve_for_restore`` before the fabric is consulted, so warming a
   fleet from step *k* to *k+K* transfers only the delta-chain bytes
   (``fleet.delta_pull`` spans make the saving auditable).

Whatever the source, the staged step is only published locally through
``repository.admit_fetched_step`` — the same size- + checksum-verified
atomic rename the direct tier path uses — and admission is single-flight
per step, so K replicas sharing one local tier produce one publish.

Per-step transfer accounting (remote vs. peer-exchanged bytes, cache
hits, replica count) is persisted to ``.catalog/fleet-stats.json`` for
``python -m repro.storage.cli stats --fleet``.

Locking: ``fleet.fabric`` (rank 42) guards the admit-flight table and the
stats dict only; fetches, staging writes, and admission all run outside
it (admission acquires ``repository.state`` from a bare stack).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

from repro.analysis.locks import declares_lock
from repro.obs import trace as obs

from repro.storage.backend import BackendError
from repro.storage.manifest import StepManifest
from repro.storage.repository import (CATALOG_DIR, CheckpointRepository,
                                      Tier, catalog_key, data_key)

from .cache import FleetCache, _Flight
from .peer import ExchangeStats, PeerExchange

__all__ = ["FleetFabric", "FLEET_STATS_KEY"]

FLEET_STATS_KEY = f"{CATALOG_DIR}/fleet-stats.json"


@declares_lock("fleet.fabric", rank=42, attrs=("_lock",))
class FleetFabric:
    """Cache + peer-exchange + delta-aware transfer, behind one handle."""

    def __init__(self, cache: Optional[FleetCache] = None,
                 peers: Optional[PeerExchange] = None, *,
                 cache_bytes: int = 256 << 20,
                 slice_bytes: int = 4 << 20,
                 claim_timeout_s: float = 5.0):
        self.cache = cache if cache is not None \
            else FleetCache(capacity_bytes=cache_bytes)
        self.peers = peers if peers is not None \
            else PeerExchange(slice_bytes=slice_bytes,
                              claim_timeout_s=claim_timeout_s)
        self._lock = threading.Lock()  # declared: fleet.fabric (r42)
        self._admits: Dict[Tuple[str, int], _Flight] = {}
        self._step_stats: Dict[int, Dict[str, int]] = {}

    # ------------------------------------------------------------ step fetch
    def fetch_step(self, repo: CheckpointRepository,
                   step: int) -> Optional[str]:
        """Re-hydrate ``step`` into ``repo``'s local tier through the
        fabric; ``None`` when no remote tier holds the step (the caller
        falls back to its own resolution)."""
        tier = self._tier_for(repo, step)
        if tier is None:
            return None
        stats = ExchangeStats()
        hits = [0]
        mbytes = self._cached_fetch(
            catalog_key(step),
            lambda: tier.backend.get(catalog_key(step)), stats, hits)
        manifest = StepManifest.from_json_bytes(mbytes)
        d = (manifest.meta or {}).get("delta") or {}
        is_delta = not d.get("keyframe", True)
        span = "fleet.delta_pull" if is_delta else "fleet.fetch"
        t0 = time.perf_counter()
        files: Dict[str, bytes] = {}
        for fe in manifest.files:
            files[fe.name] = self._file_bytes(tier, step, fe, stats, hits)
        sdir = self._admit(repo, step, manifest, files)
        obs.add_span(span, t0, time.perf_counter(), lane=span, step=step,
                     tier=tier.name, files=len(files),
                     remote_bytes=stats.remote_bytes,
                     peer_bytes=stats.peer_bytes,
                     cache_hits=hits[0],
                     **({"base_step": d.get("base_step")} if is_delta
                        else {}))
        with self._lock:
            st = self._step_stats.setdefault(
                step, {"remote_bytes": 0, "peer_bytes": 0,
                       "cache_hits": 0, "replicas": 0, "delta": is_delta})
            st["remote_bytes"] += stats.remote_bytes
            st["peer_bytes"] += stats.peer_bytes
            st["cache_hits"] += hits[0]
            st["replicas"] += 1
        for fe in manifest.files:  # free finished swap-session tables
            self.peers.discard(data_key(step, fe.name))
        self.persist(repo)
        return sdir

    @staticmethod
    def _tier_for(repo: CheckpointRepository,
                  step: int) -> Optional[Tier]:
        for tier in repo.remote_tiers:
            try:
                if repo.tier_has_step(tier, step):
                    return tier
            except BackendError:
                continue
        return None

    # ----------------------------------------------------------- per object
    def _cached_fetch(self, key: str, fetch: Callable[[], bytes],
                      stats: ExchangeStats, hits: list) -> bytes:
        """Cache read-through with per-replica remote-byte attribution:
        only the flight leader's fetch counts against this replica."""
        fetched = []

        def _fetch() -> bytes:
            data = fetch()
            fetched.append(len(data))
            return data

        data = self.cache.get_through(key, _fetch)
        if fetched:
            stats.remote_bytes += fetched[0]
        else:
            hits[0] += 1
        return data

    def _file_bytes(self, tier: Tier, step: int, fe: Any,
                    stats: ExchangeStats, hits: list) -> bytes:
        key = data_key(step, fe.name)
        if fe.nbytes <= self.peers.slice_bytes:
            data = self._cached_fetch(
                key, lambda: tier.backend.get(key), stats, hits)
        else:
            data = self.cache.peek(key)
            if data is not None:
                hits[0] += 1
            else:
                data = self.peers.fetch(
                    key, fe.nbytes,
                    lambda off, nb: tier.backend.get_range(key, off, nb),
                    stats)
                self.cache.offer(key, data)
        if len(data) != fe.nbytes:
            raise BackendError(
                f"fleet fabric assembled {fe.name} with {len(data)} B, "
                f"manifest says {fe.nbytes} B")
        return data

    # ------------------------------------------------------------- admission
    def _admit(self, repo: CheckpointRepository, step: int,
               manifest: StepManifest, files: Dict[str, bytes]) -> str:
        """Single-flight local publish: K replicas sharing one local tier
        stage and verify once. A failed leader wakes the waiters, and the
        next one retries with its own assembled bytes."""
        akey = (repo.root, step)
        while True:
            if repo._local_complete(step):
                return repo.step_dir(step)
            with self._lock:
                fl = self._admits.get(akey)
                leader = fl is None
                if leader:
                    fl = _Flight()
                    self._admits[akey] = fl
            if not leader:
                fl.event.wait(timeout=60.0)
                continue  # re-check local completeness (or take over)
            try:
                staging = repo.new_staging_dir(step)
                try:
                    for name, data in files.items():
                        # atomic write via the repository's own local
                        # backend (staging is repository-owned space)
                        repo._local.put(os.path.relpath(
                            os.path.join(staging, name), repo.root), data)
                    return repo.admit_fetched_step(
                        step, manifest, staging, source="fleet fabric")
                except BaseException:
                    shutil.rmtree(staging, ignore_errors=True)
                    raise
            finally:
                with self._lock:
                    self._admits.pop(akey, None)
                fl.event.set()

    # ------------------------------------------------------------ accounting
    def step_stats(self) -> Dict[int, Dict[str, int]]:
        with self._lock:
            return {s: dict(v) for s, v in self._step_stats.items()}

    def persist(self, repo: CheckpointRepository) -> None:
        """Write the per-step transfer ledger where the admin CLI can see
        it (``stats --fleet`` works on the repository alone, no fabric
        instance required)."""
        steps = self.step_stats()
        payload = json.dumps(
            {"steps": {str(s): v for s, v in sorted(steps.items())},
             "cache": self.cache.snapshot()},
            indent=2).encode()
        try:
            repo._local.put(FLEET_STATS_KEY, payload)
        except (BackendError, OSError):
            pass  # read-only local tier: the in-process ledger remains
