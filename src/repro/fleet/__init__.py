"""Fleet warm-start distribution fabric for serving replicas.

When a new checkpoint step lands, N serving replicas naively issue N
identical full reads against the slowest storage tier — restore traffic
at serve time is the dominant, burstiest access pattern in the
checkpoint-I/O study (arXiv 2512.24511), and ByteCheckpoint
(arXiv 2407.20143) argues training resume and fleet warm-start should
share one surface. This package is that surface, layered on the existing
:class:`~repro.storage.repository.CheckpointRepository`:

``cache``    :class:`FleetCache` — shared read-through cache tier
             (capacity-bound ``MemoryBackend``) with single-flight
             de-duplication: K concurrent restorers of one object cause
             exactly one remote read;
``peer``     :class:`PeerExchange` — bittorrent-style slice exchange:
             each replica reads a disjoint shard slice from remote and
             swaps with its peers, so remote-tier bytes stay ~1× the
             checkpoint size regardless of replica count;
``fabric``   :class:`FleetFabric` — picks cache vs. peer vs. delta-chain
             transfer per object, funnels admission through the
             repository's verified atomic publish, and persists per-step
             transfer accounting for ``storage.cli stats --fleet``.

Usage (serving)::

    from repro.fleet import FleetFabric

    fabric = FleetFabric()                 # one per host, shared
    params, stats = load_params_for_serving(
        root, template, repository=repo, fleet=fabric)
"""

from .cache import FleetCache
from .fabric import FLEET_STATS_KEY, FleetFabric
from .peer import ExchangeStats, PeerExchange

__all__ = ["FleetCache", "PeerExchange", "ExchangeStats", "FleetFabric",
           "FLEET_STATS_KEY"]
