"""Shared read-through cache tier with single-flight de-duplication.

The first layer of the fleet warm-start fabric: K concurrent restorers
asking for the same object cause exactly one remote read. The winner (the
*leader* of the key's flight) fetches, publishes the bytes into a shared
:class:`~repro.storage.backend.MemoryBackend`, and wakes the waiters; the
waiters re-check the cache instead of issuing their own remote reads.

Capacity pressure is handled by LRU eviction: an insert that overflows the
memory tier evicts least-recently-used entries until it fits. An object
larger than the whole tier passes through *uncached* — the caller still
gets its bytes, the cache just never holds them (and concurrent readers of
such an object still collapse to one remote read via the flight table).

Failure semantics: a leader whose fetch raises wakes the waiters with
nothing published; each waiter then retries the flight (one becomes the
new leader), so a flaky remote degrades to per-caller retries instead of
deadlock.

Locking: ``fleet.cache`` (rank 44) guards only the flight table and LRU
book-keeping — dict/OrderedDict mutation, never a fetch, never a sleep.
The remote read and the event wait both happen outside the lock.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Callable, Dict, Optional

from repro.analysis.locks import declares_lock
from repro.obs import trace as obs
from repro.obs.metrics import metrics as obs_metrics

from repro.storage.backend import BackendError, MemoryBackend

__all__ = ["FleetCache"]


class _Flight:
    """One in-progress fetch: waiters block on ``event`` and read the
    leader's published ``data`` directly, so even objects too large to
    cache are fetched remotely exactly once per flight."""

    __slots__ = ("event", "data")

    def __init__(self) -> None:
        self.event = threading.Event()
        self.data: Optional[bytes] = None


@declares_lock("fleet.cache", rank=44, attrs=("_lock",))
class FleetCache:
    """Read-through byte cache over a capacity-bound memory tier."""

    def __init__(self, capacity_bytes: int = 256 << 20,
                 mem: Optional[MemoryBackend] = None):
        self._mem = mem if mem is not None \
            else MemoryBackend(capacity_bytes=capacity_bytes)
        self._lock = threading.Lock()  # declared: fleet.cache (r44)
        self._flights: Dict[str, _Flight] = {}
        self._lru: "OrderedDict[str, int]" = OrderedDict()  # key -> nbytes
        self.stats = {"hits": 0, "misses": 0, "waits": 0,
                      "remote_bytes": 0, "evictions": 0, "uncached": 0}

    # ------------------------------------------------------------------ reads
    def _cached(self, key: str) -> Optional[bytes]:
        try:
            data = self._mem.get(key)
        except BackendError:
            return None
        with self._lock:
            if key in self._lru:
                self._lru.move_to_end(key)
            self.stats["hits"] += 1
        obs_metrics.inc("fleet.cache_hits")
        return data

    def get_through(self, key: str, fetch: Callable[[], bytes]) -> bytes:
        """Bytes for ``key``: from the cache, or via exactly one concurrent
        ``fetch()`` shared by every caller currently asking for ``key``."""
        while True:
            data = self._cached(key)
            if data is not None:
                return data
            with self._lock:
                fl = self._flights.get(key)
                leader = fl is None
                if leader:
                    fl = _Flight()
                    self._flights[key] = fl
                else:
                    self.stats["waits"] += 1
            if not leader:
                fl.event.wait(timeout=60.0)
                if fl.data is not None:
                    return fl.data  # leader's bytes, shared in-process
                continue  # leader failed (or timed out): retry the flight
            try:
                with obs.span("fleet.fetch", lane="fleet.fetch", key=key):
                    data = fetch()
            except BaseException:
                with self._lock:
                    self._flights.pop(key, None)
                fl.event.set()
                raise
            self._insert(key, data)
            fl.data = data
            with self._lock:
                self._flights.pop(key, None)
                self.stats["misses"] += 1
                self.stats["remote_bytes"] += len(data)
            obs_metrics.inc("fleet.remote_bytes", len(data))
            fl.event.set()
            return data

    # ---------------------------------------------------------------- inserts
    def _insert(self, key: str, data: bytes) -> None:
        """Publish ``data`` under ``key``, evicting LRU entries on capacity
        pressure; oversized objects silently pass through uncached."""
        while True:
            try:
                self._mem.put(key, data)
            except BackendError:
                victim = None
                with self._lock:
                    for k in self._lru:
                        if k != key:
                            victim = k
                            break
                    if victim is not None:
                        self._lru.pop(victim)
                        self.stats["evictions"] += 1
                    else:
                        self.stats["uncached"] += 1
                if victim is None:
                    return  # larger than the whole tier: pass through
                self._mem.delete(victim)
                continue
            with self._lock:
                self._lru[key] = len(data)
                self._lru.move_to_end(key)
            return

    def peek(self, key: str) -> Optional[bytes]:
        """Cache-only lookup (no fetch, no flight): the fabric's fast path
        for objects that normally travel the peer-exchange route."""
        return self._cached(key)

    def offer(self, key: str, data: bytes) -> None:
        """Best-effort insert of bytes obtained elsewhere (a completed
        peer exchange): stragglers arriving after the swap session ends
        get a cache hit instead of a fresh session."""
        if not self._mem.exists(key):
            self._insert(key, data)

    # ------------------------------------------------------------------ admin
    def used_bytes(self) -> int:
        return self._mem.used_bytes()

    def invalidate(self, key: str) -> None:
        with self._lock:
            self._lru.pop(key, None)
        self._mem.delete(key)

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return dict(self.stats)
