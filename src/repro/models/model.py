"""Model assembly: init / forward (train) / prefill / decode for every block
type, with ``lax.scan`` over stacked layer groups and optional remat.

The stack is described by ``cfg.layer_groups = ((pattern, count), ...)``;
each group scans ``count`` repetitions of ``pattern`` (a tuple of block
types) with parameters stacked on a leading axis. Caches mirror that
structure: ``caches[g][pos_in_pattern] = dict of (count, B, ...) arrays``.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.sharding.context import constrain
from . import layers, moe, rglru, rwkv6

ATTN_TYPES = {"full", "window", "chunked", "full_moe", "window_moe",
              "chunked_moe", "xattn"}


def attn_kind(btype: str) -> str:
    return btype.split("_")[0] if btype != "xattn" else "full"


def is_moe(btype: str) -> bool:
    return btype.endswith("_moe")


# ------------------------------------------------------------------- init
def init_block(cfg, btype: str, rng) -> Dict[str, Any]:
    ks = jax.random.split(rng, 6)
    p: Dict[str, Any] = {"ln1": layers.init_norm(cfg, cfg.d_model),
                         "ln2": layers.init_norm(cfg, cfg.d_model)}
    if btype in ATTN_TYPES:
        p["attn"] = layers.init_attention(cfg, ks[0])
        if btype == "xattn":
            p["lnx"] = layers.init_norm(cfg, cfg.d_model)
            p["xattn"] = layers.init_attention(cfg, ks[1], cross=True)
        if is_moe(btype):
            p["moe"] = moe.init_moe(cfg, ks[2])
        else:
            p["ffn"] = layers.init_ffn(cfg, ks[2])
    elif btype == "rec":
        p["rec"] = rglru.init_rglru_block(cfg, ks[0])
        p["ffn"] = layers.init_ffn(cfg, ks[1])
    elif btype == "rwkv":
        p["tmix"] = rwkv6.init_rwkv(cfg, ks[0])
        p["cmix"] = rwkv6.init_channel_mix(cfg, ks[1])
    else:
        raise ValueError(btype)
    return p


def init_params(cfg, rng) -> Dict[str, Any]:
    k_emb, k_blocks, k_fin = jax.random.split(rng, 3)
    params: Dict[str, Any] = {"embed": layers.init_embed(cfg, k_emb),
                              "ln_f": layers.init_norm(cfg, cfg.d_model)}
    groups = []
    gk = jax.random.split(k_blocks, len(cfg.layer_groups))
    for (pattern, count), kg in zip(cfg.layer_groups, gk):
        per_pos = []
        pk = jax.random.split(kg, len(pattern))
        for pos, (btype, kp) in enumerate(zip(pattern, pk)):
            lk = jax.random.split(kp, count)
            stacked = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs),
                *[init_block(cfg, btype, lk[i]) for i in range(count)])
            per_pos.append(stacked)
        groups.append(tuple(per_pos))
    params["groups"] = tuple(groups)
    return params


# --------------------------------------------------------------- block apply
def block_forward(cfg, btype: str, p, x, *, positions, n_prefix: int,
                  memory, collect_cache: bool):
    """Full-sequence apply. Returns (x, cache_or_None, aux)."""
    aux = jnp.zeros((), jnp.float32)
    cache = None
    if btype in ATTN_TYPES:
        h = layers.apply_norm(cfg, p["ln1"], x)
        a, (k, v) = layers.attention(
            cfg, p["attn"], h, positions=positions, kind=attn_kind(btype),
            n_prefix=n_prefix)
        x = x + a.astype(x.dtype)
        if btype == "xattn":
            hx = layers.apply_norm(cfg, p["lnx"], x)
            mk, mv = layers.memory_kv(cfg, p["xattn"], memory)
            x = x + layers.cross_attention(cfg, p["xattn"], hx, mk, mv).astype(x.dtype)
        h2 = layers.apply_norm(cfg, p["ln2"], x)
        if is_moe(btype):
            f, aux = moe.apply_moe(cfg, p["moe"], h2)
        else:
            f = layers.apply_ffn(cfg, p["ffn"], h2)
        x = x + f.astype(x.dtype)
        if collect_cache:
            cache = _cache_from_kv(cfg, btype, k, v)
            if btype == "xattn":
                cache["mk"], cache["mv"] = mk, mv
    elif btype == "rec":
        h = layers.apply_norm(cfg, p["ln1"], x)
        r, state = rglru.apply_rglru_block(cfg, p["rec"], h)
        x = x + r.astype(x.dtype)
        h2 = layers.apply_norm(cfg, p["ln2"], x)
        x = x + layers.apply_ffn(cfg, p["ffn"], h2).astype(x.dtype)
        if collect_cache:
            cache = {"h": state[0], "conv": state[1]}
    elif btype == "rwkv":
        B = x.shape[0]
        h = layers.apply_norm(cfg, p["ln1"], x)
        zero_last = jnp.zeros((B, cfg.d_model), x.dtype)
        t, (x_t, S) = rwkv6.time_mix(cfg, p["tmix"], h, zero_last, None)
        x = x + t.astype(x.dtype)
        h2 = layers.apply_norm(cfg, p["ln2"], x)
        c, x_c = rwkv6.channel_mix(cfg, p["cmix"], h2, zero_last)
        x = x + c.astype(x.dtype)
        if collect_cache:
            # carry raw *normed* inputs for token shift at decode time
            cache = {"x_t": h[:, -1, :], "S": S, "x_c": h2[:, -1, :]}
    else:
        raise ValueError(btype)
    return x, cache, aux


def _cache_from_kv(cfg, btype: str, k, v) -> Dict[str, jnp.ndarray]:
    """Build the decode ring/linear cache from full-sequence K/V."""
    B, S = k.shape[0], k.shape[1]
    kind = attn_kind(btype)
    if kind == "full":
        if cfg.max_decode_len:  # headroom for tokens generated after prefill
            pad = [(0, 0), (0, cfg.max_decode_len), (0, 0), (0, 0)]
            return {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
        return {"k": k, "v": v}
    T = cfg.window if kind == "window" else cfg.chunk
    if S <= T:
        pad = [(0, 0), (0, T - S), (0, 0), (0, 0)]
        return {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
    if kind == "window":
        # last T positions scattered into ring slots (abs_pos % T)
        tail_pos = jnp.arange(S - T, S)
        slots = tail_pos % T
        ck = jnp.zeros((B, T) + k.shape[2:], k.dtype).at[:, slots].set(
            k[:, -T:])
        cv = jnp.zeros((B, T) + v.shape[2:], v.dtype).at[:, slots].set(
            v[:, -T:])
        return {"k": ck, "v": cv}
    # chunked: current (possibly empty) partial chunk sits at slots [0, r)
    r = S % T
    ck = jnp.zeros((B, T) + k.shape[2:], k.dtype)
    cv = jnp.zeros((B, T) + v.shape[2:], v.dtype)
    if r:
        ck = ck.at[:, :r].set(k[:, -r:])
        cv = cv.at[:, :r].set(v[:, -r:])
    return {"k": ck, "v": cv}


def block_decode(cfg, btype: str, p, x, cache, pos):
    """Single-token apply. Returns (x, new_cache)."""
    if btype in ATTN_TYPES:
        h = layers.apply_norm(cfg, p["ln1"], x)
        a, ck, cv = layers.decode_attention(
            cfg, p["attn"], h, cache["k"], cache["v"], pos,
            mode=attn_kind(btype))
        x = x + a.astype(x.dtype)
        new_cache = dict(cache, k=ck, v=cv)
        if btype == "xattn":
            hx = layers.apply_norm(cfg, p["lnx"], x)
            x = x + layers.cross_attention(cfg, p["xattn"], hx,
                                           cache["mk"], cache["mv"]).astype(x.dtype)
        h2 = layers.apply_norm(cfg, p["ln2"], x)
        if is_moe(btype):
            f, _aux = moe.apply_moe(cfg, p["moe"], h2)
        else:
            f = layers.apply_ffn(cfg, p["ffn"], h2)
        x = x + f.astype(x.dtype)
        return x, new_cache
    if btype == "rec":
        h = layers.apply_norm(cfg, p["ln1"], x)
        r, state = rglru.apply_rglru_block(
            cfg, p["rec"], h, state=(cache["h"], cache["conv"]))
        x = x + r.astype(x.dtype)
        h2 = layers.apply_norm(cfg, p["ln2"], x)
        x = x + layers.apply_ffn(cfg, p["ffn"], h2).astype(x.dtype)
        return x, {"h": state[0], "conv": state[1]}
    if btype == "rwkv":
        h = layers.apply_norm(cfg, p["ln1"], x)
        t, (x_t, S) = rwkv6.time_mix(cfg, p["tmix"], h, cache["x_t"],
                                     cache["S"], decode=True)
        x = x + t.astype(x.dtype)
        h2 = layers.apply_norm(cfg, p["ln2"], x)
        c, x_c = rwkv6.channel_mix(cfg, p["cmix"], h2, cache["x_c"])
        x = x + c.astype(x.dtype)
        return x, {"x_t": x_t, "S": S, "x_c": x_c}
    raise ValueError(btype)


# ----------------------------------------------------------------- forward
def _embed_inputs(cfg, params, batch) -> Tuple[jnp.ndarray, int, Any]:
    """Returns (hidden (B,S,d), n_prefix, memory)."""
    tokens = batch["tokens"]
    x = layers.embed_tokens(cfg, params["embed"], tokens)
    x = x * math.sqrt(cfg.d_model)
    n_prefix = 0
    memory = None
    if cfg.n_prefix_embeds:  # VLM: prepend (stubbed) patch embeddings
        prefix = batch["prefix_embeds"].astype(x.dtype)
        x = jnp.concatenate([prefix, x], axis=1)
        n_prefix = cfg.n_prefix_embeds
    if cfg.n_memory_embeds:  # audio: cross-attention conditioning memory
        memory = batch["memory_embeds"].astype(x.dtype)
    x = constrain(x, P(("pod", "data"), None, None))
    return x, n_prefix, memory


def forward(cfg, params, batch, *, collect_caches: bool = False):
    """Full-sequence forward. Returns (logits, aux_loss, caches)."""
    x, n_prefix, memory = _embed_inputs(cfg, params, batch)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    aux_total = jnp.zeros((), jnp.float32)
    caches: List[Tuple] = []

    for (pattern, count), stacked in zip(cfg.layer_groups, params["groups"]):
        def body(carry, xs):
            h, aux = carry
            new_caches = []
            for btype, pp in zip(pattern, xs):
                h, cache, a = block_forward(
                    cfg, btype, pp, h, positions=positions,
                    n_prefix=n_prefix, memory=memory,
                    collect_cache=collect_caches)
                new_caches.append(cache)
                aux = aux + a
            return (h, aux), tuple(new_caches)

        if cfg.seq_parallel_residual and S % 128 == 0:
            inner = body

            def body(carry, xs, _inner=inner):
                h, aux = carry
                # Megatron-SP: residual stream stays seq-sharded over
                # 'model' at block boundaries; GSPMD turns the TP boundary
                # all-reduces into reduce-scatter + all-gather pairs.
                h = constrain(h, P(("pod", "data"), "model", None))
                (h, aux), cc = _inner((h, aux), xs)
                h = constrain(h, P(("pod", "data"), "model", None))
                return (h, aux), cc

        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux_total), group_caches = jax.lax.scan(
            body, (x, aux_total), stacked,
            unroll=count if cfg.analysis_unroll else 1)
        caches.append(group_caches)

    x = layers.apply_norm(cfg, params["ln_f"], x)
    logits = layers.logits_from_hidden(cfg, params["embed"], x)
    return logits, aux_total, (tuple(caches) if collect_caches else None)


def decode(cfg, params, batch, caches, pos):
    """One-token decode. batch['tokens']: (B,1[,K]). Returns (logits, caches)."""
    x = layers.embed_tokens(cfg, params["embed"], batch["tokens"])
    x = x * math.sqrt(cfg.d_model)
    new_groups = []
    for (pattern, count), stacked, gcache in zip(
            cfg.layer_groups, params["groups"], caches):
        def body(h, xs):
            pp_tuple, cc_tuple = xs
            new_cc = []
            for btype, pp, cc in zip(pattern, pp_tuple, cc_tuple):
                h, nc = block_decode(cfg, btype, pp, h, cc, pos)
                new_cc.append(nc)
            return h, tuple(new_cc)

        x, new_gcache = jax.lax.scan(
            body, x, (stacked, gcache),
            unroll=count if cfg.analysis_unroll else 1)
        new_groups.append(new_gcache)
    x = layers.apply_norm(cfg, params["ln_f"], x)
    logits = layers.logits_from_hidden(cfg, params["embed"], x)
    return logits, tuple(new_groups)


# ------------------------------------------------------------------- loss
def loss_fn(cfg, params, batch):
    """Next-token cross-entropy (+ MoE aux). Returns scalar loss."""
    logits, aux, _ = forward(cfg, params, batch)
    tokens = batch["tokens"]
    if cfg.n_prefix_embeds:  # loss only over text positions
        logits = logits[:, cfg.n_prefix_embeds:]
    tgt = tokens[:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, tgt[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    ce = (logz - gold).mean()
    return ce + cfg.router_aux_coef * aux


# --------------------------------------------------------------- accounting
def count_params_analytic(cfg, active_only: bool = False) -> int:
    d, f, V = cfg.d_model, cfg.d_ff, cfg.vocab
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    n_emb = cfg.n_codebooks or 1
    total = n_emb * V * d
    if not cfg.tie_embeddings:
        total += d * n_emb * V

    def ffn_params():
        mats = 2 if cfg.act == "gelu_mlp" else 3
        return mats * d * f

    def attn_params():
        return d * H * hd + 2 * d * KV * hd + H * hd * d

    for pattern, count in cfg.layer_groups:
        for btype in pattern:
            n = 2 * d  # norms
            if btype in ATTN_TYPES:
                n += attn_params()
                if btype == "xattn":
                    n += attn_params() + d
                if is_moe(btype):
                    E = cfg.top_k if active_only else cfg.n_experts
                    n += E * 3 * d * f + d * cfg.n_experts
                    if cfg.shared_expert:
                        n += ffn_params()
                else:
                    n += ffn_params()
            elif btype == "rec":
                dr = cfg.d_rnn
                n += 2 * d * dr + 2 * dr * dr + dr * d + cfg.conv_width * dr
                n += ffn_params()
            elif btype == "rwkv":
                n += 5 * d * d + d * (5 * rwkv6.MIX_LORA) \
                    + 5 * rwkv6.MIX_LORA * d + 2 * d * cfg.rwkv_decay_lora
                n += 2 * d * f + d * d  # channel mix
            total += n * count
    return int(total)
