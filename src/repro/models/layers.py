"""Shared neural layers: norms, RoPE, GQA attention (full / sliding-window /
chunked / prefix-LM), cross-attention, gated FFNs.

Everything is functional: params are nested dicts of ``jnp`` arrays; init
functions build them, apply functions consume them. Activation sharding
constraints go through :func:`repro.sharding.context.constrain` so the same
code runs un-meshed (smoke tests) and under the production mesh (dry-run).
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.context import constrain
from jax.sharding import PartitionSpec as P


# --------------------------------------------------------------------- norms
def init_norm(cfg, d: int) -> Dict[str, Any]:
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32)}


def apply_norm(cfg, p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------- rope
def rope_frequencies(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (...,S,hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- masks
def make_mask(seq_len: int, kind: str, *, window: int = 0, chunk: int = 0,
              n_prefix: int = 0) -> jnp.ndarray:
    """(S, S) boolean attention mask. ``n_prefix`` positions attend
    bidirectionally (prefix-LM, PaliGemma)."""
    i = jnp.arange(seq_len)[:, None]
    j = jnp.arange(seq_len)[None, :]
    causal = j <= i
    if kind == "full":
        m = causal
    elif kind == "window":
        assert window > 0
        m = causal & (j > i - window)
    elif kind == "chunked":
        assert chunk > 0
        m = causal & ((i // chunk) == (j // chunk))
    else:
        raise ValueError(kind)
    if n_prefix:
        m = m | ((i < n_prefix) & (j < n_prefix))
    return m


# ----------------------------------------------------------------- attention
def init_attention(cfg, rng, *, cross: bool = False) -> Dict[str, Any]:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    k_q, k_k, k_v, k_o = jax.random.split(rng, 4)
    s = 1.0 / math.sqrt(d)
    dt = jnp.dtype(cfg.dtype)
    p = {
        "wq": (jax.random.normal(k_q, (d, H * hd)) * s).astype(dt),
        "wk": (jax.random.normal(k_k, (d, KV * hd)) * s).astype(dt),
        "wv": (jax.random.normal(k_v, (d, KV * hd)) * s).astype(dt),
        "wo": (jax.random.normal(k_o, (H * hd, d)) * (s / math.sqrt(2 * cfg.n_layers))).astype(dt),
    }
    if cfg.use_bias:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((KV * hd,), dt)
        p["bv"] = jnp.zeros((KV * hd,), dt)
        p["bo"] = jnp.zeros((d,), dt)
    return p


def _proj(x, w, b=None):
    y = x @ w
    if b is not None:
        y = y + b
    return y


def _sdpa(q, k, v, mask) -> jnp.ndarray:
    """q: (B,S,H,hd), k/v: (B,T,KV,hd); GQA via head grouping."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    qg = q.reshape(B, S, KV, rep, hd)
    logits = jnp.einsum("bskrh,btkh->bkrst", qg, k) / math.sqrt(hd)
    logits = logits.astype(jnp.float32)
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkrst,btkh->bskrh", probs, v)
    return out.reshape(B, S, H * hd)


def _allowed(qpos, kpos, kind: str, window: int, chunk: int, n_prefix: int):
    """(Sq, Sk) boolean visibility between absolute positions."""
    i = qpos[:, None]
    j = kpos[None, :]
    m = j <= i
    if kind == "window":
        m = m & (j > i - window)
    elif kind == "chunked":
        m = m & ((i // chunk) == (j // chunk))
    if n_prefix:
        m = m | ((i < n_prefix) & (j < n_prefix))
    return m


_DIRECT_SDPA_MAX_SEQ = 2048  # above this, use the online-softmax blocked path


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, kind, window, chunk, n_prefix, kv_block, unroll):
    out, _stats = _flash_fwd_impl(q, k, v, kind, window, chunk, n_prefix,
                                  kv_block, unroll)
    return out


def _flash_fwd_impl(q, k, v, kind, window, chunk, n_prefix, kv_block, unroll):
    """Online-softmax forward. q: (B,S,KV,rep,hd) pre-scaled f32;
    k/v: (B,S,KV,hd). Returns out (B,S,KV,rep,hd) f32 + (m, l) row stats."""
    B, S, KV, rep, hd = q.shape
    kvb = min(kv_block, S)
    nk = S // kvb
    f32 = jnp.float32
    qpos = jnp.arange(S)
    k_blocks = k.reshape(B, nk, kvb, KV, hd).transpose(1, 0, 2, 3, 4)
    v_blocks = v.reshape(B, nk, kvb, KV, hd).transpose(1, 0, 2, 3, 4)
    kpos_blocks = jnp.arange(S).reshape(nk, kvb)
    m0 = jnp.full((B, S, KV, rep), -1e30, f32)
    l0 = jnp.zeros((B, S, KV, rep), f32)
    a0 = jnp.zeros((B, S, KV, rep, hd), f32)

    def step(carry, xs):
        m, l, acc = carry
        k_j, v_j, kpos = xs
        logits = jnp.einsum("bskrh,btkh->bskrt", q, k_j.astype(f32))
        allow = _allowed(qpos, kpos, kind, window, chunk, n_prefix)
        logits = jnp.where(allow[None, :, None, None, :], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(-1))
        scale = jnp.exp(m - m_new)
        pexp = jnp.exp(logits - m_new[..., None])
        pexp = jnp.where(allow[None, :, None, None, :], pexp, 0.0)
        l = l * scale + pexp.sum(-1)
        acc = acc * scale[..., None] + jnp.einsum(
            "bskrt,btkh->bskrh", pexp, v_j.astype(f32))
        return (m_new, l, acc), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (k_blocks, v_blocks, kpos_blocks),
                                  unroll=nk if unroll else 1)
    out = acc / (l[..., None] + 1e-30)
    return out, (m, l)


def _flash_fwd(q, k, v, kind, window, chunk, n_prefix, kv_block, unroll):
    out, (m, l) = _flash_fwd_impl(q, k, v, kind, window, chunk, n_prefix,
                                  kv_block, unroll)
    return out, (q, k, v, out, m, l)


def _flash_bwd(kind, window, chunk, n_prefix, kv_block, unroll, res, dout):
    """FlashAttention-2-style backward: recompute P blockwise from saved row
    stats — nothing S×S is ever stored (this is the whole point: the naive
    scan VJP keeps per-block logits alive and blows past HBM)."""
    q, k, v, out, m, l = res
    B, S, KV, rep, hd = q.shape
    kvb = min(kv_block, S)
    nk = S // kvb
    f32 = jnp.float32
    dout = dout.astype(f32)
    qpos = jnp.arange(S)
    k_blocks = k.reshape(B, nk, kvb, KV, hd).transpose(1, 0, 2, 3, 4)
    v_blocks = v.reshape(B, nk, kvb, KV, hd).transpose(1, 0, 2, 3, 4)
    kpos_blocks = jnp.arange(S).reshape(nk, kvb)
    D = jnp.sum(dout * out, axis=-1)                       # (B,S,KV,rep)
    linv = 1.0 / (l + 1e-30)

    def step(dq, xs):
        k_j, v_j, kpos = xs
        logits = jnp.einsum("bskrh,btkh->bskrt", q, k_j.astype(f32))
        allow = _allowed(qpos, kpos, kind, window, chunk, n_prefix)
        p = jnp.exp(logits - m[..., None]) * linv[..., None]
        p = jnp.where(allow[None, :, None, None, :], p, 0.0)
        dv_j = jnp.einsum("bskrt,bskrh->btkh", p, dout)
        dp = jnp.einsum("bskrh,btkh->bskrt", dout, v_j.astype(f32))
        ds = p * (dp - D[..., None])
        dq = dq + jnp.einsum("bskrt,btkh->bskrh", ds, k_j.astype(f32))
        dk_j = jnp.einsum("bskrt,bskrh->btkh", ds, q)
        return dq, (dk_j, dv_j)

    dq0 = jnp.zeros_like(q)
    dq, (dk_blocks, dv_blocks) = jax.lax.scan(
        step, dq0, (k_blocks, v_blocks, kpos_blocks),
        unroll=nk if unroll else 1)
    dk = dk_blocks.transpose(1, 0, 2, 3, 4).reshape(B, S, KV, hd)
    dv = dv_blocks.transpose(1, 0, 2, 3, 4).reshape(B, S, KV, hd)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)


def blocked_sdpa(q, k, v, *, kind: str = "full", window: int = 0,
                 chunk: int = 0, n_prefix: int = 0, kv_block: int = 1024,
                 unroll: bool = False) -> jnp.ndarray:
    """Flash-style attention: ``lax.scan`` over KV blocks with running
    (max, denom, acc) — never materializes the (S,S) logits, and the
    custom-VJP backward recomputes P from saved row stats. Pure-XLA
    production path; ``repro.kernels.flash_attention`` is the Pallas TPU
    twin validated against this in interpret mode."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    kvb = min(kv_block, S)
    pad = (-S) % kvb
    if pad:  # e.g. prefix-LM seq = text + patch prefix; padded rows are
        # sliced off below, padded keys sit beyond every real query
        # (causal-masked), and their zero cotangents contribute no gradient.
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    qg = (q.reshape(B, Sp, KV, rep, hd).astype(jnp.float32)
          * (1.0 / math.sqrt(hd)))
    out = _flash(qg, k, v, kind, window, chunk, n_prefix, kvb, unroll)
    out = out.reshape(B, Sp, H * hd)
    if pad:
        out = out[:, :S]
    return out.astype(q.dtype)


def full_seq_sdpa(q, k, v, *, kind: str, window: int, chunk: int,
                  n_prefix: int, unroll: bool = False,
                  kv_block: int = 1024) -> jnp.ndarray:
    """Dispatch: direct masked SDPA for short sequences (cheap, exact),
    blocked online-softmax beyond ``_DIRECT_SDPA_MAX_SEQ``."""
    S = q.shape[1]
    if S <= _DIRECT_SDPA_MAX_SEQ:
        mask = make_mask(S, "full" if kind == "full" else kind,
                         window=window, chunk=chunk, n_prefix=n_prefix)
        return _sdpa(q, k, v, mask)
    return blocked_sdpa(q, k, v, kind=kind, window=window, chunk=chunk,
                        n_prefix=n_prefix, unroll=unroll, kv_block=kv_block)


def attention(cfg, p, x, *, positions, kind: str = "full",
              n_prefix: int = 0, use_rope: bool = True) -> jnp.ndarray:
    """Full-sequence self-attention (train / prefill). x: (B,S,d)."""
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = _proj(x, p["wq"], p.get("bq")).reshape(B, S, H, hd)
    k = _proj(x, p["wk"], p.get("bk")).reshape(B, S, KV, hd)
    v = _proj(x, p["wv"], p.get("bv")).reshape(B, S, KV, hd)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.ulysses_attention and S % 128 == 0:
        # Ulysses-style sequence parallelism: enter attention with the SEQ
        # dim sharded over 'model' (GSPMD inserts the all-to-all) so each
        # device holds whole heads/head_dims for its query slice — no
        # partial-logit all-reduce per flash block.
        seq_spec = P(("pod", "data"), "model", None, None)
        q = constrain(q, seq_spec)
        k = constrain(k, seq_spec)
        v = constrain(v, seq_spec)
    out = full_seq_sdpa(q, k, v, kind=kind, window=cfg.window,
                        chunk=cfg.chunk, n_prefix=n_prefix,
                        unroll=cfg.analysis_unroll,
                        kv_block=cfg.attn_kv_block)
    if cfg.ulysses_attention and S % 128 == 0:
        out = constrain(out, P(("pod", "data"), "model", None))
    return _proj(out, p["wo"], p.get("bo")), (k, v)


def decode_attention(cfg, p, x, cache_k, cache_v, pos, *,
                     mode: str = "full", use_rope: bool = True):
    """Single-token decode. x: (B,1,d); cache_k/v: (B,T,KV,hd).

    ``mode``: "full" — cache holds absolute positions 0..T-1;
    "window"/"chunked" — the cache is a ring buffer of length T (= window or
    chunk size); ``pos`` is the new token's absolute position (RoPE is
    positionally exact because keys are rotated before storage; softmax is
    permutation-invariant over the ring).
    """
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    T = cache_k.shape[1]
    q = _proj(x, p["wq"], p.get("bq")).reshape(B, 1, H, hd)
    k = _proj(x, p["wk"], p.get("bk")).reshape(B, 1, KV, hd)
    v = _proj(x, p["wv"], p.get("bv")).reshape(B, 1, KV, hd)
    if use_rope:
        posv = jnp.full((B, 1), pos)
        q = apply_rope(q, posv, cfg.rope_theta)
        k = apply_rope(k, posv, cfg.rope_theta)
    slot = pos if mode == "full" else pos % T
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, slot, axis=1)
    from repro.sharding import context as _shctx
    if _shctx.seq_axis_active():
        cache_spec = P(None, "seq", None, None)   # context parallelism (B=1)
    elif cfg.decode_kv_seq_shard and T % 128 == 0:
        # beyond-paper: keep heads/hd whole, shard the cache depth instead —
        # attention over a seq-sharded cache needs only O(B·H) softmax-stat
        # collectives instead of all-gathering the cache every layer.
        cache_spec = P(("pod", "data"), "model", None, None)
    else:
        cache_spec = P(("pod", "data"), None, None, None)
    cache_k = constrain(cache_k, cache_spec)
    cache_v = constrain(cache_v, cache_spec)
    idx = jnp.arange(T)
    if mode == "window":
        valid = idx < jnp.minimum(pos + 1, T)     # rolling window
    elif mode == "chunked":
        valid = idx <= pos % T                    # resets at chunk boundary
    else:
        valid = idx <= pos
    mask = valid[None, None, None, None, :]  # (1,1,1,1,T) over (b,k,r,s,t)
    out = _sdpa(q, cache_k, cache_v, mask)
    return _proj(out, p["wo"], p.get("bo")), cache_k, cache_v


def cross_attention(cfg, p, x, mem_k, mem_v) -> jnp.ndarray:
    """Cross-attention to precomputed memory K/V. x: (B,S,d)."""
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = _proj(x, p["wq"], p.get("bq")).reshape(B, S, H, hd)
    out = _sdpa(q, mem_k, mem_v, None)
    return _proj(out, p["wo"], p.get("bo"))


def memory_kv(cfg, p, memory) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Project conditioning memory to K/V once (prefill-time)."""
    B, M, _ = memory.shape
    KV, hd = cfg.n_kv_heads, cfg.hd
    k = _proj(memory, p["wk"], p.get("bk")).reshape(B, M, KV, hd)
    v = _proj(memory, p["wv"], p.get("bv")).reshape(B, M, KV, hd)
    return k, v


# ----------------------------------------------------------------------- ffn
def init_ffn(cfg, rng, d_ff: Optional[int] = None) -> Dict[str, Any]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(rng, 3)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f) / math.sqrt(2 * cfg.n_layers)
    p = {"w_up": (jax.random.normal(k2, (d, f)) * s_in).astype(dt),
         "w_down": (jax.random.normal(k3, (f, d)) * s_out).astype(dt)}
    if cfg.act != "gelu_mlp":  # gated variants
        p["w_gate"] = (jax.random.normal(k1, (d, f)) * s_in).astype(dt)
    if cfg.use_bias:
        p["b_up"] = jnp.zeros((f,), dt)
        p["b_down"] = jnp.zeros((d,), dt)
    return p


def apply_ffn(cfg, p, x) -> jnp.ndarray:
    up = _proj(x, p["w_up"], p.get("b_up"))
    if cfg.act == "gelu_mlp":
        h = jax.nn.gelu(up)
    else:
        gate = x @ p["w_gate"]
        if cfg.act == "silu":
            h = jax.nn.silu(gate) * up
        elif cfg.act == "gelu":
            h = jax.nn.gelu(gate) * up
        elif cfg.act == "relu_sq":
            h = jnp.square(jax.nn.relu(gate)) * up
        else:
            raise ValueError(cfg.act)
    h = constrain(h, P(("pod", "data"), None, "model"))
    return _proj(h, p["w_down"], p.get("b_down"))


# ----------------------------------------------------------------- embedding
def init_embed(cfg, rng) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.dtype)
    n_emb = cfg.n_codebooks or 1
    k_e, k_h = jax.random.split(rng)
    p = {"embed": (jax.random.normal(k_e, (n_emb * cfg.vocab, cfg.d_model))
                   * 0.02).astype(dt)}
    if not cfg.tie_embeddings:
        p["head"] = (jax.random.normal(k_h, (cfg.d_model,
                                             (cfg.n_codebooks or 1) * cfg.vocab))
                     * 0.02).astype(dt)
    return p


def embed_tokens(cfg, p, tokens) -> jnp.ndarray:
    """tokens: (B,S) or (B,S,n_codebooks) -> (B,S,d)."""
    if cfg.n_codebooks:
        offs = jnp.arange(cfg.n_codebooks) * cfg.vocab
        e = jnp.take(p["embed"], tokens + offs, axis=0)  # (B,S,K,d)
        return e.sum(axis=2)
    return jnp.take(p["embed"], tokens, axis=0)


def logits_from_hidden(cfg, p, x) -> jnp.ndarray:
    if cfg.tie_embeddings:
        logits = x @ p["embed"].T
    else:
        logits = x @ p["head"]
    if cfg.n_codebooks:
        B, S, _ = x.shape
        logits = logits.reshape(B, S, cfg.n_codebooks, cfg.vocab)
    return logits
