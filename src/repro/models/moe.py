"""Mixture-of-Experts FFN with capacity-based dispatch (GShard-style).

Tokens are split into groups of ``cfg.moe_group_size``; within each group a
top-k router assigns tokens to experts up to a capacity
``C = ceil(group * top_k * capacity_factor / E)``. Dispatch/combine are dense
einsums so the whole layer is one differentiable XLA program; under the
production mesh the expert dimension is sharded over the ``model`` axis
(expert parallelism) and groups over ``data``, so GSPMD materializes the
dispatch as an all-to-all — the communication pattern the paper's MoE
checkpoints shard along (expert-parallel shard boundaries).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.context import constrain
from jax.sharding import PartitionSpec as P

from . import layers


def init_moe(cfg, rng) -> Dict[str, Any]:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 5)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f) / math.sqrt(2 * cfg.n_layers)
    p = {
        "router": (jax.random.normal(ks[0], (d, E)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, d, f)) * s_in).astype(dt),
        "w_up": (jax.random.normal(ks[2], (E, d, f)) * s_in).astype(dt),
        "w_down": (jax.random.normal(ks[3], (E, f, d)) * s_out).astype(dt),
    }
    if cfg.shared_expert:
        p["shared"] = layers.init_ffn(cfg, ks[4])
    return p


def capacity(cfg, group: int) -> int:
    c = math.ceil(group * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(c, 1)


def route(cfg, p, x_grouped) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x_grouped: (G, S, d) -> dispatch (G,S,E,C), combine (G,S,E,C), aux loss."""
    G, S, d = x_grouped.shape
    E, K = cfg.n_experts, cfg.top_k
    C = capacity(cfg, S)
    logits = (x_grouped.astype(jnp.float32) @ p["router"])       # (G,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, topk_idx = jax.lax.top_k(probs, K)                # (G,S,K)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    # expert assignment one-hots: (G,S,K,E)
    assign = jax.nn.one_hot(topk_idx, E, dtype=jnp.float32)
    # position of each (token, k) within its expert queue
    flat = assign.reshape(G, S * K, E)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(G, S, K, E)
    keep = pos_in_expert < C
    assign = assign * keep
    pos = jnp.einsum("gske->gsk", pos_in_expert * assign).astype(jnp.int32)
    cap_onehot = jax.nn.one_hot(pos, C, dtype=jnp.float32)       # (G,S,K,C)
    disp = jnp.einsum("gske,gskc->gsec", assign, cap_onehot)     # (G,S,E,C)
    comb = jnp.einsum("gske,gskc,gsk->gsec", assign, cap_onehot,
                      gate_vals.astype(jnp.float32))
    # Switch-style load-balance auxiliary loss
    density = assign.sum(2).mean(1)                               # (G,E) frac
    router_prob = probs.mean(1)                                   # (G,E)
    aux = (density * router_prob).sum(-1).mean() * (E ** 2) / K
    return disp, comb, aux


def apply_moe(cfg, p, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,S,d) -> (out, aux_loss)."""
    B, S, d = x.shape
    gs = min(cfg.moe_group_size, B * S)
    tokens = B * S
    G = max(tokens // gs, 1)
    gs = tokens // G
    xg = x.reshape(G, gs, d)
    xg = constrain(xg, P(("pod", "data"), None, None))
    disp, comb, aux = route(cfg, p, xg)
    dt = x.dtype
    expert_in = jnp.einsum("gsec,gsd->egcd", disp.astype(dt), xg)
    expert_in = constrain(expert_in, P("model", ("pod", "data"), None, None))
    h = jnp.einsum("egcd,edf->egcf", expert_in, p["w_gate"])
    u = jnp.einsum("egcd,edf->egcf", expert_in, p["w_up"])
    if cfg.act == "gelu":
        h = jax.nn.gelu(h) * u
    else:
        h = jax.nn.silu(h) * u
    expert_out = jnp.einsum("egcf,efd->egcd", h, p["w_down"])
    expert_out = constrain(expert_out, P("model", ("pod", "data"), None, None))
    out = jnp.einsum("gsec,egcd->gsd", comb.astype(dt), expert_out)
    out = out.reshape(B, S, d)
    if cfg.shared_expert:
        out = out + layers.apply_ffn(cfg, p["shared"], x)
    return out, aux.astype(jnp.float32)
