"""RG-LRU recurrent block (Griffin / RecurrentGemma). [arXiv:2402.19427]

    r_t = σ(x_t W_a + b_a)            (recurrence gate)
    i_t = σ(x_t W_x + b_x)            (input gate)
    a_t = exp(-c · softplus(Λ) · r_t) (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ u_t)

The recurrence is elementwise-diagonal, so train/prefill uses
``jax.lax.associative_scan`` over time (log-depth on TPU); decode is a single
step. The block follows Griffin: (norm → [gelu gate ‖ conv1d→RG-LRU] → merge
→ out-proj) with residual, then a gated-MLP sub-block handled by the caller.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

C_FACTOR = 8.0


def init_rglru_block(cfg, rng) -> Dict[str, Any]:
    d, dr = cfg.d_model, cfg.d_rnn
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 7)
    s = 1.0 / math.sqrt(d)
    return {
        "w_gate_branch": (jax.random.normal(ks[0], (d, dr)) * s).astype(dt),
        "w_rec_in": (jax.random.normal(ks[1], (d, dr)) * s).astype(dt),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, dr)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((dr,), dt),
        "lam": jax.random.uniform(ks[3], (dr,), jnp.float32, 0.5, 4.0),
        "w_a": (jax.random.normal(ks[4], (dr, dr)) * (1 / math.sqrt(dr))).astype(dt),
        "b_a": jnp.zeros((dr,), jnp.float32),
        "w_x": (jax.random.normal(ks[5], (dr, dr)) * (1 / math.sqrt(dr))).astype(dt),
        "b_x": jnp.zeros((dr,), jnp.float32),
        "w_out": (jax.random.normal(ks[6], (dr, d)) * (1 / math.sqrt(dr))
                  / math.sqrt(2 * cfg.n_layers)).astype(dt),
    }


def causal_conv1d(p, x, conv_state=None):
    """Depthwise causal conv, width W. x: (B,T,dr).

    conv_state: (B, W-1, dr) trailing inputs from the previous segment
    (decode); returns (y, new_conv_state)."""
    W = p["conv_w"].shape[0]
    B, T, dr = x.shape
    if conv_state is None:
        conv_state = jnp.zeros((B, W - 1, dr), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)           # (B, T+W-1, dr)
    y = sum(xp[:, i:i + T, :] * p["conv_w"][i] for i in range(W))
    new_state = xp[:, -(W - 1):, :] if W > 1 else conv_state
    return y + p["conv_b"], new_state


def rg_lru(p, u, h0=None):
    """u: (B,T,dr) gated inputs; h0: (B,dr) carried state. -> (y, h_last)."""
    f32 = jnp.float32
    uf = u.astype(f32)
    r = jax.nn.sigmoid(uf @ p["w_a"].astype(f32) + p["b_a"])
    i = jax.nn.sigmoid(uf @ p["w_x"].astype(f32) + p["b_x"])
    log_a = -C_FACTOR * jax.nn.softplus(p["lam"]) * r        # (B,T,dr) ≤ 0
    a = jnp.exp(log_a)
    # sqrt(1-a^2) with guard; gated input
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)
    if h0 is not None:
        # fold carried state in as a virtual step 0: b_0 += a_0 * h0
        b = b.at[:, 0, :].add(a[:, 0, :] * h0.astype(f32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_sc, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(u.dtype), h[:, -1, :]


def apply_rglru_block(cfg, p, x, state=None):
    """x: (B,T,d). state: None or (h (B,dr), conv (B,W-1,dr)).
    Returns (out (B,T,d), new_state)."""
    gate = jax.nn.gelu(x @ p["w_gate_branch"])
    u = x @ p["w_rec_in"]
    h0 = conv_state = None
    if state is not None:
        h0, conv_state = state
    u, new_conv = causal_conv1d(p, u, conv_state)
    rec, h_last = rg_lru(p, u, h0)
    out = (gate * rec) @ p["w_out"]
    return out, (h_last.astype(jnp.float32), new_conv)
