"""RWKV6 "Finch" block: data-dependent decay linear recurrence.
[arXiv:2404.05892]

The WKV6 recurrence per head (state S ∈ R^{dk×dv}):

    o_t = r_t · (S_{t-1} + diag(u) k_t^T v_t)
    S_t = diag(w_t) S_{t-1} + k_t^T v_t,   w_t = exp(-exp(x_w,t)) ∈ (0,1)

Training/prefill uses a **chunked parallel form** (TPU-friendly: the MXU sees
(C×C) matmuls instead of a length-T scalar scan): within a chunk of length C
intra-chunk contributions use pairwise log-decay factors; the carried state is
propagated chunk-to-chunk by a ``lax.scan``. Per-step log-decays are clamped
to ≥ ``LOG_DECAY_CLAMP`` so the intra-chunk exp() factors stay inside fp32
range — decays below e^-6/step are numerically zero after a few steps anyway
(documented deviation from the CUDA kernel, which does the recurrence
stepwise). ``reference_wkv6`` is the exact stepwise oracle used by tests.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

LOG_DECAY_CLAMP = -5.0  # e^-5/step ≈ 0.0067 — effectively zero within a chunk
MIX_LORA = 32


def init_rwkv(cfg, rng) -> Dict[str, Any]:
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    H = d // hs
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 12)
    s = 1.0 / math.sqrt(d)
    p = {
        # token-shift data-dependent mixing (5 targets: r,w,k,v,g)
        "mix_base": (jax.random.uniform(ks[0], (5, d)) * 0.5).astype(jnp.float32),
        "mix_w1": (jax.random.normal(ks[1], (d, 5 * MIX_LORA)) * s).astype(dt),
        "mix_w2": (jax.random.normal(ks[2], (5, MIX_LORA, d)) * 0.01).astype(dt),
        # data-dependent decay LoRA
        "w0": (jax.random.normal(ks[3], (d,)) * 0.5 - 0.6).astype(jnp.float32),
        "w_a": (jax.random.normal(ks[4], (d, cfg.rwkv_decay_lora)) * s).astype(dt),
        "w_b": (jax.random.normal(ks[5], (cfg.rwkv_decay_lora, d)) * 0.01).astype(dt),
        # bonus
        "u": (jax.random.normal(ks[6], (H, hs)) * 0.1).astype(jnp.float32),
        # projections
        "wr": (jax.random.normal(ks[7], (d, d)) * s).astype(dt),
        "wk": (jax.random.normal(ks[8], (d, d)) * s).astype(dt),
        "wv": (jax.random.normal(ks[9], (d, d)) * s).astype(dt),
        "wg": (jax.random.normal(ks[10], (d, d)) * s).astype(dt),
        "wo": (jax.random.normal(ks[11], (d, d)) * s
               / math.sqrt(2 * cfg.n_layers)).astype(dt),
        "ln_x_scale": jnp.ones((d,), jnp.float32),  # per-head group-norm
    }
    return p


def init_channel_mix(cfg, rng) -> Dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 3)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    return {
        "mix_k": (jax.random.uniform(ks[0], (d,)) * 0.5).astype(jnp.float32),
        "mix_r": (jax.random.uniform(ks[0], (d,)) * 0.5).astype(jnp.float32),
        "w_in": (jax.random.normal(ks[1], (d, f)) * s_in).astype(dt),
        "w_out": (jax.random.normal(ks[2], (f, d)) * s_out
                  / math.sqrt(2 * cfg.n_layers)).astype(dt),
        "w_r": (jax.random.normal(ks[2], (d, d)) * s_in).astype(dt),
    }


# --------------------------------------------------------------------- wkv6
def _group_norm_heads(x, scale, H: int, eps: float = 64e-5):
    """Per-head group norm over the output (RWKV's ln_x). x: (B,T,d)."""
    B, T, d = x.shape
    xs = x.reshape(B, T, H, d // H).astype(jnp.float32)
    mu = xs.mean(-1, keepdims=True)
    var = xs.var(-1, keepdims=True)
    xs = (xs - mu) * jax.lax.rsqrt(var + eps)
    return (xs.reshape(B, T, d) * scale).astype(x.dtype)


def chunked_wkv6(r, k, v, lw, u, chunk: int
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunk-parallel WKV6 as a ``lax.scan`` over chunks.

    r,k,v: (B,T,H,hs); lw: (B,T,H,hs) log-decays (≤0); u: (H,hs).
    Returns (out (B,T,H,hs), final state (B,H,hs,hs)).

    Within a chunk the contributions are (C×C) matmuls (MXU-friendly); the
    carried state propagates sequentially. Live memory per step is
    O(B·H·C²), independent of T — the full-T parallel form would need
    O(B·H·T·C) which does not fit HBM at production batch sizes.
    The exp() range is bounded by C·|LOG_DECAY_CLAMP| < 88 (fp32-safe).
    """
    B, T, H, hs = r.shape
    C = chunk
    assert T % C == 0, f"T={T} must be divisible by chunk={C}"
    assert C * (-LOG_DECAY_CLAMP) < 88.0, "intra-chunk exp() would overflow"
    N = T // C
    f32 = jnp.float32
    # (N, B, H, C, hs) scan layout
    def to_scan(a):
        return a.astype(f32).reshape(B, N, C, H, hs).transpose(1, 0, 3, 2, 4)
    r_s, k_s, v_s = to_scan(r), to_scan(k), to_scan(v)
    lw_s = to_scan(jnp.clip(lw, LOG_DECAY_CLAMP, 0.0))
    uf = u.astype(f32)
    idx = jnp.arange(C)
    strict = idx[:, None] > idx[None, :]

    def step(S, xs):
        r_c, k_c, v_c, lw_c = xs                       # (B,H,C,hs)
        cum = jnp.cumsum(lw_c, axis=2)                 # Σ_{u<=t}
        ex = cum - lw_c                                # Σ_{u<t}
        total = cum[:, :, -1, :]                       # (B,H,hs)
        q_t = r_c * jnp.exp(ex)
        k_t = k_c * jnp.exp(-cum)
        scores = jnp.einsum("bhci,bhdi->bhcd", q_t, k_t)
        scores = jnp.where(strict, scores, 0.0)
        diag = jnp.einsum("bhci,hi,bhci->bhc", r_c, uf, k_c)
        intra = jnp.einsum("bhcd,bhdj->bhcj", scores, v_c) \
            + diag[..., None] * v_c
        inter = jnp.einsum("bhci,bhij->bhcj", q_t, S)
        k_state = k_c * jnp.exp(total[:, :, None, :] - cum)
        S_new = S * jnp.exp(total)[..., :, None] \
            + jnp.einsum("bhci,bhcj->bhij", k_state, v_c)
        return S_new, intra + inter

    S0 = jnp.zeros((B, H, hs, hs), f32)
    S_final, out = jax.lax.scan(step, S0, (r_s, k_s, v_s, lw_s))
    out = out.transpose(1, 0, 3, 2, 4).reshape(B, T, H, hs)
    return out, S_final


def reference_wkv6(r, k, v, lw, u, initial_state=None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact stepwise oracle (and the decode path). Shapes as chunked_wkv6."""
    B, T, H, hs = r.shape
    f32 = jnp.float32
    r, k, v = (a.astype(f32).transpose(0, 2, 1, 3) for a in (r, k, v))
    lw = jnp.clip(lw.astype(f32), LOG_DECAY_CLAMP, 0.0).transpose(0, 2, 1, 3)
    S = initial_state if initial_state is not None \
        else jnp.zeros((B, H, hs, hs), f32)

    def step(S, xs):
        r_t, k_t, v_t, lw_t = xs                 # (B,H,hs)
        kv = k_t[..., :, None] * v_t[..., None, :]
        o = jnp.einsum("bhi,bhij->bhj", r_t,
                       S + u.astype(f32)[None, :, :, None] * kv)
        S = jnp.exp(lw_t)[..., :, None] * S + kv
        return S, o

    xs = tuple(a.transpose(2, 0, 1, 3) for a in (r, k, v, lw))
    S, out = jax.lax.scan(step, S, xs)
    return out.transpose(1, 0, 2, 3).reshape(B, T, H, hs), S


# ----------------------------------------------------------------- the block
def _ddlerp(p, x, x_prev):
    """Data-dependent token-shift mixing (Finch): 5 mixed variants of x."""
    B, T, d = x.shape
    delta = x_prev - x
    base = x + delta * p["mix_base"][0]          # seed mix (uses target 0)
    lora = jnp.tanh(base @ p["mix_w1"]).reshape(B, T, 5, MIX_LORA)
    dyn = jnp.einsum("btki,kid->btkd", lora, p["mix_w2"])
    mixes = p["mix_base"][None, None] + dyn      # (B,T,5,d)
    return x[:, :, None, :] + delta[:, :, None, :] * mixes


def time_mix(cfg, p, x, x_prev_last, state, *, decode: bool = False):
    """RWKV6 attention analogue.

    x: (B,T,d); x_prev_last: (B,d) last token of previous segment (token
    shift carry); state: (B,H,hs,hs) WKV state. Returns (out, new_carry)."""
    B, T, d = x.shape
    hs = cfg.rwkv_head_size
    H = d // hs
    x_prev = jnp.concatenate([x_prev_last[:, None, :], x[:, :-1, :]], axis=1)
    m = _ddlerp(p, x, x_prev)
    xr, xw, xk, xv, xg = (m[:, :, i, :] for i in range(5))
    r = (xr @ p["wr"]).reshape(B, T, H, hs)
    kk = (xk @ p["wk"]).reshape(B, T, H, hs)
    vv = (xv @ p["wv"]).reshape(B, T, H, hs)
    g = jax.nn.silu(xg @ p["wg"])
    lw = -jnp.exp(p["w0"] + jnp.tanh(xw @ p["w_a"]) @ p["w_b"])  # (B,T,d) ≤ 0
    lw = lw.reshape(B, T, H, hs)
    if decode or state is not None or T % cfg.rwkv_chunk != 0:
        wkv, S = reference_wkv6(r, kk, vv, lw, p["u"], initial_state=state)
    else:  # train/prefill from zero state: chunk-parallel form
        wkv, S = chunked_wkv6(r, kk, vv, lw, p["u"], cfg.rwkv_chunk)
    out = _group_norm_heads(wkv.reshape(B, T, d).astype(x.dtype),
                            p["ln_x_scale"], H)
    out = (out * g) @ p["wo"]
    return out, (x[:, -1, :], S.astype(jnp.float32))


def channel_mix(cfg, p, x, x_prev_last):
    B, T, d = x.shape
    x_prev = jnp.concatenate([x_prev_last[:, None, :], x[:, :-1, :]], axis=1)
    delta = x_prev - x
    xk = x + delta * p["mix_k"]
    xr = x + delta * p["mix_r"]
    k = jnp.square(jax.nn.relu(xk @ p["w_in"]))
    r = jax.nn.sigmoid(xr @ p["w_r"])
    return r * (k @ p["w_out"]), x[:, -1, :]
