"""Serving: prefill + single-token decode steps and cache templates.

``prefill_step`` consumes a full prompt and returns (last-token logits,
decode caches). ``decode_step`` consumes one token + caches. Cache templates
(:func:`cache_template`) let the dry-run lower decode steps from
``ShapeDtypeStruct``s without ever allocating a 500k-token cache.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.model import ATTN_TYPES, attn_kind


def load_params_for_serving(directory: str, params_template: Any,
                            step: Optional[int] = None,
                            threads: Optional[int] = None,
                            throttle_mbps: Optional[float] = None,
                            repository: Optional[Any] = None,
                            fleet: Optional[Any] = None):
    """Restore *model parameters only* straight into a serving process.

    Serving needs no optimizer state, so this restores the ``model``
    sub-tree alone through the parallel
    :class:`~repro.core.restore.RestoreEngine` — the engine's up-front
    intersection planning means only the parameter byte ranges are read
    from the (much larger) training checkpoint, whatever engine format
    wrote it. ``params_template`` leaves may carry a serving-mesh
    ``.sharding`` that differs from the training layout (elastic restore).

    Step resolution goes through the checkpoint repository: only
    *committed* steps are eligible (a crash-interrupted save is never
    served), and a step evicted from the local tier is re-hydrated from
    the first remote tier holding a complete copy. Pass ``repository`` (a
    :class:`~repro.storage.CheckpointRepository` configured with the
    training job's remote tiers) to serve from remote storage; otherwise a
    local-tier view of ``directory`` is used.

    ``fleet`` attaches a :class:`~repro.fleet.FleetFabric` to the
    repository for the fleet warm-start path: concurrent replicas loading
    the same step share one remote read per object through the fabric's
    read-through cache and peer slice exchange, and replicas already
    holding the step's chain prefix pull only the delta chain. The fabric
    stays attached (it is shared, idempotent state) so every replica
    hitting this repository benefits; pass
    ``repository.attach_fleet(None)`` to detach explicitly.

    Returns ``(params, stats)`` where ``stats`` is a
    :class:`~repro.core.restore.RestoreStats` (check ``bytes_read`` to see
    the sub-tree effect).

    This is the manager's selective-restore path
    (:func:`repro.core.checkpoint.restore_from_repository` with
    ``domains=("model",)``): serving, ``Trainer.resume``, and
    ``CheckpointManager.restore(domains=...)`` share one implementation,
    so damaged-step fallback, delta-chain replay, and the bytes-read audit
    behave identically everywhere.
    """
    from repro.core.checkpoint import restore_from_repository
    from repro.core.restore import RestoreEngine
    from repro.storage.repository import CheckpointRepository

    repo = repository
    if repo is None:
        repo = CheckpointRepository(directory, auto_cascade=False,
                                    auto_gc=False)
    if fleet is not None:
        repo.attach_fleet(fleet)
    engine = RestoreEngine(threads=threads, throttle_mbps=throttle_mbps)
    tree, stats, _step = restore_from_repository(
        repo, {"model": params_template}, step=step, engine=engine,
        domains=("model",))
    return tree["model"], stats


def make_prefill_step(cfg) -> Callable:
    def prefill_step(params, batch):
        logits, _aux, caches = M.forward(cfg, params, batch,
                                         collect_caches=True)
        return logits[:, -1:, :], caches
    return prefill_step


def make_decode_step(cfg) -> Callable:
    def decode_step(params, tokens, caches, pos):
        logits, new_caches = M.decode(cfg, params, {"tokens": tokens},
                                      caches, pos)
        return logits, new_caches
    return decode_step


# ---------------------------------------------------------------- templates
def _cache_entry_shapes(cfg, btype: str, batch: int, seq_len: int
                        ) -> Dict[str, Tuple[Tuple[int, ...], Any]]:
    """Shapes/dtypes of one layer's decode cache (without the stack dim)."""
    dt = jnp.dtype(cfg.dtype)
    if btype in ATTN_TYPES:
        kind = attn_kind(btype)
        if kind == "window":
            T = min(cfg.window, seq_len)
        elif kind == "chunked":
            T = min(cfg.chunk, seq_len)
        else:
            T = seq_len
        e = {"k": ((batch, T, cfg.n_kv_heads, cfg.hd), dt),
             "v": ((batch, T, cfg.n_kv_heads, cfg.hd), dt)}
        if btype == "xattn":
            e["mk"] = ((batch, cfg.n_memory_embeds, cfg.n_kv_heads, cfg.hd), dt)
            e["mv"] = ((batch, cfg.n_memory_embeds, cfg.n_kv_heads, cfg.hd), dt)
        return e
    if btype == "rec":
        return {"h": ((batch, cfg.d_rnn), jnp.float32),
                "conv": ((batch, cfg.conv_width - 1, cfg.d_rnn), dt)}
    if btype == "rwkv":
        hs = cfg.rwkv_head_size
        H = cfg.d_model // hs
        return {"x_t": ((batch, cfg.d_model), dt),
                "S": ((batch, H, hs, hs), jnp.float32),
                "x_c": ((batch, cfg.d_model), dt)}
    raise ValueError(btype)


def cache_template(cfg, batch: int, seq_len: int,
                   make_leaf=None) -> Tuple:
    """Caches pytree of ShapeDtypeStructs (or arrays via ``make_leaf``)."""
    if make_leaf is None:
        make_leaf = lambda shape, dtype: jax.ShapeDtypeStruct(shape, dtype)
    groups = []
    for pattern, count in cfg.layer_groups:
        per_pos = []
        for btype in pattern:
            entries = _cache_entry_shapes(cfg, btype, batch, seq_len)
            per_pos.append({k: make_leaf((count,) + shape, dt)
                            for k, (shape, dt) in entries.items()})
        groups.append(tuple(per_pos))
    return tuple(groups)


def zero_caches(cfg, batch: int, seq_len: int) -> Tuple:
    return cache_template(
        cfg, batch, seq_len,
        make_leaf=lambda shape, dt: jnp.zeros(shape, dt))


def greedy_generate(cfg, params, prompt_batch, n_new: int):
    """Small convenience driver used by examples/tests (CPU-sized)."""
    import dataclasses
    cfg = dataclasses.replace(cfg, max_decode_len=n_new)
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))
    logits, caches = prefill(params, prompt_batch)
    tokens = prompt_batch["tokens"]
    B = tokens.shape[0]
    S = tokens.shape[1] + cfg.n_prefix_embeds  # vlm: image prefix positions

    def next_tokens(logits):
        last = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        if cfg.n_codebooks:
            return last.reshape(B, 1, cfg.n_codebooks)
        return last.reshape(B, 1)

    out = []
    nxt = next_tokens(logits)
    for i in range(n_new):
        out.append(nxt)
        logits, caches = decode(params, nxt, caches, S + i)
        nxt = next_tokens(logits)
    return jnp.concatenate(out, axis=1)
