"""Parameter/optimizer partition rules.

Two modes (cfg.sharding_mode):

* ``"2d"`` (default, beyond-paper): every large matrix is sharded on *both*
  mesh axes (FSDP×TP hybrid) — the only way 132B/400B fit 16 GB v5e HBM.
  Optimizer state inherits the param spec (already fully sharded).
* ``"tp_zero1"`` (paper-faithful): params are TP-sharded over ``model`` and
  replicated over ``data`` (Megatron/DeepSpeed layout); optimizer state is
  additionally sharded over ``data`` — exactly DeepSpeed ZeRO Stage-1, the
  paper's evaluation setup (Table II).

Rules match on the leaf's key name. Scan-stacked params get a leading None.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# name -> (spec for 2d mode, spec for tp_zero1 mode)
_RULES: Dict[str, Tuple[Tuple, Tuple]] = {
    # embeddings
    "embed": (("model", "data"), ("model", None)),
    "head": (("data", "model"), (None, "model")),
    # attention
    "wq": (("data", "model"), (None, "model")),
    "wk": (("data", "model"), (None, "model")),
    "wv": (("data", "model"), (None, "model")),
    "wo": (("model", "data"), ("model", None)),
    # ffn
    "w_gate": (("data", "model"), (None, "model")),
    "w_up": (("data", "model"), (None, "model")),
    "w_down": (("model", "data"), ("model", None)),
    # moe (leading expert dim -> expert parallelism over 'model')
    "router": ((None, None), (None, None)),
    # rwkv
    "wg": (("data", "model"), (None, "model")),
    "wr": (("data", "model"), (None, "model")),
    "w_in": (("data", "model"), (None, "model")),
    "w_out": (("model", "data"), ("model", None)),
    "w_r": (("data", "model"), (None, "model")),
    "mix_w1": (("data", None), (None, None)),
    "w_a": (("data", "model"), (None, "model")),
    "w_b": ((None, "model"), (None, "model")),
    # rg-lru
    "w_gate_branch": (("data", "model"), (None, "model")),
    "w_rec_in": (("data", "model"), (None, "model")),
    "w_x": (("data", "model"), (None, "model")),
    "conv_w": ((None, "model"), (None, "model")),
}

_MOE_RULES: Dict[str, Tuple[Tuple, Tuple]] = {
    # (E, d, f) / (E, f, d): experts over 'model', inner dim over 'data'
    "w_gate": (("model", "data", None), ("model", None, None)),
    "w_up": (("model", "data", None), ("model", None, None)),
    "w_down": (("model", "data", None), ("model", None, None)),
}


def _spec_for(path: Tuple[str, ...], shape: Tuple[int, ...], mode: str
              ) -> Tuple:
    name = path[-1]
    if mode == "fsdp":
        # pure ZeRO-3/FSDP: no tensor parallelism — shard the first
        # shardable dim of every sizeable matrix over the WHOLE mesh;
        # weights are all-gathered per layer, grads reduce-scattered.
        if len(shape) >= 2:
            return (("data", "model"),) + (None,) * (len(shape) - 1)
        if len(shape) == 1 and shape[0] >= 4096:
            return (("data", "model"),)
        return (None,) * len(shape)
    in_moe = "moe" in path and "shared" not in path
    col = 0 if mode == "2d" else 1
    rules = _MOE_RULES if (in_moe and name in _MOE_RULES) else _RULES
    if name in rules and len(rules[name][col]) == len(shape):
        return rules[name][col]
    return (None,) * len(shape)  # norms, biases, scalars: replicated


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for e in path:
        if hasattr(e, "key"):
            out.append(str(e.key))
        elif hasattr(e, "idx"):
            out.append(str(e.idx))
        else:
            out.append(str(e))
    return tuple(out)


def _divisible(spec: Tuple, shape: Tuple[int, ...], mesh: Mesh) -> Tuple:
    """Drop axis assignments that don't divide the dim (GSPMD would pad;
    we prefer clean replication for small/awkward dims)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for dim, entry in zip(shape, spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = int(np.prod([sizes.get(a, 1) for a in axes]))
        out.append(entry if dim % n == 0 and dim >= n else None)
    return tuple(out)


def param_pspecs(cfg, params, mesh: Mesh):
    """PartitionSpec tree matching ``params`` (handles scan-stacked leaves:
    any leaf under 'groups' has one extra leading (layer) dim -> None)."""
    def spec(path, leaf):
        names = _path_names(path)
        shape = tuple(leaf.shape)
        stacked = "groups" in names
        base_shape = shape[1:] if stacked else shape
        s = _spec_for(names, base_shape, cfg.sharding_mode)
        s = _divisible(s, base_shape, mesh)
        if stacked:
            s = (None,) + s
        return P(*s)
    return jax.tree_util.tree_map_with_path(spec, params)


def opt_pspecs(cfg, params, mesh: Mesh):
    """Optimizer-state specs. ``tp_zero1``: shard the largest replicated dim
    of each master/m/v leaf over 'data' (ZeRO-1). ``2d``: same as params."""
    pspecs = param_pspecs(cfg, params, mesh)
    if cfg.sharding_mode != "tp_zero1":
        mv = {"master": pspecs, "m": pspecs, "v": pspecs}
    else:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        dp = sizes.get("data", 1)

        def zero1(path, leaf):
            base = param_pspecs(cfg, {"_": leaf}, mesh)["_"]
            # reuse the param spec for this leaf
            return base

        def shard_over_data(p_spec: P, leaf):
            entries = list(p_spec) + [None] * (len(leaf.shape) - len(p_spec))
            for i, (dim, e) in enumerate(zip(leaf.shape, entries)):
                if e is None and dim % dp == 0 and dim >= dp:
                    entries[i] = "data"
                    break
            return P(*entries)

        zp = jax.tree_util.tree_map(shard_over_data, pspecs, params)
        mv = {"master": zp, "m": zp, "v": zp}
    return {**mv, "count": P()}


def shardings_for(tree_specs, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))


def cache_pspecs(cfg, caches_template, mesh: Mesh, *, long_context: bool):
    """Decode-cache specs. Batch over ('pod','data') normally; for
    ``long_context`` (batch=1) the KV sequence dim is sharded over 'data'
    (context parallelism). Head/feature dims go over 'model' when divisible."""
    names = set(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    baxes = tuple(a for a in ("pod", "data") if a in names)
    nb = int(np.prod([sizes[a] for a in baxes])) if baxes else 1
    mp = sizes.get("model", 1)
    dp = sizes.get("data", 1)

    def spec(path, leaf):
        shape = tuple(leaf.shape)  # (count, B, ...)
        name = _path_names(path)[-1]
        s = [None] * len(shape)
        B = shape[1]
        if not long_context and baxes and B % nb == 0 and B >= nb:
            s[1] = baxes if len(baxes) > 1 else baxes[0]
        if name in ("k", "v") and len(shape) == 5:
            # (count, B, T, KV, hd)
            if long_context and "data" in names and shape[2] % dp == 0:
                s[2] = "data"
            elif getattr(cfg, "decode_kv_seq_shard", False) \
                    and "model" in names and shape[2] % mp == 0:
                s[2] = "model"   # beyond-paper: seq-sharded decode cache
            elif shape[3] % mp == 0 and shape[3] >= mp:
                s[3] = "model"
            elif shape[4] % mp == 0 and shape[4] >= mp:
                s[4] = "model"
        elif name in ("mk", "mv") and len(shape) == 5:
            if shape[3] % mp == 0 and shape[3] >= mp:
                s[3] = "model"
        elif name == "S" and len(shape) == 5:   # (count,B,H,hs,hs)
            if shape[2] % mp == 0 and shape[2] >= mp:
                s[2] = "model"
        elif name in ("h", "x_t", "x_c") and len(shape) == 3:
            if shape[2] % mp == 0 and shape[2] >= mp:
                s[2] = "model"
        elif name == "conv" and len(shape) == 4:
            if shape[3] % mp == 0 and shape[3] >= mp:
                s[3] = "model"
        return P(*s)

    return jax.tree_util.tree_map_with_path(spec, caches_template)


def batch_pspecs(cfg, shape_kind: str, batch_template: Dict[str, Any],
                 mesh: Mesh):
    """Input batch specs: batch dim over ('pod','data') when divisible
    (plus 'model' in fsdp mode — the whole mesh is one DP domain)."""
    names = set(mesh.axis_names)
    axes = ("pod", "data", "model") if cfg.sharding_mode == "fsdp" \
        else ("pod", "data")
    baxes = tuple(a for a in axes if a in names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = int(np.prod([sizes[a] for a in baxes])) if baxes else 1

    def spec(k, v):
        b = v.shape[0]
        first = baxes if (baxes and b % n == 0 and b >= n) else None
        if isinstance(first, tuple) and len(first) == 1:
            first = first[0]
        return P(first, *([None] * (len(v.shape) - 1)))

    return {k: spec(k, v) for k, v in batch_template.items()}
