"""Ambient-mesh sharding context.

The model code expresses activation constraints against *logical* axes
("pod", "data", "model", "seq"). The launcher activates a mesh via
:func:`activate`; when no mesh is active (CPU smoke tests) constraints are
no-ops, so the same model code runs everywhere.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def active_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def activate(mesh: Optional[Mesh]):
    prev = active_mesh()
    _state.mesh = mesh
    try:
        if mesh is not None:
            with mesh:
                yield mesh
        else:
            yield None
    finally:
        _state.mesh = prev


def _resolve(spec: P, mesh: Mesh) -> Optional[P]:
    """Map logical axes onto the active mesh: drop axis names the mesh does
    not have, map 'seq' to the configured physical axis (context parallelism
    for batch=1 decode), and never use one physical axis twice. Returns None
    when nothing survives (→ skip the constraint, don't force replication)."""
    names = set(mesh.axis_names)
    used = set()
    out = []
    any_axis = False
    for entry in spec:
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        mapped = []
        expanded = []
        for a in axes:
            ba = getattr(_state, "batch_axes", None)
            if a == "data" and ba:
                expanded.extend(ba)   # fsdp: batch spans extra axes
            else:
                expanded.append(a)
        for a in expanded:
            if a == "seq":
                a = getattr(_state, "seq_axis", None)
                if a is None:
                    continue
            if a in names and a not in used:
                mapped.append(a)
                used.add(a)
        if not mapped:
            out.append(None)
        elif len(mapped) == 1:
            out.append(mapped[0])
            any_axis = True
        else:
            out.append(tuple(mapped))
            any_axis = True
    return P(*out) if any_axis else None


def set_seq_axis(axis: Optional[str]) -> None:
    """Map the logical 'seq' axis onto a physical mesh axis (or disable)."""
    _state.seq_axis = axis


def set_batch_axes(axes) -> None:
    """Expand the logical 'data' (batch) axis onto extra physical axes —
    e.g. ("data", "model") for pure-FSDP runs where the whole mesh is one
    big data-parallel domain."""
    _state.batch_axes = tuple(axes) if axes else None


def seq_axis_active() -> bool:
    return getattr(_state, "seq_axis", None) is not None


def constrain(x, spec: P):
    mesh = active_mesh()
    if mesh is None:
        return x
    resolved = _resolve(spec, mesh)
    if resolved is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, resolved))
