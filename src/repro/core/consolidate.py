"""Offline shard consolidation (the paper's §VII future work: "shard
aggregation/consolidation to mitigate PFS metadata pressure without
sacrificing parallelism").

A checkpoint written at scale produces one ``.dsllm`` file per owning rank
(Fig 1(c,d)) — thousands of files per step on a large mesh, which hammers
the PFS metadata servers on restore. :func:`consolidate_step_dir` repacks a
step directory into ``ceil(n_ranks / group)`` aggregate files *after* the
checkpoint is persisted (background/maintenance path — never on the
training critical path). Restore needs no changes: the manager indexes
whatever ``.dsllm`` files exist by tensor name + shard region.
"""

from __future__ import annotations

import glob
import os
from typing import List, Optional

from .layout import FileLayout, FileReader, FileWriter


def consolidate_step_dir(sdir: str, group: int = 8,
                         remove_originals: bool = True) -> List[str]:
    """Merge per-rank ``rank*.dsllm`` files into aggregates of ``group``.

    Returns the list of aggregate paths written. Safe against partial
    failure: aggregates are written + fsynced before any original is
    removed; a crash in between leaves duplicates (restore tolerates them
    — identical shard regions resolve to the same bytes).
    """
    ranks = sorted(p for p in glob.glob(os.path.join(sdir, "rank*.dsllm")))
    if not ranks:
        return []
    written: List[str] = []
    for gi in range(0, len(ranks), group):
        batch = ranks[gi:gi + group]
        out_path = os.path.join(sdir, f"agg{gi // group:05d}.dsllm")
        readers = [FileReader(p) for p in batch]
        specs = []
        for rd in readers:
            for name, e in rd.tensors.items():
                specs.append((name, e.nbytes, e.dtype, e.shape,
                              e.global_shape, e.index))
        layout = FileLayout.plan(specs)
        writer = FileWriter(out_path, layout)
        try:
            by_name = {t.name: t for t in layout.tensors}
            for rd in readers:
                for name in rd.tensors:
                    writer.write_at(by_name[name].offset,
                                    rd.read_tensor(name).tobytes())
                for oname in rd.objects:
                    writer.append_object(oname, rd.read_object_raw(oname),
                                         codec=rd.objects[oname].codec)
            writer.set_meta("consolidated_from", [os.path.basename(p)
                                                  for p in batch])
            writer.finalize()
        except BaseException:
            writer.abort()
            if os.path.exists(out_path):
                os.remove(out_path)
            raise
        written.append(out_path)
    if remove_originals:
        for p in ranks:
            os.remove(p)
    return written


def file_count(sdir: str) -> int:
    return len(glob.glob(os.path.join(sdir, "*.dsllm")))
