"""Pre-allocated, pre-pinned host staging cache (paper §V-A1, §V-C).

On the target TPU system this is committed host memory the device runtime can
DMA into; here it is a single pre-allocated byte buffer with a blocking
first-fit interval allocator. Pre-allocation removes per-checkpoint alloc
overheads; the blocking behaviour implements the paper's back-pressure rule —
"if the host memory reserved for checkpointing is full, the next checkpoint
request waits for previous tensors to get evicted after they are flushed".
"""

from __future__ import annotations

import threading
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.analysis.locks import declares_lock
from repro.obs import trace as obs
from repro.obs.metrics import metrics as obs_metrics


class CacheFullError(RuntimeError):
    pass


class Reservation:
    """A byte range inside the cache, exposed as a zero-copy memoryview."""

    __slots__ = ("start", "nbytes", "_cache", "_released")

    def __init__(self, start: int, nbytes: int, cache: "HostCache"):
        self.start = start
        self.nbytes = nbytes
        self._cache = cache
        self._released = False

    @property
    def view(self) -> memoryview:
        return self._cache._buf_view[self.start:self.start + self.nbytes]

    def array(self, dtype, shape) -> np.ndarray:
        """Zero-copy ndarray view over this reservation."""
        return np.frombuffer(self.view, dtype=dtype).reshape(shape)

    def release(self) -> None:
        if not self._released:
            self._released = True
            self._cache._free(self)


# Innermost lock of the hierarchy: reserve() may block on back-pressure,
# so nothing else may be held while other threads need the allocator.
@declares_lock("host_cache.alloc", rank=70, attrs=("_lock", "_freed"))
class HostCache:
    """Blocking first-fit allocator over one pre-allocated pinned buffer."""

    def __init__(self, capacity_bytes: int):
        self.capacity = int(capacity_bytes)
        # The "pinned" pool. One allocation for the lifetime of the engine.
        self._buf = np.zeros(self.capacity, dtype=np.uint8)
        self._buf_view = memoryview(self._buf)
        self._lock = threading.Lock()
        self._freed = threading.Condition(self._lock)
        # Sorted list of allocated (start, end) intervals.
        self._allocated: List[Tuple[int, int]] = []
        self.peak_usage = 0
        self.total_reserved = 0  # lifetime bytes, for stats

    # -- internals -----------------------------------------------------------
    def _find_gap(self, nbytes: int) -> Optional[int]:
        prev_end = 0
        for start, end in self._allocated:
            if start - prev_end >= nbytes:
                return prev_end
            prev_end = end
        if self.capacity - prev_end >= nbytes:
            return prev_end
        return None

    def _free(self, res: Reservation) -> None:
        with self._lock:
            self._allocated.remove((res.start, res.start + res.nbytes))
            self._freed.notify_all()
            used = sum(e - s for s, e in self._allocated)
        obs_metrics.set_gauge("host_cache.used_bytes", used)
        if obs.enabled():
            obs.counter("host_cache.used_bytes", used)

    # -- public --------------------------------------------------------------
    def used_bytes(self) -> int:
        with self._lock:
            return sum(e - s for s, e in self._allocated)

    def reserve(self, nbytes: int, timeout: Optional[float] = None
                ) -> Reservation:
        """Reserve ``nbytes``; blocks until space frees up (back-pressure)."""
        nbytes = int(nbytes)
        if nbytes > self.capacity:
            raise CacheFullError(
                f"request of {nbytes} B exceeds cache capacity {self.capacity} B")
        t0 = time.perf_counter()
        with self._lock:
            while True:
                start = self._find_gap(nbytes)
                if start is not None:
                    break
                if not self._freed.wait(timeout=timeout):
                    raise CacheFullError(
                        f"timed out waiting for {nbytes} B of cache space")
            self._allocated.append((start, start + nbytes))
            self._allocated.sort()
            self.total_reserved += nbytes
            used = sum(e - s for s, e in self._allocated)
            self.peak_usage = max(self.peak_usage, used)
        # Observability happens after the allocator lock is released (the
        # obs locks rank above host_cache.alloc, but no reason to hold it).
        waited = time.perf_counter() - t0
        obs_metrics.observe("host_cache.reserve_wait_s", waited)
        obs_metrics.set_gauge("host_cache.used_bytes", used)
        if obs.enabled():
            obs.add_span("host_cache.reserve", t0, t0 + waited,
                         bytes=nbytes)
            obs.counter("host_cache.used_bytes", used)
        return Reservation(start, nbytes, self)
