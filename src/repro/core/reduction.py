"""Checkpoint data reduction (beyond-paper: the paper's §VII future work).

Codecs applied per tensor *on device* (Pallas kernels) before host
compression:

* ``bf16``   — fp32→bf16 downcast of optimizer moments (2×, lossy-bounded);
* ``int8``   — blockwise symmetric quantization (4×, lossy-bounded);
* ``delta``  — XOR vs the previous snapshot (lossless) — slowly-moving
  state XORs to sparse bitstreams that zstd crushes;
* ``zstd``   — host-side entropy coding (always applied last).

``DifferentialCheckpointer`` keeps the previous snapshot per tensor and
writes either a keyframe (full) or a delta, with integrity checksums from
``kernels.ops.tensor_checksum``. Restore replays keyframe ⊕ deltas.

NOTE: differential checkpointing is now a first-class *engine* path —
``CheckpointManager(..., delta=DeltaPolicy())`` streams XOR deltas through
the async data-movement engine with chain-aware catalog/GC/verify and
parallel chain restore. The synchronous ``DifferentialCheckpointer`` here
is deprecated for training use (see its docstring)."""

from __future__ import annotations

import dataclasses
import io
import os
import pickle
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

try:  # optional: fall back to stdlib zlib when zstandard is not installed
    import zstandard
except ImportError:  # pragma: no cover - exercised on minimal installs
    zstandard = None
import zlib

from repro.kernels import ops as kops


@dataclasses.dataclass
class EncodedTensor:
    codec: str                  # "raw" | "delta-xor"
    quant: str                  # "none" | "bf16" | "int8"
    payload: bytes              # zstd-compressed
    dtype: str
    shape: Tuple[int, ...]
    checksum: int               # of the *original* bytes
    raw_nbytes: int
    scales: Optional[bytes] = None


def _compress(b: bytes, level: int = 3) -> bytes:
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=level).compress(b)
    return zlib.compress(b, level)


_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _decompress(b: bytes) -> bytes:
    if b[:4] == _ZSTD_MAGIC:  # sniff the frame so codecs mix across installs
        if zstandard is None:
            raise RuntimeError(
                "payload was compressed with zstandard, which is not "
                "installed; `pip install zstandard` to read it")
        return zstandard.ZstdDecompressor().decompress(b)
    return zlib.decompress(b)


def encode_tensor(arr: jax.Array, *, prev: Optional[np.ndarray] = None,
                  quant: str = "none") -> Tuple[EncodedTensor, np.ndarray]:
    """Encode one tensor: optional on-device quantize, optional XOR delta
    against ``prev`` (same quantized domain), then zstd. Returns the
    encoded record *and* the working-precision array (the ``prev`` to
    retain for the next delta)."""
    checksum = int(kops.tensor_checksum(arr))
    dtype, shape = str(arr.dtype), tuple(arr.shape)
    scales = None
    if quant == "bf16" and arr.dtype == jnp.float32 and arr.ndim == 2 \
            and arr.shape[0] % 256 == 0 and arr.shape[1] % 256 == 0:
        work = np.asarray(kops.downcast_bf16(arr))
    elif quant == "int8" and arr.dtype == jnp.float32 and arr.ndim == 2 \
            and arr.shape[0] % 256 == 0 and arr.shape[1] == 256:
        q, s = kops.quantize_int8(arr)
        work = np.asarray(q)
        scales = _compress(np.asarray(s).tobytes())
    else:
        quant = "none"
        work = np.asarray(arr)
    if prev is not None and prev.shape == work.shape \
            and prev.dtype == work.dtype:
        delta = np.asarray(kops.delta_xor(jnp.asarray(work),
                                          jnp.asarray(prev)))
        payload = _compress(delta.tobytes())
        codec = "delta-xor"
    else:
        payload = _compress(np.ascontiguousarray(work).tobytes())
        codec = "raw"
    return EncodedTensor(codec=codec, quant=quant, payload=payload,
                         dtype=dtype, shape=shape, checksum=checksum,
                         raw_nbytes=int(np.asarray(arr).nbytes),
                         scales=scales), work


def decode_tensor(enc: EncodedTensor, *, prev: Optional[np.ndarray] = None
                  ) -> np.ndarray:
    """Inverse of encode (returns the *working-precision* array)."""
    raw = _decompress(enc.payload)
    if enc.codec == "delta-xor":
        assert prev is not None, "delta decode needs the previous snapshot"
        n_u32 = len(raw) // 4
        delta = np.frombuffer(raw, np.uint32)
        prev_u32 = prev.reshape(-1).view(np.uint8)
        pad = (-len(prev_u32)) % 4
        prev_u32 = np.pad(prev_u32, (0, pad)).view(np.uint32)
        pad2 = n_u32 - len(prev_u32)
        if pad2:
            prev_u32 = np.pad(prev_u32, (0, pad2))
        cur = np.bitwise_xor(delta, prev_u32)
        work = cur.view(np.uint8)
    else:
        work = np.frombuffer(raw, np.uint8)
    if enc.quant == "bf16":
        arr = work[:int(np.prod(enc.shape)) * 2].view(jnp.bfloat16)
    elif enc.quant == "int8":
        arr = work[:int(np.prod(enc.shape))].view(np.int8)
    else:
        arr = work[:enc.raw_nbytes].view(np.dtype(enc.dtype))
    return np.array(arr).reshape(enc.shape)


class DifferentialCheckpointer:
    """Keyframe + delta checkpoint stream for a pytree of arrays.

    .. deprecated::
        This standalone sidecar predates differential checkpointing on the
        main engine path and bypasses the async data-movement engine, the
        crash-consistent catalog, multi-rank coordination, and the parallel
        restore engine. Prefer ``CheckpointManager(..., delta=DeltaPolicy())``
        (see ``repro.core.checkpoint``): same keyframe+XOR-delta reduction,
        but lazy/async, chain-aware in GC/cascade/verify, and restored
        through ``RestoreEngine`` chain replay. This class remains for
        offline/sidecar use and as the reference for the quantized
        (``bf16``/``int8``) encode path.
    """

    def __init__(self, directory: str, *, keyframe_every: int = 4,
                 quant: str = "none"):
        self.directory = directory
        self.keyframe_every = keyframe_every
        self.quant = quant
        self._prev: Dict[str, np.ndarray] = {}
        self._n_saves = 0
        os.makedirs(directory, exist_ok=True)
        # Restart recovery: derive chain state from what is already on
        # disk. Without this, a restarted process had _n_saves=0 (→
        # keyframe cadence restarts) but ALSO wrote its first record with
        # keyframe=False whenever the cadence said "delta" — while
        # actually raw-encoding every tensor (_prev empty) — so restore()
        # across the restart failed its `chain[0]["keyframe"]` assertion.
        existing = self._existing_steps()
        if existing:
            self._n_saves = len(existing)
            try:
                # re-arm the delta bases from the last restorable step so
                # the chain continues across the restart
                self._prev = self.restore(existing[-1])
            except Exception:
                self._prev = {}  # damaged tail: next save re-keyframes

    def _existing_steps(self) -> List[int]:
        return sorted(int(f[5:13]) for f in os.listdir(self.directory)
                      if f.startswith("diff_") and f.endswith(".pkl"))

    def save(self, step: int, tree) -> Dict[str, Any]:
        leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
        # no retained bases ⇒ this save is raw-encoded whatever the
        # cadence says; record it as the keyframe it actually is
        keyframe = (self._n_saves % self.keyframe_every == 0) \
            or not self._prev
        record: Dict[str, Any] = {"step": step, "keyframe": keyframe,
                                  "tensors": {}}
        raw_total = comp_total = 0
        for path, leaf in leaves:
            name = jax.tree_util.keystr(path)
            prev = None if keyframe else self._prev.get(name)
            enc, work = encode_tensor(jnp.asarray(leaf), prev=prev,
                                      quant=self.quant)
            self._prev[name] = work
            record["tensors"][name] = enc
            raw_total += enc.raw_nbytes
            comp_total += len(enc.payload)
        path = os.path.join(self.directory, f"diff_{step:08d}.pkl")
        # Deprecated standalone reducer (pre-repository legacy format): its
        # flat diff_*.pkl files live outside the catalog/manifest protocol
        # by definition; kept only for the migration window.
        with open(path, "wb") as f:  # ckptlint: disable=CKPT301
            pickle.dump(record, f, protocol=pickle.HIGHEST_PROTOCOL)
        self._n_saves += 1
        return {"path": path, "raw_bytes": raw_total,
                "compressed_bytes": comp_total,
                "ratio": raw_total / max(comp_total, 1),
                "keyframe": keyframe}

    def restore(self, step: int) -> Dict[str, np.ndarray]:
        """Replay keyframe + deltas up to ``step``."""
        files = sorted(os.listdir(self.directory))
        chain: List[Dict[str, Any]] = []
        for f in files:
            if not f.startswith("diff_"):
                continue
            s = int(f[5:13])
            if s > step:
                break
            try:
                with open(os.path.join(self.directory, f), "rb") as fh:
                    rec = pickle.load(fh)
            except Exception:
                # A broken link invalidates everything accumulated so far
                # — only a later keyframe can re-anchor the chain. Never
                # splice across a damaged record.
                chain = []
                continue
            if rec["keyframe"]:
                chain = [rec]
            else:
                chain.append(rec)
        assert chain and chain[0]["keyframe"], "no keyframe found"
        state: Dict[str, np.ndarray] = {}
        for rec in chain:
            for name, enc in rec["tensors"].items():
                state[name] = decode_tensor(enc, prev=state.get(name))
        return state
