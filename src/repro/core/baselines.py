"""Checkpoint engines: DataStates-LLM and the paper's three baselines (§VI-B).

All engines implement :class:`BaseCheckpointEngine` and fill the same
:class:`~repro.core.engine.CheckpointStats`, so the benchmark harness can
compare them head-to-head exactly as the paper's figures do.

* :class:`SyncSerializedEngine` — "DeepSpeed default": blocking,
  type-agnostic serialization of the full object graph (tensors deep-copied
  through the pickler), synchronous single-stream write. (Fig 6(a))
* :class:`SnapshotThenFlushEngine` — "TorchSnapshot": blocking up-front
  metadata serialization, blocking D2H snapshot of *all* shards into freshly
  allocated (non-pinned, per-request) buffers, then background multi-threaded
  chunk-*file* writes (chunk-to-file mapping inflates file counts, §IV-D).
  (Fig 6(b))
* :class:`DataStatesOldEngine` — HPDC'24 prior work: coalesced pinned cache,
  lazy capture, async flush — but metadata/objects are serialized in a
  blocking prologue (layout precomputed up front) and tensors flush only
  after fully staged (no intra-tensor streaming). (Fig 6(c))
* :class:`DataStatesEngine` — this paper: everything above plus composable
  state providers (zero-copy tensors, lazy object serialization overlapped
  with bulk I/O) and intra-tensor stage/flush streaming. (Fig 6(d))
"""

from __future__ import annotations

import os
import pickle
import queue
import threading
import time
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from .distributed import ShardRecord
from .engine import CheckpointError, CheckpointFuture, DataMovementEngine, \
    FilePlan
from .layout import maybe_fsync
from .state_provider import (CompositeStateProvider, DeltaSaveSpec,
                             DeltaStateProvider, EncodeBudget,
                             ObjectStateProvider, QuantizedStateProvider,
                             SnapshotCache, TensorStateProvider)


def resolve_provider(rec: ShardRecord, delta: Optional[DeltaSaveSpec]):
    """Resolve one shard record's registry route to a concrete provider
    kind: ``(kind, factory)`` where kind is a stock name and factory is
    the user callable for custom providers (else None). ``"auto"`` (and
    records without a route) adapts to the save mode: delta when the save
    is differential, raw otherwise — the pre-registry behavior."""
    route = rec.route
    if route is None or (route.provider == "auto" and route.factory is None):
        return ("delta" if delta is not None else "tensor"), None
    return route.provider, route.factory


def _object_domain(key: str) -> Optional[str]:
    """State-domain of an object-log key (None for engine-internal keys
    like ``__checkpoint_meta__``)."""
    parts = key.split("/")
    name = parts[1] if len(parts) > 1 else parts[0]
    return None if name.startswith("__") else name


def merge_domains_meta(dst: Dict[str, Dict[str, List[str]]],
                       src: Dict[str, Dict[str, List[str]]]
                       ) -> Dict[str, Dict[str, List[str]]]:
    """Fold one ``{domain: {providers, codecs}}`` map into another
    (union, sorted). Used to aggregate per-file maps into the save-level
    summary and per-rank summaries across coordinator lanes — one
    derivation (from the live provider instances) feeds both the ``.dsllm``
    footers and ``StepManifest.meta['domains']``, so they can never drift."""
    for domain, e in src.items():
        t = dst.setdefault(domain, {"providers": [], "codecs": []})
        for k in ("providers", "codecs"):
            for v in e.get(k, ()):
                if v not in t[k]:
                    t[k].append(v)
            t[k].sort()
    return dst


def _reject_encoded_routes(by_rank, engine_name: str) -> None:
    """Baseline (non-DataMovementEngine) engines stream raw only — a
    registry route to an encoding provider must fail loudly, not be
    silently dropped."""
    for recs in by_rank.values():
        for r in recs:
            if r.route is not None \
                    and r.route.provider not in ("auto", "tensor"):
                raise ValueError(
                    f"engine {engine_name!r} cannot honor provider route "
                    f"{r.route.provider!r} for {r.tensor_name!r}; "
                    f"registry-routed delta/quantized/custom providers "
                    f"require a DataMovementEngine mode "
                    f"(datastates / datastates-old)")


def rank_file(directory: str, rank: int, ext: str = "dsllm") -> str:
    return os.path.join(directory, f"rank{rank:05d}.{ext}")


class BaseCheckpointEngine:
    name = "base"

    def __init__(self, host_cache_bytes: int = 1 << 30,
                 flush_threads: int = 4, chunk_bytes: int = 4 << 20,
                 throttle_mbps: Optional[float] = None,
                 checksum_files: bool = False,
                 label: str = "dsllm"):
        self.host_cache_bytes = host_cache_bytes
        self.flush_threads = flush_threads
        self.chunk_bytes = chunk_bytes
        self.throttle_mbps = throttle_mbps
        # manifest checksums are on for this repository: engines that can
        # should produce integrity metadata in-pass (streaming file
        # checksums, fused per-chunk payload digests) so the vote/commit
        # lanes never re-read persisted bytes
        self.checksum_files = checksum_files
        # lane-name prefix for this engine's worker threads (trace tracks)
        self.label = label

    def save(self, directory: str,
             by_rank: Dict[int, List[ShardRecord]],
             objects: Dict[str, Any],
             future: CheckpointFuture,
             delta: Optional[DeltaSaveSpec] = None) -> None:
        raise NotImplementedError

    def drain(self) -> None:
        pass

    def close(self) -> None:
        pass

    # shared helper: simulate limited storage bandwidth if configured
    def _throttle(self, nbytes: int, t0: float) -> None:
        if self.throttle_mbps:
            target = nbytes / (self.throttle_mbps * 1e6)
            elapsed = time.perf_counter() - t0
            if target > elapsed:
                time.sleep(target - elapsed)


# --------------------------------------------------------------------------
class DataStatesEngine(BaseCheckpointEngine):
    """This paper's engine: state providers + streamlined multi-tier flush."""

    name = "datastates"
    _stream_intra_tensor = True
    _blocking_object_serialization = False

    def __init__(self, **kw):
        super().__init__(**kw)
        self._engine = DataMovementEngine(
            host_cache_bytes=self.host_cache_bytes,
            flush_threads=self.flush_threads,
            chunk_bytes=self.chunk_bytes,
            throttle_mbps=self.throttle_mbps,
            track_file_checksums=self.checksum_files,
            label=self.label)
        # Differential checkpointing: retained previous-snapshot copies,
        # held inside the same pinned host-cache budget as staging.
        self.snapshot_cache = SnapshotCache(self._engine.host_cache)
        # Consecutive delta saves are ordered: save N+1 may only start
        # streaming (mutating the snapshot cache) once save N's providers
        # have finished streaming — tracked as (streamed_event, future).
        self._delta_prev: Optional[tuple] = None
        self._delta_gate_timeout_s = 600.0

    @property
    def host_cache(self):
        return self._engine.host_cache

    def _object_providers(self, objects: Dict[str, Any],
                          future: CheckpointFuture
                          ) -> List[ObjectStateProvider]:
        if not self._blocking_object_serialization:
            # lazy: serialization happens on the producer lane, overlapped
            # with bulk tensor I/O (§V-A5).
            return [ObjectStateProvider(name, obj)
                    for name, obj in objects.items()]
        # legacy engines: serialize everything up front, blocking (§IV-D).
        provs = []
        t0 = time.perf_counter()
        for name, obj in objects.items():
            payload = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
            provs.append(ObjectStateProvider(name, obj,
                                             preserialized=payload))
        future.stats.serialize_s += time.perf_counter() - t0
        return provs

    # -- differential-save plumbing -----------------------------------------
    def _await_delta_turn(self) -> None:
        """Block (briefly) until the previous delta save has finished
        *streaming* — its providers are done mutating the snapshot cache;
        its flush lanes may still be writing, which is fine."""
        prev = self._delta_prev
        if prev is None:
            return
        streamed, prev_future = prev
        deadline = time.perf_counter() + self._delta_gate_timeout_s
        while not streamed.is_set() \
                and not prev_future._persisted.is_set():
            streamed.wait(0.05)
            if time.perf_counter() > deadline:
                raise CheckpointError(
                    "previous differential save never finished streaming — "
                    "cannot order the snapshot-cache updates of the next one")

    def _delta_precheck(self, delta: DeltaSaveSpec,
                        delta_records: List[ShardRecord],
                        all_records: List[ShardRecord]) -> None:
        """Fail fast instead of deadlocking inside the cache allocator:
        a delta save needs previous-version (snapshot cache — only the
        delta-routed tensors retain one) + in-flight version (staging,
        every device tensor) bytes simultaneously."""
        snap = sum(r.nbytes for r in delta_records)
        stage = sum(r.nbytes for r in all_records if r.device_resident)
        if snap + stage > self._engine.host_cache.capacity:
            raise CheckpointError(
                f"differential checkpointing needs the host cache to hold "
                f"the previous snapshot ({snap/2**20:.0f} MiB) plus the "
                f"in-flight staging copy ({stage/2**20:.0f} MiB); raise "
                f"host_cache_bytes above {(snap+stage)/2**20:.0f} MiB")
        if not delta.keyframe:
            for r in delta_records:
                prev = self.snapshot_cache.view(r.tensor_name)
                if prev is None or len(prev) != r.nbytes:
                    raise CheckpointError(
                        f"delta save of step {delta.step}: no retained "
                        f"snapshot for {r.tensor_name!r} — the chain "
                        f"tracker should have forced a keyframe")

    def save(self, directory, by_rank, objects, future, delta=None) -> None:
        plans: List[FilePlan] = []
        capture_items = []
        streamed_cb = None
        encode_budget = None
        all_records = [r for recs in by_rank.values() for r in recs]
        # registry routing resolves here, once per record: "auto" adapts to
        # the save mode, explicit routes pin a provider per state domain.
        resolved = {id(r): resolve_provider(r, delta) for r in all_records}
        delta_records = [r for r in all_records
                         if resolved[id(r)][0] == "delta"]
        if delta is None and delta_records:
            doms = sorted({r.domain for r in delta_records})
            raise CheckpointError(
                f"state domains {doms} are routed to the 'delta' provider "
                f"but the manager has no DeltaPolicy — set "
                f"CheckpointPolicy.delta, or route them to 'auto'/'tensor'")
        if delta is not None or any(
                resolved[id(r)][0] == "quantized"
                or resolved[id(r)][1] is not None  # custom: may encode too
                for r in all_records):
            # bounds in-flight freshly-allocated encoded (XOR / quantized /
            # custom) payloads between producer and flush lanes (~4 chunks'
            # worth, min 64 MiB)
            encode_budget = EncodeBudget(max(4 * self.chunk_bytes, 64 << 20))
        if delta is not None:
            self._await_delta_turn()
            self._delta_precheck(delta, delta_records, all_records)
            if delta.keyframe:
                # elastic reshard / re-route: drop snapshot entries for
                # tensors that left the delta set, then (re-)reserve it
                self.snapshot_cache.retain_only(
                    [r.tensor_name for r in delta_records])
            streamed = threading.Event()
            n_pending = [len(delta_records)]
            pend_lock = threading.Lock()
            if not delta_records:
                streamed.set()

            def streamed_cb() -> None:
                with pend_lock:
                    n_pending[0] -= 1
                    done = n_pending[0] == 0
                if done:
                    streamed.set()
        obj_rank = min(by_rank) if by_rank else 0
        save_domains: Dict[str, Dict[str, List[str]]] = {}
        file_domains: Dict[str, Dict[str, Any]] = {}
        for rank, records in sorted(by_rank.items()):
            provs: List[Any] = []
            domains_meta: Dict[str, Dict[str, List[str]]] = {}

            def note_domain(domain: str, provider: str, codec: str) -> None:
                e = domains_meta.setdefault(domain,
                                            {"providers": [], "codecs": []})
                if provider not in e["providers"]:
                    e["providers"].append(provider)
                if codec not in e["codecs"]:
                    e["codecs"].append(codec)

            for rec in records:
                kind, factory = resolved[id(rec)]
                kw = dict(
                    dtype=rec.dtype, shape=rec.shape, nbytes=rec.nbytes,
                    host_array=None if rec.device_resident else rec.data,
                    global_shape=rec.global_shape, index=rec.index,
                    chunk_bytes=self.chunk_bytes,
                    stream_intra_tensor=self._stream_intra_tensor)
                if factory is not None:
                    tp = factory(rec, **kw)
                    if not isinstance(tp, TensorStateProvider):
                        raise CheckpointError(
                            f"custom provider factory {kind!r} returned "
                            f"{type(tp).__name__} for {rec.tensor_name!r}"
                            f" — factories must build TensorStateProvider "
                            f"subclasses")
                elif kind == "quantized":
                    tp = QuantizedStateProvider(rec.tensor_name, **kw)
                elif kind == "delta":
                    tp = DeltaStateProvider(
                        rec.tensor_name,
                        prev=self.snapshot_cache.ensure(rec.tensor_name,
                                                        rec.nbytes),
                        keyframe=delta.keyframe, codec=delta.codec, **kw)
                    tp.on_stream_end = streamed_cb
                else:
                    tp = TensorStateProvider(rec.tensor_name, **kw)
                # uniform encoded-provider wiring: defer encode work until
                # the device is drained (the staging lane runs uncontended,
                # so encoded saves add no capture latency over raw
                # snapshots) and bound in-flight payload allocations.
                if getattr(tp, "capture_gate", False) is None:
                    tp.capture_gate = future._captured
                if getattr(tp, "encode_budget", False) is None:
                    tp.encode_budget = encode_budget
                if self.checksum_files and hasattr(tp, "checksum_chunks"):
                    # fused encode emits per-chunk payload digests in the
                    # same pass; the footer stores them for verified decode
                    tp.checksum_chunks = True
                note_domain(rec.domain, kind,
                            "raw" if getattr(tp, "fixed_offset", True)
                            else getattr(tp, "enc_codec", "raw"))
                provs.append(tp)
                if rec.device_resident:
                    capture_items.append((tp, rec.data))
            if rank == obj_rank:
                provs.extend(self._object_providers(objects, future))
                for key in objects:
                    dom = _object_domain(key)
                    if dom is not None:
                        note_domain(dom, "object", "pickle")
            meta = {"rank": rank}
            if delta is not None:
                meta["delta"] = delta.manifest_meta()
            path = rank_file(directory, rank)
            if domains_meta:
                meta["domains"] = domains_meta
                merge_domains_meta(save_domains, domains_meta)
                file_domains[os.path.basename(path)] = domains_meta
            plans.append(FilePlan(path,
                                  CompositeStateProvider(f"rank{rank}", provs),
                                  meta=meta))
        if not by_rank:  # objects only
            provs = self._object_providers(objects, future)
            meta = {"rank": 0}
            if delta is not None:
                meta["delta"] = delta.manifest_meta()
            domains_meta = {}
            for key in objects:
                dom = _object_domain(key)
                if dom is not None:
                    domains_meta.setdefault(dom, {"providers": ["object"],
                                                  "codecs": ["pickle"]})
            path = rank_file(directory, 0)
            if domains_meta:
                meta["domains"] = domains_meta
                merge_domains_meta(save_domains, domains_meta)
                file_domains[os.path.basename(path)] = domains_meta
            plans.append(FilePlan(path,
                                  CompositeStateProvider("rank0", provs),
                                  meta=meta))
        if save_domains:
            # one derivation feeds the per-file footers (above), the
            # per-file FileEntry.domains catalog records (file_domains —
            # threaded to the committer so commit never has to re-parse
            # footers), and the step-level StepManifest.meta["domains"] —
            # all from the live provider instances (merged across rank
            # lanes by the coordinator).
            merge_domains_meta(
                future.stats.extra.setdefault("domains", {}), save_domains)
            future.stats.extra.setdefault("file_domains", {}).update(
                file_domains)
        self._engine.submit(plans, capture_items, future)
        if delta is not None:
            # Registered only now: a prologue failure above (cache full,
            # oversized payload) propagates to the caller without ever
            # settling `streamed`/the future — gating the next save on it
            # would stall the retry for the full gate timeout. Nothing has
            # streamed before submit succeeds, so there is nothing to
            # order against on those paths.
            self._delta_prev = (streamed, future)

    def drain(self) -> None:
        self._engine.drain()

    def close(self) -> None:
        self._engine.close()


class DataStatesOldEngine(DataStatesEngine):
    """HPDC'24 engine: lazy capture + async flush, but blocking up-front
    object serialization and tensor-granular (non-streamed) staging."""

    name = "datastates-old"
    _stream_intra_tensor = False
    _blocking_object_serialization = True


# --------------------------------------------------------------------------
class SnapshotThenFlushEngine(BaseCheckpointEngine):
    """TorchSnapshot-style: blocking snapshot of everything, then async
    multi-threaded chunk-file flush (one *file per chunk*)."""

    name = "snapshot"

    CHUNK_FILE_BYTES = 64 << 20

    def __init__(self, **kw):
        super().__init__(**kw)
        self._q: "queue.Queue[Optional[tuple]]" = queue.Queue()
        self._threads = [threading.Thread(target=self._worker, daemon=True,
                                          name=f"snapshot-flush-{i}")
                         for i in range(self.flush_threads)]
        for t in self._threads:
            t.start()

    def save(self, directory, by_rank, objects, future, delta=None) -> None:
        if delta is not None:
            raise ValueError(
                "differential checkpointing requires a DataMovementEngine "
                "mode; the snapshot baseline cannot encode deltas")
        _reject_encoded_routes(by_rank, self.name)
        stats = future.stats
        # (1) blocking: metadata/object serialization first (precompute the
        # layout manifest up front — §IV-D's "do the opposite" pattern).
        t0 = time.perf_counter()
        obj_payload = pickle.dumps(objects, protocol=pickle.HIGHEST_PROTOCOL)
        stats.serialize_s += time.perf_counter() - t0
        stats.bytes_objects += len(obj_payload)

        # (2) blocking D2H snapshot: fresh (non-pinned) allocations each time.
        t0 = time.perf_counter()
        snapshots: Dict[int, List[tuple]] = {}
        for rank, records in sorted(by_rank.items()):
            for rec in records:
                host = np.array(np.asarray(rec.data), copy=True)  # alloc+copy
                snapshots.setdefault(rank, []).append((rec, host))
                stats.bytes_tensors += rec.nbytes
                stats.n_tensors += 1
        stats.stage_s += time.perf_counter() - t0
        future._set_captured()

        # (3) async: chunk-file writes + per-rank manifest.
        pending = {"n": 0}
        lock = threading.Lock()

        def done_one():
            with lock:
                pending["n"] -= 1
                last = pending["n"] == 0
            if last:
                future._set_persisted()

        jobs = []
        for rank, snaps in snapshots.items():
            manifest = {"tensors": [], "objects": None}
            for rec, host in snaps:
                n_chunks = max(1, -(-rec.nbytes // self.CHUNK_FILE_BYTES))
                chunk_paths = []
                flat = host.reshape(-1).view(np.uint8)
                for ci in range(n_chunks):
                    lo = ci * self.CHUNK_FILE_BYTES
                    hi = min(lo + self.CHUNK_FILE_BYTES, rec.nbytes)
                    safe = rec.tensor_name.replace("/", "_").replace("@", "_")
                    cpath = os.path.join(
                        directory, f"r{rank:03d}_{safe}_c{ci:04d}.bin")
                    chunk_paths.append((cpath, lo, hi))
                    jobs.append((cpath, flat[lo:hi], future))
                manifest["tensors"].append({
                    "name": rec.tensor_name, "dtype": rec.dtype,
                    "shape": rec.shape, "global_shape": rec.global_shape,
                    "index": rec.index,
                    "chunks": [(p, lo, hi) for p, lo, hi in chunk_paths]})
            mpath = os.path.join(directory, f"manifest_rank{rank:05d}.pkl")
            payload = pickle.dumps(manifest)
            jobs.append((mpath, payload, future))
        if min(by_rank, default=0) in snapshots or not by_rank:
            opath = os.path.join(directory, "objects.pkl")
            jobs.append((opath, obj_payload, future))
        # one job == one file (chunk files + manifests + objects.pkl)
        stats.n_files = len(jobs)
        with lock:
            pending["n"] = len(jobs)
        if not jobs:
            future._set_persisted()
        for path, data, fut in jobs:
            self._q.put((path, data, fut, done_one))

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            path, data, future, done_one = item
            try:
                t0 = time.perf_counter()
                with open(path, "wb") as f:
                    f.write(data)
                    f.flush()
                    maybe_fsync(f.fileno())
                nb = len(data) if isinstance(data, bytes) else data.nbytes
                self._throttle(nb, t0)
                future.stats.flush_s += time.perf_counter() - t0
                done_one()
            except BaseException as exc:  # noqa: BLE001
                future._set_error(exc)
            finally:
                self._q.task_done()

    def drain(self) -> None:
        self._q.join()

    def close(self) -> None:
        for _ in self._threads:
            self._q.put(None)


# --------------------------------------------------------------------------
class SyncSerializedEngine(BaseCheckpointEngine):
    """DeepSpeed-default / torch.save analogue: fully blocking, type-agnostic
    serialization of the whole object graph (tensor payloads deep-copied
    through the pickler), single synchronous write per rank file."""

    name = "sync"

    def save(self, directory, by_rank, objects, future, delta=None) -> None:
        if delta is not None:
            raise ValueError(
                "differential checkpointing requires a DataMovementEngine "
                "mode; the sync baseline cannot encode deltas")
        _reject_encoded_routes(by_rank, self.name)
        stats = future.stats
        obj_rank = min(by_rank) if by_rank else 0
        ranks = sorted(by_rank) if by_rank else [0]
        for rank in ranks:
            records = by_rank.get(rank, [])
            t0 = time.perf_counter()
            graph: Dict[str, Any] = {}
            for rec in records:
                # device_get + deep copy through the pickler (type-agnostic)
                graph[rec.tensor_name] = {
                    "data": np.asarray(rec.data), "dtype": rec.dtype,
                    "shape": rec.shape, "global_shape": rec.global_shape,
                    "index": rec.index}
                stats.bytes_tensors += rec.nbytes
                stats.n_tensors += 1
            if rank == obj_rank:
                graph["__objects__"] = objects
            payload = pickle.dumps(graph, protocol=pickle.HIGHEST_PROTOCOL)
            stats.serialize_s += time.perf_counter() - t0
            t0 = time.perf_counter()
            path = rank_file(directory, rank, ext="pkl")
            # Baseline measured as-published (torch.save analogue): a single
            # blocking whole-graph write with no atomic-rename protocol is
            # the behaviour under study; commit visibility still comes from
            # the repository's manifest-last path above this engine.
            with open(path, "wb") as f:  # ckptlint: disable=CKPT301
                f.write(payload)
                f.flush()
                maybe_fsync(f.fileno())
            self._throttle(len(payload), t0)
            stats.flush_s += time.perf_counter() - t0
            stats.n_files += 1
        future._set_captured()
        future._set_persisted()


# --------------------------------------------------------------------------
# Loaders for the non-native baseline formats (used by tests/benchmarks).

def load_sync_rank(path: str) -> Dict[str, Any]:
    with open(path, "rb") as f:
        return pickle.load(f)


def load_snapshot_rank(directory: str, rank: int) -> Dict[str, np.ndarray]:
    mpath = os.path.join(directory, f"manifest_rank{rank:05d}.pkl")
    with open(mpath, "rb") as f:
        manifest = pickle.load(f)
    out = {}
    for t in manifest["tensors"]:
        itemsize = np.dtype(t["dtype"]).itemsize
        nbytes = int(np.prod(t["shape"], dtype=np.int64)) * itemsize \
            if t["shape"] else itemsize
        buf = np.empty(nbytes, dtype=np.uint8)
        for cpath, lo, hi in t["chunks"]:
            with open(cpath, "rb") as f:
                buf[lo:hi] = np.frombuffer(f.read(), dtype=np.uint8)
        out[t["name"]] = buf.view(np.dtype(t["dtype"])).reshape(t["shape"])
    return out
