"""Checkpoint manager: lazy non-blocking capture + globally consistent restore.

The manager is the training-runtime-facing API (paper §V-B — the "drop-in
engine"). It is configured by a declarative
:class:`~repro.core.policy.CheckpointPolicy` (``from_policy``; the legacy
flat-kwarg constructor is a deprecation shim), owns an engine (DataStates
or one of the baselines), plans the per-rank shard composition — routing
each leaf of the named state domains through the policy's
:class:`~repro.core.registry.StateProviderRegistry` — and exposes the two
consistency points of the lazy protocol (paper §V-A2, Fig 6(c,d)):

* ``save(step, state)`` — returns immediately after the blocking prologue
  (planning + coalesced reservation + async D2H launch);
* ``wait_for_capture()`` — the barrier the training loop calls **before the
  optimizer update** of the following iteration: the update mutates (donates)
  the very buffers being snapshotted, so it may only run once all device
  state has left the device.

Persisted steps live in a :class:`~repro.storage.CheckpointRepository`:
once an engine reports a step fully persisted, a background committer
writes the step's catalog manifest (file list, sizes, kernel checksums)
atomically *last* — so ``latest_step()`` only ever sees complete steps —
then hands the step to the repository's cascade flusher for replication to
any configured remote tiers, and triggers retention GC.

Restore is elastic: shards are reassembled to *any* requested sharding (the
stored shard boundaries come from the training layout at save time; restore
intersects them with the target layout, so mesh-shape changes between save
and restore are supported — a beyond-paper capability). Resolution falls
back tier-by-tier: a step missing from the local tier is re-hydrated from
the first remote tier holding a complete copy, and ``step=None`` restores
walk the catalog newest→oldest past damaged steps.
"""

from __future__ import annotations

import contextlib
import os
import queue
import threading
import time
import warnings
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.analysis.locks import declares_lock
from repro.obs import trace as obs
from repro.obs.metrics import metrics as obs_metrics
from repro.storage.backend import BackendError
from repro.storage.repository import (CheckpointRepository, RetentionPolicy,
                                      Tier, committed_steps)

from .baselines import (BaseCheckpointEngine, DataStatesEngine,
                        DataStatesOldEngine, SnapshotThenFlushEngine,
                        SyncSerializedEngine)
from .distributed import group_by_rank, plan_shards
from .engine import CheckpointError, CheckpointFuture
# DeltaPolicy moved to repro.core.policy; re-exported here (and from
# repro.core) for backward compatibility.
from .policy import (CheckpointPolicy, DeltaPolicy, DistPolicy,  # noqa: F401
                     EnginePolicy, StoragePolicy)
from .restore import RestoreEngine, RestoreError, RestoreStats
from .state_provider import DeltaSaveSpec

ENGINES = {
    "datastates": DataStatesEngine,          # this paper
    "datastates-old": DataStatesOldEngine,   # HPDC'24 prior work
    "snapshot": SnapshotThenFlushEngine,     # TorchSnapshot-style
    "sync": SyncSerializedEngine,            # DeepSpeed default (torch.save)
}

# Sentinel distinguishing "kwarg not passed" from an explicit value, so the
# deprecation shim can tell legacy constructor use from plain defaults.
_UNSET: Any = object()


@declares_lock("manager.delta_tracker", rank=30, attrs=("_lock",))
class _DeltaChainTracker:
    """Decides keyframe vs delta per save and tracks the chain position.

    The fingerprint (shard names + dtypes + sizes) detects elastic
    reshards; any engine/commit failure invalidates the tracker so the
    next save re-arms the chain with a keyframe.
    """

    def __init__(self, policy: DeltaPolicy):
        self.policy = policy
        self._lock = threading.Lock()
        self._fingerprint: Optional[tuple] = None
        self._last_step: Optional[int] = None
        self._n_since_keyframe = 0

    def plan(self, step: int, records) -> DeltaSaveSpec:
        fp = tuple(sorted((r.tensor_name, r.dtype, int(r.nbytes))
                          for r in records))
        with self._lock:
            if self._last_step is not None and step <= self._last_step:
                # rewind-resave: chaining onto a *later* step would record
                # base_step > step (a cycle); re-arm with a keyframe
                self._fingerprint = None
                self._last_step = None
            keyframe = (
                self._fingerprint != fp
                or self._last_step is None
                or self._n_since_keyframe >= self.policy.keyframe_every - 1)
            if keyframe:
                spec = DeltaSaveSpec(step=step, keyframe=True,
                                     codec=self.policy.codec)
                self._n_since_keyframe = 0
            else:
                spec = DeltaSaveSpec(
                    step=step, keyframe=False, base_step=self._last_step,
                    chain_depth=self._n_since_keyframe + 1,
                    codec=self.policy.codec)
                self._n_since_keyframe += 1
            self._fingerprint = fp
            self._last_step = step
        return spec

    def invalidate(self) -> None:
        """A save failed (engine error or commit abort): the snapshot
        cache / on-disk chain can no longer be trusted as a base."""
        with self._lock:
            self._fingerprint = None
            self._last_step = None
            self._n_since_keyframe = 0


def step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"global_step{step}")


def latest_step(directory: str) -> Optional[int]:
    """Highest *complete* step, or None.

    Complete = committed to the repository catalog (manifest present), or
    a legacy pre-repository directory that passes the per-format
    completeness probe. A directory left by a crashed save — data files
    but no manifest — is never eligible, so resume cannot select a
    half-written checkpoint (the seed picked any ``global_step*`` dir).
    """
    steps = committed_steps(directory)
    return steps[-1] if steps else None


# ---------------------------------------------------------------------------
# Shared catalog-driven restore path. CheckpointManager.restore,
# Trainer.resume, and serving.load_params_for_serving all land here, so
# selective (per-domain) restore, delta-chain replay, tier fallback, and
# damaged-step skipping behave identically everywhere.

def _subset_template(template: Any, domains: Optional[Sequence[str]]) -> Any:
    """Restrict ``template`` to the requested state domains."""
    if domains is None:
        return template
    if not isinstance(template, dict):
        raise ValueError(
            "restore(domains=...) needs the template to be a mapping of "
            "named state domains at its top level "
            "({'model': ..., 'optimizer': ..., ...})")
    missing = [d for d in domains if d not in template]
    if missing:
        raise KeyError(
            f"requested domains {missing} not in template "
            f"(have {sorted(template)})")
    return {d: template[d] for d in domains}


def _chain_for(repository: CheckpointRepository, step: int) -> List[int]:
    """[keyframe, ..., step] for a differential step (ascending), or
    ``[step]`` for a full snapshot / legacy manifest-less step. Strict
    walk: an unreadable ancestor or corrupt base metadata is a broken
    chain, never a shorter one."""
    try:
        return repository.chain_steps(step, strict=True)
    except (BackendError, OSError, ValueError) as exc:
        raise RestoreError(
            f"step {step}: delta chain unreadable — {exc}") from exc


def _verify_chain(repository: CheckpointRepository,
                  chain: Sequence[int]) -> None:
    """Every member of a delta chain must be checksum-clean before
    replay: XOR folding silently amplifies a corrupt keyframe or
    intermediate delta into every downstream tensor."""
    for c in chain:
        if not repository.has_manifest(c):
            continue  # re-hydrated legacy copy: nothing to audit against
        res = repository.verify_step(c)
        if not res.ok:
            raise RestoreError(
                f"delta-chain member step {c} failed verification "
                f"({', '.join(res.problems)}) — refusing chain replay")


def restore_from_repository(
        repository: CheckpointRepository, template: Any, *,
        step: Optional[int] = None,
        engine: Optional[RestoreEngine] = None,
        fallback: Optional[bool] = None,
        domains: Optional[Sequence[str]] = None,
        verify_chain: bool = True) -> Tuple[Any, RestoreStats, int]:
    """Rebuild ``template``-shaped state from a repository's catalog.

    ``domains`` restricts the restore to the named state domains (top-level
    keys of the template mapping): only those sub-trees are planned, so
    only their byte ranges are read — the bytes-minimal selective restore
    of arXiv 2512.24511 — and the returned tree keeps the template's own
    values for every unrequested domain.

    Step selection, tier fallback, and delta-chain replay follow
    :meth:`CheckpointManager.restore` semantics exactly (this *is* that
    path): ``step=None`` walks committed steps newest→oldest past damaged
    ones, an explicit step surfaces its own error, and a step evicted
    from the local tier is re-hydrated from the first remote tier holding
    a complete copy. Returns ``(tree, stats, restored_step)``.
    """
    sub_template = _subset_template(template, domains)
    if step is None:
        candidates = list(reversed(repository.steps()))
        if not candidates:
            raise FileNotFoundError(f"no checkpoints in {repository.root}")
        if fallback is None:
            fallback = True
    else:
        candidates = [step]
        if fallback is None:
            fallback = False
    eng = engine or RestoreEngine()
    last_exc: Optional[BaseException] = None
    for s in candidates:
        try:
            chain = _chain_for(repository, s)
            with contextlib.ExitStack() as stack:
                for c in chain:  # shield the whole chain from auto-GC
                    stack.enter_context(repository.reading(c))
                sdirs = [repository.resolve_for_restore(c) for c in chain]
                if len(chain) > 1 and verify_chain:
                    _verify_chain(repository, chain)
                if len(chain) == 1:
                    tree, stats = eng.restore(sdirs[0], sub_template)
                else:
                    tree, stats = eng.restore_chain(sdirs, sub_template)
        except (RestoreError, FileNotFoundError, KeyError, OSError,
                BackendError, ValueError) as exc:
            if not fallback:
                raise
            last_exc = exc
            continue
        if domains is not None:
            merged = dict(template)
            merged.update(tree)
            tree = merged
        return tree, stats, s
    raise RestoreError(
        f"no restorable checkpoint among steps {candidates} in "
        f"{repository.root}") from last_exc


class CheckpointManager:
    def __init__(self, directory: str, mode: str = _UNSET,
                 host_cache_bytes: int = _UNSET,
                 flush_threads: int = _UNSET,
                 chunk_bytes: int = _UNSET,
                 throttle_mbps: Optional[float] = _UNSET,
                 restore_threads: Optional[int] = _UNSET,
                 tiers: Sequence[Tier] = _UNSET,
                 retention: Optional[RetentionPolicy] = _UNSET,
                 manifest_checksums: bool = _UNSET,
                 world: Optional[int] = _UNSET,
                 coordinator: Optional[Any] = _UNSET,
                 ack_timeout_s: Optional[float] = _UNSET,
                 delta: Optional[DeltaPolicy] = _UNSET,
                 *, policy: Optional[CheckpointPolicy] = None):
        """Construct a manager.

        .. deprecated::
            The flat-kwarg surface (``mode=``, ``tiers=``, ``world=``,
            ``delta=``, ...) is deprecated: every kwarg maps onto exactly
            one field of a :class:`~repro.core.policy.CheckpointPolicy`
            (see ``LEGACY_KWARG_MAP`` / the README migration table).
            Compose a policy and call :meth:`from_policy` instead; legacy
            kwargs keep working through
            :meth:`CheckpointPolicy.from_legacy_kwargs` but emit a
            ``DeprecationWarning``.
        """
        legacy = {k: v for k, v in dict(
            mode=mode, host_cache_bytes=host_cache_bytes,
            flush_threads=flush_threads, chunk_bytes=chunk_bytes,
            throttle_mbps=throttle_mbps, restore_threads=restore_threads,
            tiers=tiers, retention=retention,
            manifest_checksums=manifest_checksums, world=world,
            coordinator=coordinator, ack_timeout_s=ack_timeout_s,
            delta=delta).items() if v is not _UNSET}
        if policy is not None and legacy:
            raise ValueError(
                f"pass either policy= or legacy constructor kwargs, not "
                f"both (got {sorted(legacy)} alongside a policy)")
        if policy is None:
            if legacy:
                warnings.warn(
                    "CheckpointManager(directory, mode=..., tiers=..., "
                    "world=..., delta=..., ...) flat kwargs are "
                    "deprecated; compose a CheckpointPolicy and use "
                    "CheckpointManager.from_policy(directory, policy) — "
                    "see the README 'Policy & providers' migration table",
                    DeprecationWarning, stacklevel=2)
            policy = CheckpointPolicy.from_legacy_kwargs(**legacy)
        self._init_from_policy(directory, policy)

    @classmethod
    def from_policy(cls, directory: str,
                    policy: Optional[CheckpointPolicy] = None
                    ) -> "CheckpointManager":
        """The policy-first constructor: one composable
        :class:`~repro.core.policy.CheckpointPolicy` (engine/storage/dist
        sections, an optional
        :class:`~repro.core.policy.DeltaPolicy` chain schedule, and an
        optional :class:`~repro.core.registry.StateProviderRegistry`
        routing state domains to providers) replaces the legacy kwarg
        sprawl. ``policy=None`` means all defaults."""
        return cls(directory, policy=policy or CheckpointPolicy())

    def _init_from_policy(self, directory: str,
                          policy: CheckpointPolicy) -> None:
        ep, sp, dp = policy.engine, policy.storage, policy.dist
        if ep.mode not in ENGINES:
            raise ValueError(f"unknown engine mode {ep.mode!r}; "
                             f"choose from {sorted(ENGINES)}")
        delta = policy.delta
        if delta is not None and ep.mode not in ("datastates",
                                                 "datastates-old"):
            raise ValueError(
                f"differential checkpointing requires a DataMovementEngine "
                f"mode (datastates / datastates-old), got {ep.mode!r}")
        self.policy = policy
        self.registry = policy.providers
        self.delta_policy = delta
        self._delta_tracker = _DeltaChainTracker(delta) \
            if delta is not None else None
        # last save's surviving writer set (multi-rank): a change means
        # shard slices moved between rank engines, so every per-rank
        # delta base is stale and the next save must keyframe
        self._last_writers: Optional[tuple] = None
        self.directory = directory
        self.mode = ep.mode
        os.makedirs(directory, exist_ok=True)
        self.repository = CheckpointRepository(
            directory, remote_tiers=sp.tiers, retention=sp.retention,
            checksum=sp.manifest_checksums)
        coordinator = dp.coordinator
        if coordinator is None and dp.world is not None and dp.world > 1:
            from repro.dist.coordinator import Coordinator

            # ``world=N`` (N > 1) or an explicit coordinator switches
            # saves onto the multi-rank path: N simulated writer ranks,
            # each with its own engine + host-cache lane, drain a
            # balanced partition of the shards concurrently; the step
            # becomes visible only after every rank acks and the global
            # manifest commits (two-phase commit — repro.dist.
            # coordinator). host_cache_bytes and flush_threads stay
            # *node totals*: divided across the ranks, so world=N
            # neither multiplies the staging budget nor loosens
            # back-pressure (a coordinator built by hand takes per-rank
            # values instead). Restore is unchanged (and elastic): an
            # N-rank save restores onto any mesh/world.
            coordinator = Coordinator(
                dp.world, mode=ep.mode,
                runtime=dp.runtime, node_size=dp.node_size,
                host_cache_bytes=max(1, ep.host_cache_bytes // dp.world),
                flush_threads=max(1, ep.flush_threads // dp.world),
                chunk_bytes=ep.chunk_bytes,
                throttle_mbps=ep.throttle_mbps,
                checksum_files=sp.manifest_checksums,
                ack_timeout_s=dp.ack_timeout_s)
        if coordinator is not None and dp.world is not None \
                and coordinator.world != dp.world:
            raise ValueError(
                f"world={dp.world} does not match the provided "
                f"coordinator's world={coordinator.world}")
        self.coordinator = coordinator
        # Multi-rank managers save through the coordinator's per-rank
        # engines; constructing the single-writer engine too would burn a
        # host-cache buffer + idle flush threads per manager for a lane
        # that never runs.
        self.engine: Optional[BaseCheckpointEngine] = None
        if coordinator is None:
            self.engine = ENGINES[ep.mode](
                host_cache_bytes=ep.host_cache_bytes,
                flush_threads=ep.flush_threads,
                chunk_bytes=ep.chunk_bytes,
                throttle_mbps=ep.throttle_mbps,
                checksum_files=sp.manifest_checksums)
        self.restore_engine = RestoreEngine(threads=ep.restore_threads)
        self.last_restore_stats: Optional[RestoreStats] = None
        self.last_restored_step: Optional[int] = None
        self._inflight: List[CheckpointFuture] = []
        # Committer lane: waits for engine persist, then commits the step's
        # manifest to the catalog (and kicks cascade + retention GC) off
        # the training path.
        self._commit_q: "queue.Queue[Optional[CheckpointFuture]]" = \
            queue.Queue()
        self._commit_events: Dict[int, threading.Event] = {}
        self.commit_errors: List[tuple] = []
        self._committer = threading.Thread(
            target=self._commit_worker, daemon=True, name="ckpt-commit")
        self._committer.start()

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, blocking: bool = False
             ) -> CheckpointFuture:
        """Request a checkpoint of ``state`` (any pytree of jax/np arrays +
        Python objects). Returns after the engine's blocking prologue only."""
        future = CheckpointFuture(step, step_dir(self.directory, step))
        t0 = time.perf_counter()
        future.stats.t_request = t0
        obs.instant("save.request", step=step,
                    flow=obs.flow_id("save", step), flow_phase="start")
        # A previous save of this very step still in flight would have its
        # directory rmtree'd under its flush threads by begin_step, and
        # its committer could then manifest our half-written files. Settle
        # it first (no-op unless the caller re-saves the same step).
        self.wait_for_commit(step)
        records, objects = plan_shards(state, group="state",
                                       registry=self.registry)
        world = self.coordinator.world if self.coordinator is not None else 1
        objects["__checkpoint_meta__"] = {"step": step, "mode": self.mode,
                                          "n_shards": len(records),
                                          "world": world}
        delta_spec = None
        if self._delta_tracker is not None:
            if self.coordinator is not None:
                # a rank death reassigns its shard slice to survivors
                # whose engines hold no snapshot of it: force a keyframe
                # whenever the writer set changed since the last save
                writers_now = self.coordinator.active_writers()
                if self._last_writers is not None \
                        and writers_now != self._last_writers:
                    self._delta_tracker.invalidate()
                self._last_writers = writers_now
            delta_spec = self._delta_tracker.plan(step, records)
            future.stats.extra["delta"] = delta_spec.manifest_meta()
        # (the engines fill stats.extra["domains"] — the step-level
        # domain→provider/codec summary — from their live provider
        # instances, so it can never drift from the per-file footers)
        # in-flight marker first: a crash at any later point leaves an
        # identifiable orphan, never a resume-eligible directory.
        self.repository.begin_step(step)
        os.makedirs(future.directory, exist_ok=True)
        try:
            if self.coordinator is not None:
                future.stats.extra["world"] = world
                # the commit topology of *this* save (surviving writers +
                # node membership) rides the future so phase 2 validates
                # exactly the votes the save was built to cast
                info = self.coordinator.submit(step, future.directory,
                                               records, objects, future,
                                               delta=delta_spec)
                future.stats.extra["writers"] = info["writers"]
                future.stats.extra["nodes"] = info["nodes"]
            else:
                by_rank = group_by_rank(records)
                self.engine.save(future.directory, by_rank, objects, future,
                                 delta=delta_spec)
        except BaseException:
            # A synchronous prologue failure (e.g. payload exceeds the
            # host cache) never reaches the committer: retract the active
            # claim so in-process GC can reclaim the orphaned directory.
            self.repository.abort_step(step)
            if self._delta_tracker is not None:
                self._delta_tracker.invalidate()
            raise
        future.stats.blocking_s = time.perf_counter() - t0
        obs.add_span("save.prologue", t0, time.perf_counter(), step=step,
                     flow=obs.flow_id("save", step))
        self._inflight.append(future)
        self._inflight = [f for f in self._inflight if not f.persisted] \
            + [f for f in self._inflight if f.persisted][-1:]
        self._commit_events[step] = threading.Event()
        self._commit_q.put(future)
        if blocking:
            future.wait_persisted()
            self.wait_for_commit(step)
        return future

    # -------------------------------------------------------- barriers
    def wait_for_capture(self) -> float:
        """Consistency barrier before the (buffer-donating) optimizer update.

        Returns the time actually spent blocked — this is the *direct stall*
        the paper measures in Fig 8."""
        t0 = time.perf_counter()
        for f in self._inflight:
            f.wait_captured()
        return time.perf_counter() - t0

    def wait_for_persist(self) -> float:
        t0 = time.perf_counter()
        for f in self._inflight:
            f.wait_persisted()
        return time.perf_counter() - t0

    def wait_for_commit(self, step: Optional[int] = None,
                        timeout: Optional[float] = None) -> None:
        """Block until ``step`` (or every pending step) has its catalog
        manifest committed (or its save is known failed). Settled steps
        are pruned from the pending map, so an already-committed step
        returns immediately."""
        if step is not None:
            events = [self._commit_events.get(step)]
        else:
            events = list(self._commit_events.values())
        for ev in events:
            if ev is None:
                continue  # already settled (or never saved here)
            if not ev.wait(timeout):
                raise TimeoutError("manifest commit did not complete in time")

    # ---------------------------------------------------------- committer
    def _commit_worker(self) -> None:
        while True:
            future = self._commit_q.get()
            if future is None:
                self._commit_q.task_done()
                return
            try:
                try:
                    future.wait_persisted()
                except BaseException:  # engine failed: orphan, not commit
                    self.repository.abort_step(future.step)
                    if self._delta_tracker is not None:
                        self._delta_tracker.invalidate()
                else:
                    tc0 = time.perf_counter()
                    meta = {"n_files": future.stats.n_files,
                            "n_tensors": future.stats.n_tensors,
                            "bytes_tensors": future.stats.bytes_tensors,
                            "bytes_objects": future.stats.bytes_objects,
                            # save-phase timings ride the manifest so
                            # `storage.cli stats` works on any repository,
                            # long after the in-process stats are gone
                            "save": {
                                "blocking_s": future.stats.blocking_s,
                                "capture_s":
                                    future.stats.capture_latency_s,
                                "persist_s":
                                    future.stats.persist_latency_s,
                                "persist_to_commit_s":
                                    tc0 - future.stats.t_persisted,
                            }}
                    dmeta = future.stats.extra.get("delta")
                    if dmeta is not None:
                        # chain gate: a delta may only commit onto a
                        # committed base — the committer runs FIFO, so the
                        # base's outcome is already settled here. A failed
                        # base makes this step unrestorable; keep it an
                        # invisible orphan instead of blessing it.
                        base = dmeta.get("base_step")
                        if not dmeta.get("keyframe", True) \
                                and (base is None or
                                     not self.repository.has_manifest(base)):
                            raise CheckpointError(
                                f"step {future.step}: delta base step "
                                f"{base} never committed — refusing to "
                                f"commit a broken chain")
                        meta["delta"] = dmeta
                    doms = future.stats.extra.get("domains")
                    if doms:
                        meta["domains"] = doms
                        # per-file maps, known since plan time: lets the
                        # manifest fill FileEntry.domains without re-
                        # parsing footers (StepManifest.build pops this —
                        # it is never stored in the manifest meta itself)
                        fdoms = future.stats.extra.get("file_domains")
                        if fdoms:
                            meta["file_domains"] = fdoms
                    # per-file checksums accumulated by the writers while
                    # persisting — StepManifest.build pops this and reuses
                    # them instead of re-reading every byte on the commit
                    # lane (never stored in the manifest meta itself)
                    fsums = future.stats.extra.get("file_checksums")
                    if fsums:
                        meta["file_checksums"] = fsums
                    # Multi-rank saves commit with their full topology:
                    # the phase-2 gate re-validates every surviving
                    # rank's vote and every node manifest before the
                    # step becomes visible.
                    self.repository.commit_step(
                        future.step, engine_mode=self.mode,
                        expect_ranks=future.stats.extra.get("world"),
                        writers=future.stats.extra.get("writers"),
                        nodes=future.stats.extra.get("nodes"),
                        meta=meta)
                    tc1 = time.perf_counter()
                    future.stats.commit_s = tc1 - tc0
                    future.stats.t_committed = tc1
                    obs_metrics.observe("commit.latency_s", tc1 - tc0)
                    obs.add_span("commit", tc0, tc1, step=future.step,
                                 flow=obs.flow_id("save", future.step),
                                 flow_phase="end")
            except BaseException as exc:  # noqa: BLE001
                self.commit_errors.append((future.step, repr(exc)))
                # a failed commit leaves the step an orphan (marker still
                # present); retract the active claim so GC can reclaim it
                self.repository.abort_step(future.step)
                if self._delta_tracker is not None:
                    self._delta_tracker.invalidate()
            finally:
                # prune-then-set: anyone already holding the event still
                # wakes, and the pending map stays bounded over long runs
                ev = self._commit_events.pop(future.step, None)
                if ev is not None:
                    ev.set()
                self._commit_q.task_done()

    # ------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        return self.repository.latest_step()

    def restore(self, template: Any, step: Optional[int] = None,
                engine: Optional[RestoreEngine] = None,
                fallback: Optional[bool] = None,
                domains: Optional[Sequence[str]] = None) -> Any:
        """Rebuild ``template``-shaped state from a stored checkpoint.

        ``template`` leaves may be concrete arrays or ``ShapeDtypeStruct``s
        carrying a ``.sharding``; array leaves are reassembled shard-by-shard
        (elastic — target sharding need not match the stored one, so a run
        can resume onto a different mesh shape).

        ``domains`` selects named state domains (top-level template keys):
        ``restore(state, domains=("model",))`` plans and reads *only* the
        model sub-tree's byte ranges — ``last_restore_stats.bytes_read``
        is the audit — and returns the full template with unrequested
        domains untouched. Serving's ``load_params_for_serving`` is this
        same path.

        Step selection goes through the repository: with ``step=None`` the
        committed steps are tried newest→oldest (``fallback`` defaults on),
        so a checkpoint damaged *after* commit is skipped in favor of the
        previous complete one; an explicit ``step`` is restored exactly
        (``fallback`` defaults off) and surfaces its own error. Either way
        the step directory is re-hydrated from a remote tier when the
        local copy is gone (tier-by-tier fallback).

        The heavy lifting is done by the parallel
        :class:`~repro.core.restore.RestoreEngine`: the step directory is
        indexed once, the shard↔target-region intersections are planned up
        front, and only the intersecting byte ranges are read — as ranged
        positional reads fanned out over a thread pool — directly into
        preallocated destination buffers. Restore is format-universal
        (native ``.dsllm``, snapshot chunk manifests, sync pickle graphs),
        so a run can also switch engines between save and resume.

        Pass ``engine`` to override the manager's default
        (e.g. ``RestoreEngine(threads=1)`` for a serial ablation, or one
        with a read throttle). Per-restore timings and I/O counts are left
        in :attr:`last_restore_stats` (a
        :class:`~repro.core.restore.RestoreStats`)."""
        # Saves requested through this manager may have persisted but not
        # yet committed their manifest; settle the catalog before reading
        # it so a just-finished step is eligible.
        self.wait_for_commit()
        tree, stats, s = restore_from_repository(
            self.repository, template, step=step,
            engine=engine or self.restore_engine, fallback=fallback,
            domains=domains,
            verify_chain=(self.delta_policy is None
                          or self.delta_policy.verify_chain_on_restore))
        self.last_restore_stats = stats
        self.last_restored_step = s
        return tree

    # -------------------------------------------------------------- misc
    def drain(self) -> None:
        # settle every in-flight save without raising: a failed save must
        # not wedge shutdown (its error already surfaced to the caller via
        # wait_for_persist/wait_for_capture and commit_errors)
        for f in self._inflight:
            f._persisted.wait()
        if self.engine is not None:
            self.engine.drain()
        if self.coordinator is not None:
            self.coordinator.drain()
        self._commit_q.join()
        self.repository.drain()

    def close(self) -> None:
        self.drain()
        self._commit_q.put(None)
        self._committer.join(timeout=60)
        if self.engine is not None:
            self.engine.close()
        if self.coordinator is not None:
            self.coordinator.close()
        self.repository.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
