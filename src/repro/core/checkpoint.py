"""Checkpoint manager: lazy non-blocking capture + globally consistent restore.

The manager is the training-runtime-facing API (paper §V-B — the "drop-in
engine"). It owns an engine (DataStates or one of the baselines), plans the
per-rank shard composition, and exposes the two consistency points of the
lazy protocol (paper §V-A2, Fig 6(c,d)):

* ``save(step, state)`` — returns immediately after the blocking prologue
  (planning + coalesced reservation + async D2H launch);
* ``wait_for_capture()`` — the barrier the training loop calls **before the
  optimizer update** of the following iteration: the update mutates (donates)
  the very buffers being snapshotted, so it may only run once all device
  state has left the device.

Persisted steps live in a :class:`~repro.storage.CheckpointRepository`:
once an engine reports a step fully persisted, a background committer
writes the step's catalog manifest (file list, sizes, kernel checksums)
atomically *last* — so ``latest_step()`` only ever sees complete steps —
then hands the step to the repository's cascade flusher for replication to
any configured remote tiers, and triggers retention GC.

Restore is elastic: shards are reassembled to *any* requested sharding (the
stored shard boundaries come from the training layout at save time; restore
intersects them with the target layout, so mesh-shape changes between save
and restore are supported — a beyond-paper capability). Resolution falls
back tier-by-tier: a step missing from the local tier is re-hydrated from
the first remote tier holding a complete copy, and ``step=None`` restores
walk the catalog newest→oldest past damaged steps.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import queue
import threading
import time
from typing import Any, Dict, List, Optional, Sequence

from repro.storage.backend import BackendError
from repro.storage.repository import (CheckpointRepository, RetentionPolicy,
                                      Tier, committed_steps)

from .baselines import (BaseCheckpointEngine, DataStatesEngine,
                        DataStatesOldEngine, SnapshotThenFlushEngine,
                        SyncSerializedEngine)
from .distributed import group_by_rank, plan_shards
from .engine import CheckpointError, CheckpointFuture
from .restore import RestoreEngine, RestoreError, RestoreStats
from .state_provider import DELTA_CODEC, DeltaSaveSpec

ENGINES = {
    "datastates": DataStatesEngine,          # this paper
    "datastates-old": DataStatesOldEngine,   # HPDC'24 prior work
    "snapshot": SnapshotThenFlushEngine,     # TorchSnapshot-style
    "sync": SyncSerializedEngine,            # DeepSpeed default (torch.save)
}


@dataclasses.dataclass(frozen=True)
class DeltaPolicy:
    """Differential checkpointing on the main engine path (paper §VII).

    Every save streams XOR deltas of each tensor against the previous
    save's retained host copy, compressed on the flush lanes — except a
    raw *keyframe* every ``keyframe_every`` saves, on the first save of a
    run, and whenever the shard set / shapes / dtypes change (elastic
    reshard). ``verify_chain_on_restore`` re-audits every chain member
    (sizes + manifest checksums) before a chain restore, so silent
    corruption of a keyframe can never be XOR-amplified into a restored
    state.
    """

    keyframe_every: int = 4
    codec: str = DELTA_CODEC
    verify_chain_on_restore: bool = True

    def __post_init__(self):
        if self.keyframe_every < 1:
            raise ValueError(
                f"keyframe_every must be >= 1, got {self.keyframe_every}")


class _DeltaChainTracker:
    """Decides keyframe vs delta per save and tracks the chain position.

    The fingerprint (shard names + dtypes + sizes) detects elastic
    reshards; any engine/commit failure invalidates the tracker so the
    next save re-arms the chain with a keyframe.
    """

    def __init__(self, policy: DeltaPolicy):
        self.policy = policy
        self._lock = threading.Lock()
        self._fingerprint: Optional[tuple] = None
        self._last_step: Optional[int] = None
        self._n_since_keyframe = 0

    def plan(self, step: int, records) -> DeltaSaveSpec:
        fp = tuple(sorted((r.tensor_name, r.dtype, int(r.nbytes))
                          for r in records))
        with self._lock:
            if self._last_step is not None and step <= self._last_step:
                # rewind-resave: chaining onto a *later* step would record
                # base_step > step (a cycle); re-arm with a keyframe
                self._fingerprint = None
                self._last_step = None
            keyframe = (
                self._fingerprint != fp
                or self._last_step is None
                or self._n_since_keyframe >= self.policy.keyframe_every - 1)
            if keyframe:
                spec = DeltaSaveSpec(step=step, keyframe=True,
                                     codec=self.policy.codec)
                self._n_since_keyframe = 0
            else:
                spec = DeltaSaveSpec(
                    step=step, keyframe=False, base_step=self._last_step,
                    chain_depth=self._n_since_keyframe + 1,
                    codec=self.policy.codec)
                self._n_since_keyframe += 1
            self._fingerprint = fp
            self._last_step = step
        return spec

    def invalidate(self) -> None:
        """A save failed (engine error or commit abort): the snapshot
        cache / on-disk chain can no longer be trusted as a base."""
        with self._lock:
            self._fingerprint = None
            self._last_step = None
            self._n_since_keyframe = 0


def step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"global_step{step}")


def latest_step(directory: str) -> Optional[int]:
    """Highest *complete* step, or None.

    Complete = committed to the repository catalog (manifest present), or
    a legacy pre-repository directory that passes the per-format
    completeness probe. A directory left by a crashed save — data files
    but no manifest — is never eligible, so resume cannot select a
    half-written checkpoint (the seed picked any ``global_step*`` dir).
    """
    steps = committed_steps(directory)
    return steps[-1] if steps else None


class CheckpointManager:
    def __init__(self, directory: str, mode: str = "datastates",
                 host_cache_bytes: int = 1 << 30,
                 flush_threads: int = 4,
                 chunk_bytes: int = 4 << 20,
                 throttle_mbps: Optional[float] = None,
                 restore_threads: Optional[int] = None,
                 tiers: Sequence[Tier] = (),
                 retention: Optional[RetentionPolicy] = None,
                 manifest_checksums: bool = True,
                 world: Optional[int] = None,
                 coordinator: Optional[Any] = None,
                 ack_timeout_s: Optional[float] = None,
                 delta: Optional[DeltaPolicy] = None):
        """``world=N`` (N > 1) or an explicit ``coordinator=`` switches
        saves onto the multi-rank path: N simulated writer ranks, each
        with its own engine + host-cache lane, drain a balanced partition
        of the shards concurrently; the step becomes visible only after
        every rank acks and the global manifest commits (two-phase
        commit — see :mod:`repro.dist.coordinator`). ``host_cache_bytes``
        and ``flush_threads`` stay *node totals*: they are divided across
        the ranks, so ``world=N`` neither multiplies the staging budget
        nor loosens back-pressure (a coordinator built by hand takes
        per-rank values instead). Restore is unchanged (and elastic): an
        N-rank save restores onto any mesh/world."""
        if mode not in ENGINES:
            raise ValueError(f"unknown engine mode {mode!r}; "
                             f"choose from {sorted(ENGINES)}")
        if delta is not None and mode not in ("datastates", "datastates-old"):
            raise ValueError(
                f"differential checkpointing requires a DataMovementEngine "
                f"mode (datastates / datastates-old), got {mode!r}")
        self.delta_policy = delta
        self._delta_tracker = _DeltaChainTracker(delta) \
            if delta is not None else None
        self.directory = directory
        self.mode = mode
        os.makedirs(directory, exist_ok=True)
        self.repository = CheckpointRepository(
            directory, remote_tiers=tiers, retention=retention,
            checksum=manifest_checksums)
        if coordinator is None and world is not None and world > 1:
            from repro.dist.coordinator import Coordinator
            coordinator = Coordinator(
                world, mode=mode,
                host_cache_bytes=max(1, host_cache_bytes // world),
                flush_threads=max(1, flush_threads // world),
                chunk_bytes=chunk_bytes,
                throttle_mbps=throttle_mbps,
                checksum_files=manifest_checksums,
                ack_timeout_s=ack_timeout_s)
        if coordinator is not None and world is not None \
                and coordinator.world != world:
            raise ValueError(
                f"world={world} does not match the provided coordinator's "
                f"world={coordinator.world}")
        self.coordinator = coordinator
        # Multi-rank managers save through the coordinator's per-rank
        # engines; constructing the single-writer engine too would burn a
        # host-cache buffer + idle flush threads per manager for a lane
        # that never runs.
        self.engine: Optional[BaseCheckpointEngine] = None
        if coordinator is None:
            self.engine = ENGINES[mode](
                host_cache_bytes=host_cache_bytes,
                flush_threads=flush_threads,
                chunk_bytes=chunk_bytes,
                throttle_mbps=throttle_mbps)
        self.restore_engine = RestoreEngine(threads=restore_threads)
        self.last_restore_stats: Optional[RestoreStats] = None
        self.last_restored_step: Optional[int] = None
        self._inflight: List[CheckpointFuture] = []
        # Committer lane: waits for engine persist, then commits the step's
        # manifest to the catalog (and kicks cascade + retention GC) off
        # the training path.
        self._commit_q: "queue.Queue[Optional[CheckpointFuture]]" = \
            queue.Queue()
        self._commit_events: Dict[int, threading.Event] = {}
        self.commit_errors: List[tuple] = []
        self._committer = threading.Thread(
            target=self._commit_worker, daemon=True, name="ckpt-commit")
        self._committer.start()

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, blocking: bool = False
             ) -> CheckpointFuture:
        """Request a checkpoint of ``state`` (any pytree of jax/np arrays +
        Python objects). Returns after the engine's blocking prologue only."""
        future = CheckpointFuture(step, step_dir(self.directory, step))
        t0 = time.perf_counter()
        future.stats.t_request = t0
        # A previous save of this very step still in flight would have its
        # directory rmtree'd under its flush threads by begin_step, and
        # its committer could then manifest our half-written files. Settle
        # it first (no-op unless the caller re-saves the same step).
        self.wait_for_commit(step)
        records, objects = plan_shards(state, group="state")
        world = self.coordinator.world if self.coordinator is not None else 1
        objects["__checkpoint_meta__"] = {"step": step, "mode": self.mode,
                                          "n_shards": len(records),
                                          "world": world}
        delta_spec = None
        if self._delta_tracker is not None:
            delta_spec = self._delta_tracker.plan(step, records)
            future.stats.extra["delta"] = delta_spec.manifest_meta()
        # in-flight marker first: a crash at any later point leaves an
        # identifiable orphan, never a resume-eligible directory.
        self.repository.begin_step(step)
        os.makedirs(future.directory, exist_ok=True)
        try:
            if self.coordinator is not None:
                future.stats.extra["world"] = world
                self.coordinator.submit(step, future.directory, records,
                                        objects, future, delta=delta_spec)
            else:
                by_rank = group_by_rank(records)
                self.engine.save(future.directory, by_rank, objects, future,
                                 delta=delta_spec)
        except BaseException:
            # A synchronous prologue failure (e.g. payload exceeds the
            # host cache) never reaches the committer: retract the active
            # claim so in-process GC can reclaim the orphaned directory.
            self.repository.abort_step(step)
            if self._delta_tracker is not None:
                self._delta_tracker.invalidate()
            raise
        future.stats.blocking_s = time.perf_counter() - t0
        self._inflight.append(future)
        self._inflight = [f for f in self._inflight if not f.persisted] \
            + [f for f in self._inflight if f.persisted][-1:]
        self._commit_events[step] = threading.Event()
        self._commit_q.put(future)
        if blocking:
            future.wait_persisted()
            self.wait_for_commit(step)
        return future

    # -------------------------------------------------------- barriers
    def wait_for_capture(self) -> float:
        """Consistency barrier before the (buffer-donating) optimizer update.

        Returns the time actually spent blocked — this is the *direct stall*
        the paper measures in Fig 8."""
        t0 = time.perf_counter()
        for f in self._inflight:
            f.wait_captured()
        return time.perf_counter() - t0

    def wait_for_persist(self) -> float:
        t0 = time.perf_counter()
        for f in self._inflight:
            f.wait_persisted()
        return time.perf_counter() - t0

    def wait_for_commit(self, step: Optional[int] = None,
                        timeout: Optional[float] = None) -> None:
        """Block until ``step`` (or every pending step) has its catalog
        manifest committed (or its save is known failed). Settled steps
        are pruned from the pending map, so an already-committed step
        returns immediately."""
        if step is not None:
            events = [self._commit_events.get(step)]
        else:
            events = list(self._commit_events.values())
        for ev in events:
            if ev is None:
                continue  # already settled (or never saved here)
            if not ev.wait(timeout):
                raise TimeoutError("manifest commit did not complete in time")

    # ---------------------------------------------------------- committer
    def _commit_worker(self) -> None:
        while True:
            future = self._commit_q.get()
            if future is None:
                self._commit_q.task_done()
                return
            try:
                try:
                    future.wait_persisted()
                except BaseException:  # engine failed: orphan, not commit
                    self.repository.abort_step(future.step)
                    if self._delta_tracker is not None:
                        self._delta_tracker.invalidate()
                else:
                    meta = {"n_files": future.stats.n_files,
                            "n_tensors": future.stats.n_tensors,
                            "bytes_tensors": future.stats.bytes_tensors,
                            "bytes_objects": future.stats.bytes_objects}
                    dmeta = future.stats.extra.get("delta")
                    if dmeta is not None:
                        # chain gate: a delta may only commit onto a
                        # committed base — the committer runs FIFO, so the
                        # base's outcome is already settled here. A failed
                        # base makes this step unrestorable; keep it an
                        # invisible orphan instead of blessing it.
                        base = dmeta.get("base_step")
                        if not dmeta.get("keyframe", True) \
                                and (base is None or
                                     not self.repository.has_manifest(base)):
                            raise CheckpointError(
                                f"step {future.step}: delta base step "
                                f"{base} never committed — refusing to "
                                f"commit a broken chain")
                        meta["delta"] = dmeta
                    # Multi-rank saves commit with expect_ranks: the
                    # phase-2 gate re-validates every rank's vote before
                    # the step becomes visible.
                    self.repository.commit_step(
                        future.step, engine_mode=self.mode,
                        expect_ranks=future.stats.extra.get("world"),
                        meta=meta)
            except BaseException as exc:  # noqa: BLE001
                self.commit_errors.append((future.step, repr(exc)))
                # a failed commit leaves the step an orphan (marker still
                # present); retract the active claim so GC can reclaim it
                self.repository.abort_step(future.step)
                if self._delta_tracker is not None:
                    self._delta_tracker.invalidate()
            finally:
                # prune-then-set: anyone already holding the event still
                # wakes, and the pending map stays bounded over long runs
                ev = self._commit_events.pop(future.step, None)
                if ev is not None:
                    ev.set()
                self._commit_q.task_done()

    # ------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        return self.repository.latest_step()

    def restore(self, template: Any, step: Optional[int] = None,
                engine: Optional[RestoreEngine] = None,
                fallback: Optional[bool] = None) -> Any:
        """Rebuild ``template``-shaped state from a stored checkpoint.

        ``template`` leaves may be concrete arrays or ``ShapeDtypeStruct``s
        carrying a ``.sharding``; array leaves are reassembled shard-by-shard
        (elastic — target sharding need not match the stored one, so a run
        can resume onto a different mesh shape).

        Step selection goes through the repository: with ``step=None`` the
        committed steps are tried newest→oldest (``fallback`` defaults on),
        so a checkpoint damaged *after* commit is skipped in favor of the
        previous complete one; an explicit ``step`` is restored exactly
        (``fallback`` defaults off) and surfaces its own error. Either way
        the step directory is re-hydrated from a remote tier when the
        local copy is gone (tier-by-tier fallback).

        The heavy lifting is done by the parallel
        :class:`~repro.core.restore.RestoreEngine`: the step directory is
        indexed once, the shard↔target-region intersections are planned up
        front, and only the intersecting byte ranges are read — as ranged
        positional reads fanned out over a thread pool — directly into
        preallocated destination buffers. Restore is format-universal
        (native ``.dsllm``, snapshot chunk manifests, sync pickle graphs),
        so a run can also switch engines between save and resume.

        Pass ``engine`` to override the manager's default
        (e.g. ``RestoreEngine(threads=1)`` for a serial ablation, or one
        with a read throttle). Per-restore timings and I/O counts are left
        in :attr:`last_restore_stats` (a
        :class:`~repro.core.restore.RestoreStats`)."""
        # Saves requested through this manager may have persisted but not
        # yet committed their manifest; settle the catalog before reading
        # it so a just-finished step is eligible.
        self.wait_for_commit()
        if step is None:
            candidates = list(reversed(self.repository.steps()))
            if not candidates:
                raise FileNotFoundError(f"no checkpoints in {self.directory}")
            if fallback is None:
                fallback = True
        else:
            candidates = [step]
            if fallback is None:
                fallback = False
        last_exc: Optional[BaseException] = None
        eng = engine or self.restore_engine
        for s in candidates:
            try:
                chain = self._delta_chain(s)
                with contextlib.ExitStack() as stack:
                    for c in chain:  # shield the whole chain from auto-GC
                        stack.enter_context(self.repository.reading(c))
                    sdirs = [self.repository.resolve_for_restore(c)
                             for c in chain]
                    if len(chain) > 1 and (
                            self.delta_policy is None
                            or self.delta_policy.verify_chain_on_restore):
                        self._verify_chain(chain)
                    if len(chain) == 1:
                        tree, stats = eng.restore(sdirs[0], template)
                    else:
                        tree, stats = eng.restore_chain(sdirs, template)
            except (RestoreError, FileNotFoundError, KeyError, OSError,
                    BackendError, ValueError) as exc:
                if not fallback:
                    raise
                last_exc = exc
                continue
            self.last_restore_stats = stats
            self.last_restored_step = s
            return tree
        raise RestoreError(
            f"no restorable checkpoint among steps {candidates} in "
            f"{self.directory}") from last_exc

    def _delta_chain(self, step: int) -> List[int]:
        """[keyframe, ..., step] for a differential step (ascending), or
        ``[step]`` for a full snapshot / legacy manifest-less step.
        Strict walk: an unreadable ancestor or corrupt base metadata is a
        broken chain, never a shorter one."""
        try:
            return self.repository.chain_steps(step, strict=True)
        except (BackendError, OSError, ValueError) as exc:
            raise RestoreError(
                f"step {step}: delta chain unreadable — {exc}") from exc

    def _verify_chain(self, chain: Sequence[int]) -> None:
        """Every member of a delta chain must be checksum-clean before
        replay: XOR folding silently amplifies a corrupt keyframe or
        intermediate delta into every downstream tensor."""
        for c in chain:
            if not self.repository.has_manifest(c):
                continue  # re-hydrated legacy copy: nothing to audit against
            res = self.repository.verify_step(c)
            if not res.ok:
                raise RestoreError(
                    f"delta-chain member step {c} failed verification "
                    f"({', '.join(res.problems)}) — refusing chain replay")

    # -------------------------------------------------------------- misc
    def drain(self) -> None:
        # settle every in-flight save without raising: a failed save must
        # not wedge shutdown (its error already surfaced to the caller via
        # wait_for_persist/wait_for_capture and commit_errors)
        for f in self._inflight:
            f._persisted.wait()
        if self.engine is not None:
            self.engine.drain()
        if self.coordinator is not None:
            self.coordinator.drain()
        self._commit_q.join()
        self.repository.drain()

    def close(self) -> None:
        self.drain()
        self._commit_q.put(None)
        self._committer.join(timeout=60)
        if self.engine is not None:
            self.engine.close()
        if self.coordinator is not None:
            self.coordinator.close()
        self.repository.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
