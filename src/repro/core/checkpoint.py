"""Checkpoint manager: lazy non-blocking capture + globally consistent restore.

The manager is the training-runtime-facing API (paper §V-B — the "drop-in
engine"). It owns an engine (DataStates or one of the baselines), plans the
per-rank shard composition, and exposes the two consistency points of the
lazy protocol (paper §V-A2, Fig 6(c,d)):

* ``save(step, state)`` — returns immediately after the blocking prologue
  (planning + coalesced reservation + async D2H launch);
* ``wait_for_capture()`` — the barrier the training loop calls **before the
  optimizer update** of the following iteration: the update mutates (donates)
  the very buffers being snapshotted, so it may only run once all device
  state has left the device.

Restore is elastic: shards are reassembled to *any* requested sharding (the
stored shard boundaries come from the training layout at save time; restore
intersects them with the target layout, so mesh-shape changes between save
and restore are supported — a beyond-paper capability).
"""

from __future__ import annotations

import glob
import os
import re
import time
from typing import Any, List, Optional

from .baselines import (BaseCheckpointEngine, DataStatesEngine,
                        DataStatesOldEngine, SnapshotThenFlushEngine,
                        SyncSerializedEngine)
from .distributed import group_by_rank, plan_shards
from .engine import CheckpointFuture
from .restore import RestoreEngine, RestoreStats

ENGINES = {
    "datastates": DataStatesEngine,          # this paper
    "datastates-old": DataStatesOldEngine,   # HPDC'24 prior work
    "snapshot": SnapshotThenFlushEngine,     # TorchSnapshot-style
    "sync": SyncSerializedEngine,            # DeepSpeed default (torch.save)
}


def step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"global_step{step}")


def latest_step(directory: str) -> Optional[int]:
    """Highest step with a ``global_step*`` directory, or None."""
    steps = []
    for d in glob.glob(os.path.join(directory, "global_step*")):
        m = re.search(r"global_step(\d+)$", d)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


class CheckpointManager:
    def __init__(self, directory: str, mode: str = "datastates",
                 host_cache_bytes: int = 1 << 30,
                 flush_threads: int = 4,
                 chunk_bytes: int = 4 << 20,
                 throttle_mbps: Optional[float] = None,
                 restore_threads: Optional[int] = None):
        if mode not in ENGINES:
            raise ValueError(f"unknown engine mode {mode!r}; "
                             f"choose from {sorted(ENGINES)}")
        self.directory = directory
        self.mode = mode
        os.makedirs(directory, exist_ok=True)
        self.engine: BaseCheckpointEngine = ENGINES[mode](
            host_cache_bytes=host_cache_bytes,
            flush_threads=flush_threads,
            chunk_bytes=chunk_bytes,
            throttle_mbps=throttle_mbps)
        self.restore_engine = RestoreEngine(threads=restore_threads)
        self.last_restore_stats: Optional[RestoreStats] = None
        self._inflight: List[CheckpointFuture] = []

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, blocking: bool = False
             ) -> CheckpointFuture:
        """Request a checkpoint of ``state`` (any pytree of jax/np arrays +
        Python objects). Returns after the engine's blocking prologue only."""
        future = CheckpointFuture(step, step_dir(self.directory, step))
        t0 = time.perf_counter()
        future.stats.t_request = t0
        records, objects = plan_shards(state, group="state")
        objects["__checkpoint_meta__"] = {"step": step, "mode": self.mode,
                                          "n_shards": len(records)}
        by_rank = group_by_rank(records)
        os.makedirs(future.directory, exist_ok=True)
        self.engine.save(future.directory, by_rank, objects, future)
        future.stats.blocking_s = time.perf_counter() - t0
        self._inflight.append(future)
        self._inflight = [f for f in self._inflight if not f.persisted] \
            + [f for f in self._inflight if f.persisted][-1:]
        if blocking:
            future.wait_persisted()
        return future

    # -------------------------------------------------------- barriers
    def wait_for_capture(self) -> float:
        """Consistency barrier before the (buffer-donating) optimizer update.

        Returns the time actually spent blocked — this is the *direct stall*
        the paper measures in Fig 8."""
        t0 = time.perf_counter()
        for f in self._inflight:
            f.wait_captured()
        return time.perf_counter() - t0

    def wait_for_persist(self) -> float:
        t0 = time.perf_counter()
        for f in self._inflight:
            f.wait_persisted()
        return time.perf_counter() - t0

    # ------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def restore(self, template: Any, step: Optional[int] = None,
                engine: Optional[RestoreEngine] = None) -> Any:
        """Rebuild ``template``-shaped state from a stored checkpoint.

        ``template`` leaves may be concrete arrays or ``ShapeDtypeStruct``s
        carrying a ``.sharding``; array leaves are reassembled shard-by-shard
        (elastic — target sharding need not match the stored one, so a run
        can resume onto a different mesh shape).

        The heavy lifting is done by the parallel
        :class:`~repro.core.restore.RestoreEngine`: the step directory is
        indexed once, the shard↔target-region intersections are planned up
        front, and only the intersecting byte ranges are read — as ranged
        positional reads fanned out over a thread pool — directly into
        preallocated destination buffers. Restore is format-universal
        (native ``.dsllm``, snapshot chunk manifests, sync pickle graphs),
        so a run can also switch engines between save and resume.

        Pass ``engine`` to override the manager's default
        (e.g. ``RestoreEngine(threads=1)`` for a serial ablation, or one
        with a read throttle). Per-restore timings and I/O counts are left
        in :attr:`last_restore_stats` (a
        :class:`~repro.core.restore.RestoreStats`)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.directory}")
        sdir = step_dir(self.directory, step)
        tree, stats = (engine or self.restore_engine).restore(sdir, template)
        self.last_restore_stats = stats
        return tree

    # -------------------------------------------------------------- misc
    def drain(self) -> None:
        self.wait_for_persist()
        self.engine.drain()

    def close(self) -> None:
        self.drain()
        self.engine.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
