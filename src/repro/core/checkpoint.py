"""Checkpoint manager: lazy non-blocking capture + globally consistent restore.

The manager is the training-runtime-facing API (paper §V-B — the "drop-in
engine"). It owns an engine (DataStates or one of the baselines), plans the
per-rank shard composition, and exposes the two consistency points of the
lazy protocol (paper §V-A2, Fig 6(c,d)):

* ``save(step, state)`` — returns immediately after the blocking prologue
  (planning + coalesced reservation + async D2H launch);
* ``wait_for_capture()`` — the barrier the training loop calls **before the
  optimizer update** of the following iteration: the update mutates (donates)
  the very buffers being snapshotted, so it may only run once all device
  state has left the device.

Restore is elastic: shards are reassembled to *any* requested sharding (the
stored shard boundaries come from the training layout at save time; restore
intersects them with the target layout, so mesh-shape changes between save
and restore are supported — a beyond-paper capability).
"""

from __future__ import annotations

import glob
import os
import re
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from .baselines import (BaseCheckpointEngine, DataStatesEngine,
                        DataStatesOldEngine, SnapshotThenFlushEngine,
                        SyncSerializedEngine)
from .distributed import (ShardRecord, group_by_rank, normalize_index,
                          plan_shards, _path_str)
from .engine import CheckpointFuture
from .layout import FileReader

ENGINES = {
    "datastates": DataStatesEngine,          # this paper
    "datastates-old": DataStatesOldEngine,   # HPDC'24 prior work
    "snapshot": SnapshotThenFlushEngine,     # TorchSnapshot-style
    "sync": SyncSerializedEngine,            # DeepSpeed default (torch.save)
}


def step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"global_step{step}")


class _StoredShard:
    """One stored shard of a logical array, format-agnostic: its region in
    the global array plus a thunk that materializes the shard's data."""

    __slots__ = ("index", "read")

    def __init__(self, index, read):
        self.index = tuple(tuple(p) for p in index)
        self.read = read


class CheckpointManager:
    def __init__(self, directory: str, mode: str = "datastates",
                 host_cache_bytes: int = 1 << 30,
                 flush_threads: int = 4,
                 chunk_bytes: int = 4 << 20,
                 throttle_mbps: Optional[float] = None):
        if mode not in ENGINES:
            raise ValueError(f"unknown engine mode {mode!r}; "
                             f"choose from {sorted(ENGINES)}")
        self.directory = directory
        self.mode = mode
        os.makedirs(directory, exist_ok=True)
        self.engine: BaseCheckpointEngine = ENGINES[mode](
            host_cache_bytes=host_cache_bytes,
            flush_threads=flush_threads,
            chunk_bytes=chunk_bytes,
            throttle_mbps=throttle_mbps)
        self._inflight: List[CheckpointFuture] = []

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, blocking: bool = False
             ) -> CheckpointFuture:
        """Request a checkpoint of ``state`` (any pytree of jax/np arrays +
        Python objects). Returns after the engine's blocking prologue only."""
        future = CheckpointFuture(step, step_dir(self.directory, step))
        t0 = time.perf_counter()
        future.stats.t_request = t0
        records, objects = plan_shards(state, group="state")
        objects["__checkpoint_meta__"] = {"step": step, "mode": self.mode,
                                          "n_shards": len(records)}
        by_rank = group_by_rank(records)
        os.makedirs(future.directory, exist_ok=True)
        self.engine.save(future.directory, by_rank, objects, future)
        future.stats.blocking_s = time.perf_counter() - t0
        self._inflight.append(future)
        self._inflight = [f for f in self._inflight if not f.persisted] \
            + [f for f in self._inflight if f.persisted][-1:]
        if blocking:
            future.wait_persisted()
        return future

    # -------------------------------------------------------- barriers
    def wait_for_capture(self) -> float:
        """Consistency barrier before the (buffer-donating) optimizer update.

        Returns the time actually spent blocked — this is the *direct stall*
        the paper measures in Fig 8."""
        t0 = time.perf_counter()
        for f in self._inflight:
            f.wait_captured()
        return time.perf_counter() - t0

    def wait_for_persist(self) -> float:
        t0 = time.perf_counter()
        for f in self._inflight:
            f.wait_persisted()
        return time.perf_counter() - t0

    # ------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = []
        for d in glob.glob(os.path.join(self.directory, "global_step*")):
            m = re.search(r"global_step(\d+)$", d)
            if m:
                steps.append(int(m.group(1)))
        return max(steps) if steps else None

    def restore(self, template: Any, step: Optional[int] = None) -> Any:
        """Rebuild ``template``-shaped state from a stored checkpoint.

        ``template`` leaves may be concrete arrays or ``ShapeDtypeStruct``s
        carrying a ``.sharding``; array leaves are reassembled shard-by-shard
        (elastic — target sharding need not match the stored one)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.directory}")
        sdir = step_dir(self.directory, step)
        tensor_index, object_index = self._index_step_dir(sdir)

        leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
        out = []
        for path, leaf in leaves:
            pstr = f"state/{_path_str(path)}"
            if isinstance(leaf, (jax.Array, jax.ShapeDtypeStruct)) or \
                    isinstance(leaf, np.ndarray):
                if pstr not in tensor_index:
                    raise KeyError(f"tensor {pstr!r} not found in checkpoint "
                                   f"(have {sorted(tensor_index)[:5]}...)")
                out.append(self._assemble(leaf, tensor_index[pstr]))
            else:
                if pstr in object_index:
                    out.append(object_index[pstr]())
                else:
                    out.append(leaf)  # keep template value (e.g. static field)
        return jax.tree_util.tree_unflatten(treedef, out)

    # Restore is format-universal: it reads back checkpoints written by any
    # engine (native .dsllm, TorchSnapshot-style chunk manifests, or the
    # DeepSpeed-default pickled object graph), so a run can switch engines
    # between save and resume.
    @staticmethod
    def _index_step_dir(sdir: str):
        """Build {leaf_path -> [_StoredShard]} and {obj_path -> thunk} from
        whatever checkpoint format lives in ``sdir``."""
        import pickle

        tensor_index: Dict[str, List[_StoredShard]] = {}
        object_index: Dict[str, Any] = {}

        dsllm = sorted(glob.glob(os.path.join(sdir, "*.dsllm")))
        if dsllm:
            for p in dsllm:
                rd = FileReader(p)
                for name, entry in rd.tensors.items():
                    base = name.split("@[", 1)[0]
                    tensor_index.setdefault(base, []).append(_StoredShard(
                        entry.index,
                        (lambda r=rd, n=entry.name: r.read_tensor(n))))
                for oname in rd.objects:
                    object_index[oname] = \
                        (lambda r=rd, n=oname: r.read_object(n))
            return tensor_index, object_index

        manifests = sorted(glob.glob(os.path.join(sdir, "manifest_rank*.pkl")))
        snapshot_objects = os.path.join(sdir, "objects.pkl")
        if manifests or os.path.exists(snapshot_objects):
            # TorchSnapshot-style chunk files
            from .baselines import load_snapshot_rank
            for mpath in manifests:
                with open(mpath, "rb") as f:
                    manifest = pickle.load(f)
                rank = int(re.search(r"manifest_rank(\d+)", mpath).group(1))
                for t in manifest["tensors"]:
                    base = t["name"].split("@[", 1)[0]

                    def read(d=os.path.dirname(mpath), r=rank, n=t["name"]):
                        return load_snapshot_rank(d, r)[n]
                    tensor_index.setdefault(base, []).append(
                        _StoredShard(tuple(t["index"]), read))
            opath = os.path.join(sdir, "objects.pkl")
            if os.path.exists(opath):
                with open(opath, "rb") as f:
                    objects = pickle.load(f)
                for oname, val in objects.items():
                    object_index[oname] = (lambda v=val: v)
            return tensor_index, object_index

        pkls = sorted(glob.glob(os.path.join(sdir, "*.pkl")))
        if pkls:  # sync (torch.save-style) pickled object graph per rank
            from .baselines import load_sync_rank
            for p in pkls:
                graph = load_sync_rank(p)
                for name, rec in graph.items():
                    if name == "__objects__":
                        for oname, val in rec.items():
                            object_index[oname] = (lambda v=val: v)
                        continue
                    base = name.split("@[", 1)[0]
                    tensor_index.setdefault(base, []).append(_StoredShard(
                        tuple(rec["index"]), (lambda r=rec: r["data"])))
            return tensor_index, object_index

        raise FileNotFoundError(f"no checkpoint files in {sdir}")

    @staticmethod
    def _assemble(leaf, stored: List["_StoredShard"]):
        """Reassemble one logical array from stored shard entries."""
        shape = tuple(leaf.shape)
        dtype = leaf.dtype

        def read_region(region: Tuple[Tuple[int, int], ...]) -> np.ndarray:
            tgt_shape = tuple(b - a for a, b in region)
            buf = np.empty(tgt_shape, dtype=dtype)
            filled = 0
            for entry in stored:
                s_idx = entry.index
                # intersection of stored shard with requested region
                inter = tuple((max(a, c), min(b, d))
                              for (a, b), (c, d) in zip(region, s_idx))
                if any(lo >= hi for lo, hi in inter):
                    continue
                src = entry.read()
                src_sl = tuple(slice(lo - c, hi - c)
                               for (lo, hi), (c, _d) in zip(inter, s_idx))
                dst_sl = tuple(slice(lo - a, hi - a)
                               for (lo, hi), (a, _b) in zip(inter, region))
                buf[dst_sl] = src[src_sl]
                filled += int(np.prod([hi - lo for lo, hi in inter]))
            if filled < int(np.prod(tgt_shape)):
                raise ValueError(
                    f"checkpoint does not cover requested region {region}")
            return buf

        if isinstance(leaf, np.ndarray):
            return read_region(tuple((0, d) for d in shape))

        sharding = getattr(leaf, "sharding", None)
        if sharding is None:
            full = read_region(tuple((0, d) for d in shape))
            return jax.numpy.asarray(full)

        def cb(index):
            region = normalize_index(index, shape)
            return read_region(region)

        return jax.make_array_from_callback(shape, sharding, cb)

    # -------------------------------------------------------------- misc
    def drain(self) -> None:
        self.wait_for_persist()
        self.engine.drain()

    def close(self) -> None:
        self.drain()
        self.engine.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
