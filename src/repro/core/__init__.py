"""DataStates-LLM core: composable state providers + lazy async checkpointing."""

from .checkpoint import (CheckpointManager, DeltaPolicy, ENGINES,
                         latest_step, step_dir)
from .restore import (RestoreEngine, RestoreError, RestoreIndex,
                      RestoreStats)
from .engine import (CheckpointError, CheckpointFuture, CheckpointStats,
                     DataMovementEngine, FilePlan)
from .host_cache import CacheFullError, HostCache, Reservation
from .layout import FileLayout, FileReader, FileWriter, TensorEntry, ObjectEntry
from .state_provider import (Chunk, CompositeStateProvider, DeltaSaveSpec,
                             DeltaStateProvider, ObjectStateProvider,
                             SnapshotCache, StateProvider,
                             TensorStateProvider)
from .baselines import (BaseCheckpointEngine, DataStatesEngine,
                        DataStatesOldEngine, SnapshotThenFlushEngine,
                        SyncSerializedEngine, load_snapshot_rank,
                        load_sync_rank)
from .distributed import ShardRecord, group_by_rank, normalize_index, plan_shards
from .consolidate import consolidate_step_dir

__all__ = [
    "CheckpointManager", "DeltaPolicy", "ENGINES", "latest_step", "step_dir",
    "RestoreEngine", "RestoreError", "RestoreIndex", "RestoreStats",
    "CheckpointError", "CheckpointFuture", "CheckpointStats",
    "DataMovementEngine", "FilePlan",
    "CacheFullError", "HostCache", "Reservation",
    "FileLayout", "FileReader", "FileWriter", "TensorEntry", "ObjectEntry",
    "Chunk", "CompositeStateProvider", "DeltaSaveSpec", "DeltaStateProvider",
    "ObjectStateProvider", "SnapshotCache", "StateProvider",
    "TensorStateProvider",
    "BaseCheckpointEngine", "DataStatesEngine", "DataStatesOldEngine",
    "SnapshotThenFlushEngine", "SyncSerializedEngine",
    "load_snapshot_rank", "load_sync_rank",
    "ShardRecord", "group_by_rank", "normalize_index", "plan_shards",
    "consolidate_step_dir",
]
