"""DataStates-LLM core: composable state providers + lazy async checkpointing."""

from .checkpoint import (CheckpointManager, ENGINES, latest_step,
                         restore_from_repository, step_dir)
from .policy import (CheckpointPolicy, DeltaPolicy, DistPolicy,
                     EnginePolicy, StoragePolicy)
from .registry import (ProviderRoute, ProviderRule, RegistryError,
                       StateProviderRegistry)
from .codecs import CodecError, DELTA_CODEC, INT8_CODEC
from .restore import (RestoreEngine, RestoreError, RestoreIndex,
                      RestoreStats)
from .engine import (CheckpointError, CheckpointFuture, CheckpointStats,
                     DataMovementEngine, FilePlan)
from .host_cache import CacheFullError, HostCache, Reservation
from .layout import FileLayout, FileReader, FileWriter, TensorEntry, ObjectEntry
from .state_provider import (Chunk, CompositeStateProvider, DeltaSaveSpec,
                             DeltaStateProvider, ObjectStateProvider,
                             QuantizedStateProvider, SnapshotCache,
                             StateProvider, TensorStateProvider)
from .baselines import (BaseCheckpointEngine, DataStatesEngine,
                        DataStatesOldEngine, SnapshotThenFlushEngine,
                        SyncSerializedEngine, load_snapshot_rank,
                        load_sync_rank)
from .distributed import (ShardRecord, group_by_rank, normalize_index,
                          plan_shards, state_domain)
from .consolidate import consolidate_step_dir

__all__ = [
    "CheckpointManager", "ENGINES", "latest_step", "step_dir",
    "restore_from_repository",
    "CheckpointPolicy", "DeltaPolicy", "DistPolicy", "EnginePolicy",
    "StoragePolicy",
    "ProviderRoute", "ProviderRule", "RegistryError",
    "StateProviderRegistry",
    "CodecError", "DELTA_CODEC", "INT8_CODEC",
    "RestoreEngine", "RestoreError", "RestoreIndex", "RestoreStats",
    "CheckpointError", "CheckpointFuture", "CheckpointStats",
    "DataMovementEngine", "FilePlan",
    "CacheFullError", "HostCache", "Reservation",
    "FileLayout", "FileReader", "FileWriter", "TensorEntry", "ObjectEntry",
    "Chunk", "CompositeStateProvider", "DeltaSaveSpec", "DeltaStateProvider",
    "ObjectStateProvider", "QuantizedStateProvider", "SnapshotCache",
    "StateProvider", "TensorStateProvider",
    "BaseCheckpointEngine", "DataStatesEngine", "DataStatesOldEngine",
    "SnapshotThenFlushEngine", "SyncSerializedEngine",
    "load_snapshot_rank", "load_sync_rank",
    "ShardRecord", "group_by_rank", "normalize_index", "plan_shards",
    "state_domain",
    "consolidate_step_dir",
]
