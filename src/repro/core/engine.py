"""Streamlined multi-tier data-movement engine (paper §V-A1, §V-A2, §V-A4).

The engine consumes chunk streams from composable state providers and moves
them across tiers using separate physical paths in parallel:

* a **staging lane** (models the device→host DMA copy engine): drains a queue
  of device-resident tensors into their pre-reserved pinned-cache slices,
  chunk by chunk, notifying the provider so downstream flushing can begin
  before a tensor has fully landed;
* **producer lanes** (one per checkpoint file): iterate the composite
  provider's chunk stream — tensors first, then lazily-serialized objects —
  and enqueue write ops;
* a **flush pool** (models liburing/O_DIRECT writers): positional
  ``os.pwrite`` workers, multiple files in flight, GIL-released.

Completion is tracked per request as two phases (paper Fig 6(c,d)):
``captured`` (all device state has left the device — safe to mutate, i.e. the
optimizer update may run) and ``persisted`` (all files durable, footer
written).
"""

from __future__ import annotations

import os
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.locks import declares_lock, named_lock
from repro.obs import trace as obs
from repro.obs.metrics import metrics as obs_metrics

from .host_cache import HostCache
from .layout import FileWriter
from .state_provider import (Chunk, CompositeStateProvider,
                             TensorStateProvider, DEFAULT_CHUNK_BYTES)


class CheckpointError(RuntimeError):
    pass


class CheckpointStats:
    """Wall-clock phase timings, used by the benchmark harness."""

    def __init__(self) -> None:
        self.t_request: float = 0.0         # save() entered
        self.blocking_s: float = 0.0        # time training was blocked in save()
        self.t_captured: float = 0.0
        self.t_persisted: float = 0.0
        self.bytes_tensors: int = 0
        self.bytes_objects: int = 0
        self.n_files: int = 0
        self.n_tensors: int = 0
        self.serialize_s: float = 0.0       # object serialization time
        self.stage_s: float = 0.0           # device->host staging time
        self.flush_s: float = 0.0           # cumulative pwrite time
        self.t_committed: float = 0.0       # catalog manifest durable
        self.commit_s: float = 0.0          # manifest build+write duration
        self.extra: Dict[str, Any] = {}

    @property
    def capture_latency_s(self) -> float:
        return self.t_captured - self.t_request

    @property
    def persist_latency_s(self) -> float:
        return self.t_persisted - self.t_request

    @property
    def commit_latency_s(self) -> float:
        return self.t_committed - self.t_request

    @property
    def total_bytes(self) -> int:
        return self.bytes_tensors + self.bytes_objects


class CheckpointFuture:
    """Two-phase completion handle for one checkpoint request."""

    def __init__(self, step: int, directory: str):
        self.step = step
        self.directory = directory
        self.stats = CheckpointStats()
        self._captured = threading.Event()
        self._persisted = threading.Event()
        self._error: Optional[BaseException] = None

    # -- engine side ---------------------------------------------------------
    def _set_captured(self) -> None:
        self.stats.t_captured = time.perf_counter()
        self._captured.set()

    def _set_persisted(self) -> None:
        self.stats.t_persisted = time.perf_counter()
        self._persisted.set()

    def _set_error(self, exc: BaseException) -> None:
        self._error = exc
        self._captured.set()
        self._persisted.set()

    # -- user side -----------------------------------------------------------
    @property
    def captured(self) -> bool:
        return self._captured.is_set()

    @property
    def persisted(self) -> bool:
        return self._persisted.is_set()

    def _check(self) -> None:
        if self._error is not None:
            raise CheckpointError(
                f"checkpoint step={self.step} failed") from self._error

    def wait_captured(self, timeout: Optional[float] = None) -> None:
        if not self._captured.wait(timeout):
            raise TimeoutError("capture did not complete in time")
        self._check()

    def wait_persisted(self, timeout: Optional[float] = None) -> None:
        if not self._persisted.wait(timeout):
            raise TimeoutError("persist did not complete in time")
        self._check()


class FilePlan:
    """One checkpoint file: a composite provider + destination path."""

    def __init__(self, path: str, composite: CompositeStateProvider,
                 meta: Optional[Dict[str, Any]] = None):
        self.path = path
        self.composite = composite
        self.meta = meta or {}


class _WriteOp:
    __slots__ = ("writer", "chunk", "file_state", "throttle", "on_written")

    def __init__(self, writer, chunk, file_state, throttle, on_written=None):
        self.writer = writer
        self.chunk = chunk
        self.file_state = file_state
        self.throttle = throttle
        self.on_written = on_written


@declares_lock("engine.file_state", rank=52, attrs=("lock",))
class _FileState:
    """Per-file pending-op accounting to decide when to finalize."""

    def __init__(self, plan: FilePlan, writer: FileWriter,
                 on_done: Callable[[], None], future: "CheckpointFuture"):
        self.plan = plan
        self.writer = writer
        self.on_done = on_done
        self.future = future
        self.lock = threading.Lock()
        self.pending = 0
        self.producer_done = False
        self.failed = False  # producer died: discard instead of finalize
        # partial object payload assembly (chunked log appends)
        self.object_parts: Dict[str, List[bytes]] = {}
        # release tracking for tensor providers
        self.tensor_last_seen: Dict[str, TensorStateProvider] = {}

    def op_started(self) -> None:
        with self.lock:
            self.pending += 1

    def op_finished(self) -> bool:
        with self.lock:
            self.pending -= 1
            done = self.producer_done and self.pending == 0
        if done:
            self.on_done()
        return done

    def producer_finished(self) -> None:
        with self.lock:
            done = self.pending == 0
            self.producer_done = True
        if done:
            self.on_done()


class DataMovementEngine:
    """The full DataStates-LLM engine (lazy capture + streamlined flush)."""

    def __init__(self, host_cache_bytes: int = 2 << 30,
                 flush_threads: int = 4,
                 producer_threads: int = 2,
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 throttle_mbps: Optional[float] = None,
                 track_file_checksums: bool = False,
                 label: str = "dsllm"):
        self.host_cache = HostCache(host_cache_bytes)
        self.chunk_bytes = chunk_bytes
        self.throttle_mbps = throttle_mbps
        # accumulate manifest-compatible per-file checksums while writing
        # (one pass): the commit lane reuses them instead of re-reading
        # every persisted byte
        self.track_file_checksums = track_file_checksums
        # ``label`` prefixes the lane (thread) names — the coordinator gives
        # each rank's engine a distinct prefix so traces get per-rank lanes.
        self.label = label
        self._flush_q: "queue.Queue[Optional[_WriteOp]]" = queue.Queue()
        self._stage_q: "queue.Queue[Optional[Tuple]]" = queue.Queue()
        self._producer_q: "queue.Queue[Optional[Tuple]]" = queue.Queue()
        self._shutdown = False
        self._flush_threads = [
            threading.Thread(target=self._flush_worker, daemon=True,
                             name=f"{label}-flush-{i}")
            for i in range(flush_threads)]
        self._stage_thread = threading.Thread(
            target=self._stage_worker, daemon=True, name=f"{label}-stage")
        self._producer_threads = [
            threading.Thread(target=self._producer_worker, daemon=True,
                             name=f"{label}-producer-{i}")
            for i in range(producer_threads)]
        for t in (*self._flush_threads, self._stage_thread,
                  *self._producer_threads):
            t.start()

    # ------------------------------------------------------------------ API
    def submit(self, files: Sequence[FilePlan],
               capture_items: Sequence[Tuple[TensorStateProvider, Any]],
               future: CheckpointFuture) -> None:
        """Kick off one checkpoint request.

        ``capture_items`` are (provider, device_array) pairs needing D2H
        staging. This call performs only the *blocking* prologue: coalesced
        cache reservation (back-pressure lives here) and async-copy launch —
        everything else proceeds on background lanes.
        """
        stats = future.stats
        # --- coalesced reservation: all shards of the checkpoint up front
        # (pre-allocated, pre-pinned pool; §V-A1). Fail fast if one full
        # checkpoint version can never fit: the paper sizes the cache to
        # hold at least one version per node (§VI-C2, 80 GB/node) — waiting
        # here would deadlock (nothing is flushing yet, so nothing frees).
        total = sum(p.nbytes for p, _ in capture_items)
        if total > self.host_cache.capacity:
            raise CheckpointError(
                f"checkpoint device payload ({total/2**20:.0f} MiB) exceeds "
                f"host cache ({self.host_cache.capacity/2**20:.0f} MiB); "
                f"raise host_cache_bytes — the cache must hold one full "
                f"checkpoint version (paper §VI-C2)")
        bound: List[TensorStateProvider] = []
        try:
            for provider, _arr in capture_items:
                provider.bind_reservation(
                    self.host_cache.reserve(provider.nbytes))
                bound.append(provider)
            # --- launch non-blocking D2H for every device shard (lazy
            # capture; overlaps with the next iteration's forward/backward,
            # §V-A2).
            for _provider, arr in capture_items:
                try:
                    arr.copy_to_host_async()
                except AttributeError:
                    pass  # plain numpy / non-jax arrays
        except BaseException:
            # Prologue failed mid-way: nothing was enqueued yet, so no lane
            # will ever drain these reservations — release them here or the
            # pinned pool leaks and the next save deadlocks in reserve().
            for provider in bound:
                try:
                    provider.release()
                except BaseException:
                    pass
            raise
        for plan in files:
            stats.n_files += 1
            comp = plan.composite
            stats.n_tensors += len(comp.tensor_providers)
            stats.bytes_tensors += sum(p.nbytes for p in comp.tensor_providers)

        pending_files = {"n": len(files)}
        lock = named_lock("engine.save_progress", rank=50)

        def file_done() -> None:
            with lock:
                pending_files["n"] -= 1
                last = pending_files["n"] == 0
            if last and not future.persisted:
                future._set_persisted()

        capture_pending = {"n": len(capture_items)}

        def one_staged() -> None:
            with lock:
                capture_pending["n"] -= 1
                done = capture_pending["n"] == 0
            if done and not future.captured:
                future._set_captured()

        if not capture_items:
            future._set_captured()
        for provider, arr in capture_items:
            self._stage_q.put((provider, arr, one_staged, future))
        for plan in files:
            self._producer_q.put((plan, file_done, future))
        if not files:
            future._set_persisted()

    def drain(self) -> None:
        """Wait for all queued work (tests/benchmarks)."""
        self._stage_q.join()
        self._producer_q.join()
        self._flush_q.join()

    def close(self) -> None:
        self._shutdown = True
        for _ in self._producer_threads:
            self._producer_q.put(None)
        self._stage_q.put(None)
        for _ in self._flush_threads:
            self._flush_q.put(None)

    # ------------------------------------------------------------ workers
    def _stage_worker(self) -> None:
        """The D2H lane: drains device shards into their cache reservations."""
        while True:
            item = self._stage_q.get()
            if item is None:
                self._stage_q.task_done()
                return
            provider, arr, one_staged, future = item
            try:
                t0 = time.perf_counter()
                # np.asarray blocks until the async device->host copy of this
                # shard has completed, then views/copies the host buffer.
                src = np.asarray(arr).reshape(-1).view(np.uint8)
                dst = provider.reservation.array(np.uint8, (provider.nbytes,))
                n = provider.nbytes
                step = self.chunk_bytes
                for pos in range(0, n, step):
                    end = min(pos + step, n)
                    dst[pos:end] = src[pos:end]
                    if provider.stream_intra_tensor:
                        provider.notify_staged(end)  # flush the staged head
                provider.notify_staged(n)
                t1 = time.perf_counter()
                future.stats.stage_s += t1 - t0
                obs_metrics.inc("engine.bytes_staged", n)
                obs.add_span("d2h.stage", t0, t1, tensor=provider.name,
                             bytes=n, step=future.step,
                             flow=obs.flow_id("save", future.step))
                one_staged()
            except BaseException as exc:  # noqa: BLE001
                future._set_error(exc)
            finally:
                self._stage_q.task_done()

    def _producer_worker(self) -> None:
        """Iterate one file's chunk stream and enqueue write ops."""
        while True:
            item = self._producer_q.get()
            if item is None:
                self._producer_q.task_done()
                return
            plan, file_done, future = item
            try:
                with obs.span("produce.file", step=future.step,
                              file=os.path.basename(plan.path),
                              flow=obs.flow_id("save", future.step)):
                    self._produce_file(plan, file_done, future)
            except BaseException as exc:  # noqa: BLE001
                future._set_error(exc)
            finally:
                self._producer_q.task_done()

    def _produce_file(self, plan: FilePlan, file_done, future) -> None:
        layout = plan.composite.plan_layout()
        writer = FileWriter(plan.path, layout,
                            track_checksum=self.track_file_checksums)
        state = _FileState(plan, writer,
                           on_done=lambda: self._finalize_file(
                               state, file_done, future), future=future)
        try:
            for k, v in plan.meta.items():
                writer.set_meta(k, v)
            # Encoded (delta / quantized / custom) tensors never reach the
            # fixed region: declare their footer metadata up front; their
            # compressed chunks are appended by the flush lanes as they
            # land.
            for p in plan.composite.encoded_providers():
                writer.declare_encoded_tensor(
                    p.name, dtype=p.dtype, shape=p.shape, nbytes=p.nbytes,
                    codec=getattr(p, "enc_codec", "raw"),
                    global_shape=p.global_shape, index=p.index)
            providers = {p.name: p for p in plan.composite.tensor_providers}
            for chunk in plan.composite.chunks():
                if chunk.kind == "object":
                    # assemble chunked payload; single contiguous log append
                    parts = state.object_parts.setdefault(chunk.name, [])
                    parts.append(bytes(chunk.data))
                    if chunk.last:
                        payload = b"".join(state.object_parts.pop(chunk.name))
                        future.stats.bytes_objects += len(payload)
                        state.op_started()
                        self._flush_q.put(_WriteOp(
                            writer,
                            Chunk(name=chunk.name, kind="object",
                                  data=payload, codec=chunk.codec, last=True),
                            state, self.throttle_mbps))
                else:
                    state.op_started()
                    on_written = None
                    if chunk.last:
                        p = providers.get(chunk.name)
                        if p is not None and p.device_resident:
                            on_written = p.release  # evict from pinned cache
                    self._flush_q.put(_WriteOp(writer, chunk, state,
                                               self.throttle_mbps,
                                               on_written))
        except BaseException:
            # Producer failed mid-stream: the file has no footer and never
            # will. Mark the file failed and let the per-file accounting
            # drain normally — when the last queued op finishes,
            # _finalize_file aborts/unlinks the partial file. Closing the
            # fd right here would race in-flight pwrites: the kernel can
            # recycle the fd number into another open file and a stale
            # positional write would corrupt it.
            state.failed = True
            state.producer_finished()
            raise
        state.producer_finished()

    @staticmethod
    def _discard_partial(writer: FileWriter) -> None:
        """Abort a writer and remove its footer-less partial file."""
        writer.abort()
        try:
            os.unlink(writer.path)
        except OSError:
            pass

    @staticmethod
    def _release_providers(state: "_FileState") -> None:
        """Free the pinned-cache reservations of a failed file's tensors.

        On the happy path each provider releases via its last chunk's
        ``on_written``; an error path skips those callbacks, and a leaked
        reservation would make the next save block forever inside the
        cache allocator. ``release`` is idempotent, so double-freeing the
        already-flushed providers is safe."""
        for p in state.plan.composite.tensor_providers:
            try:
                p.release()
            except BaseException:  # noqa: BLE001
                pass

    def _finalize_file(self, state: "_FileState", file_done, future) -> None:
        writer = state.writer
        if state.failed or future._error is not None:
            # The producer died or some op already failed the request:
            # never write a footer over a partial file.
            self._discard_partial(writer)
            self._release_providers(state)
            return
        try:
            writer.finalize()
        except BaseException as exc:  # noqa: BLE001
            self._discard_partial(writer)
            self._release_providers(state)
            future._set_error(exc)
            return
        if writer.file_checksum is not None:
            # one finalize per file; dict.setdefault/__setitem__ are atomic
            # under the GIL, and each file writes a distinct key
            future.stats.extra.setdefault("file_checksums", {})[
                os.path.basename(writer.path)] = writer.file_checksum
        file_done()

    def _flush_worker(self) -> None:
        """liburing-style positional writers; GIL released inside pwrite."""
        while True:
            op = self._flush_q.get()
            if op is None:
                self._flush_q.task_done()
                return
            try:
                t0 = time.perf_counter()
                chunk = op.chunk
                nb_written = None
                if chunk.kind == "object":
                    op.writer.append_object(chunk.name, chunk.data,
                                            codec=chunk.codec)
                elif chunk.codec != "raw":
                    # codec-aware flush stage (differential checkpointing):
                    # compress the XOR-delta payload here — off the capture
                    # and producer paths — and log-append it.
                    from .reduction import _compress
                    payload = _compress(bytes(chunk.data))
                    t_enc = time.perf_counter()
                    obs.add_span("encode.compress", t0, t_enc,
                                 tensor=chunk.name, codec=chunk.codec,
                                 bytes_in=len(chunk.data),
                                 bytes_out=len(payload))
                    op.writer.append_encoded_chunk(chunk.name, payload,
                                                   *chunk.raw_range,
                                                   digest=chunk.digest)
                    nb_written = len(payload)
                else:
                    op.writer.write_at(chunk.offset, chunk.data)
                    if chunk.digest is not None \
                            and chunk.raw_range is not None:
                        # keyframe/raw chunk saved under manifest
                        # checksums: record the producer's per-chunk
                        # digest so verify can localize a flipped chunk
                        op.writer.record_raw_chunk(
                            chunk.name, *chunk.raw_range, chunk.digest)
                if nb_written is not None:
                    nb = nb_written
                elif isinstance(chunk.data, bytes):
                    nb = len(chunk.data)
                else:
                    nb = chunk.data.nbytes
                if op.throttle:
                    target = nb / (op.throttle * 1e6)
                    elapsed = time.perf_counter() - t0
                    if target > elapsed:
                        time.sleep(target - elapsed)
                t1 = time.perf_counter()
                fut = op.file_state.future
                fut.stats.flush_s += t1 - t0
                obs_metrics.inc(
                    "engine.bytes_written." + (chunk.codec or "raw"), nb)
                obs.add_span("flush", t0, t1, chunk=chunk.name, bytes=nb,
                             step=fut.step,
                             flow=obs.flow_id("save", fut.step))
                if op.on_written is not None:
                    op.on_written()
                op.file_state.op_finished()
            except BaseException as exc:  # noqa: BLE001
                op.file_state.future._set_error(exc)
                # keep the per-file op accounting moving so the last op
                # reaches _finalize_file, which (seeing the error) aborts
                # the writer and removes the partial file instead of
                # leaking the fd behind a footer-less file.
                try:
                    op.file_state.op_finished()
                except BaseException:  # noqa: BLE001
                    pass
            finally:
                # credit the producer's encode budget on every outcome —
                # a failed write must not starve the (blocked) producer
                if op.chunk.on_flushed is not None:
                    try:
                        op.chunk.on_flushed()
                    except BaseException:  # noqa: BLE001
                        pass
                self._flush_q.task_done()
