"""Parallel streaming restore engine (the save path's missing twin).

The paper optimizes *capture* (lazy D2H, composable providers, streamlined
flush) but says little about resume; "Understanding LLM Checkpoint/Restore
I/O Strategies and Patterns" (arXiv 2512.24511) measures serial,
data-oblivious reload as the dominant resume cost and ByteCheckpoint
(arXiv 2407.20143) shows parallel re-sharded load is the fix. This module
applies the same discipline to restore that the engine applies to save:

1. **Index once** — every checkpoint file in the step directory is opened
   exactly once and its shard directory (name, global region, byte layout)
   is extracted, whatever the format (native ``.dsllm`` footers,
   TorchSnapshot-style chunk manifests, sync pickled object graphs).
2. **Plan up front** — for every template leaf, the target regions (one per
   unique device shard of the requested sharding — elastic, so the target
   mesh need not match the stored one) are intersected with the stored
   shard regions, producing an explicit list of byte ranges *before* any
   data is read. Coverage is validated at plan time.
3. **Fan out ranged reads** — the byte ranges become positional
   ``os.preadv`` calls over a thread pool, reading *only* intersecting
   bytes (the fixed-offset aligned tensor region of ``layout.py`` makes
   every range computable from the footer alone) directly into
   preallocated destination buffers. ``preadv`` releases the GIL, so
   ranges overlap both each other and the throttled-PFS latency.

Formats without byte-addressable tensors degrade gracefully: sync pickle
graphs are loaded once per *file* per restore (never once per tensor — the
seed's snapshot path re-read whole rank files O(files × tensors) times)
and sliced in memory.

Per-restore :class:`RestoreStats` record the phase split (index / read /
assemble), bytes actually read, and the number of ranged reads issued —
``bytes_read`` is the paper-style evidence that a sub-tree or re-sharded
restore touches only the bytes it needs.
"""

from __future__ import annotations

import concurrent.futures
import dataclasses
import glob
import itertools
import os
import pickle
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.obs import trace as obs

from .codecs import is_chained_codec
from .distributed import normalize_index, _path_str
from .layout import FileReader

Region = Tuple[Tuple[int, int], ...]  # ((start, stop), ...) per dim


class RestoreError(RuntimeError):
    """A checkpoint could not be indexed or did not cover a request."""


@dataclasses.dataclass
class RestoreStats:
    """Phase timings + I/O accounting for one restore."""

    index_s: float = 0.0      # footer/manifest indexing
    plan_s: float = 0.0       # intersection planning
    read_s: float = 0.0       # parallel ranged-read fan-out (wall clock)
    assemble_s: float = 0.0   # host buffers -> device arrays
    bytes_read: int = 0       # bytes actually fetched from storage
    n_ranges: int = 0         # ranged reads issued
    n_files: int = 0          # checkpoint files indexed
    n_leaves: int = 0         # template leaves restored
    threads: int = 0          # fan-out width used

    @property
    def total_s(self) -> float:
        return self.index_s + self.plan_s + self.read_s + self.assemble_s


# --------------------------------------------------------------------------
# Byte-range math for C-contiguous stored shards.

def _volume(region: Region) -> int:
    v = 1
    for lo, hi in region:
        v *= max(0, hi - lo)
    return v


def _contiguous_runs(local_region: Region, shape: Tuple[int, ...],
                     itemsize: int):
    """Yield ``(byte_offset, nbytes)`` contiguous runs of ``local_region``
    within a C-contiguous array of ``shape``, in C order.

    Runs are maximal: a suffix of dims fully covered by the region folds
    into its predecessor, so a full-array region is a single run.
    """
    nd = len(shape)
    if nd == 0:
        yield 0, itemsize
        return
    if any(hi <= lo for lo, hi in local_region):
        return
    k = nd
    while k > 0 and local_region[k - 1] == (0, shape[k - 1]):
        k -= 1
    inner = itemsize
    for d in range(k, nd):
        inner *= shape[d]
    if k == 0:
        yield 0, inner
        return
    run_lo, run_hi = local_region[k - 1]
    run_bytes = (run_hi - run_lo) * inner
    # byte strides of the outer (partially covered) dims 0..k-2
    strides = [0] * (k - 1)
    acc = inner * shape[k - 1]
    for d in range(k - 2, -1, -1):
        strides[d] = acc
        acc *= shape[d]
    base = run_lo * inner
    for coords in itertools.product(
            *[range(lo, hi) for lo, hi in local_region[:k - 1]]):
        yield base + sum(c * strides[d] for d, c in enumerate(coords)), \
            run_bytes


def plan_ranged_slices(nbytes: int, slice_bytes: int = 16 << 20
                       ) -> List[Tuple[int, int]]:
    """``[(offset, nbytes), ...]`` fixed-cap slices covering ``[0, nbytes)``.

    The ranged-read splitting discipline shared by the restore engine
    (``_emit_tasks`` splits giant runs so they parallelize across the
    thread pool) and the fleet's peer exchange (which deals the same
    disjoint slices to concurrent replicas so each remote byte is read by
    exactly one of them)."""
    cap = max(1, int(slice_bytes))
    return [(lo, min(cap, nbytes - lo)) for lo in range(0, nbytes, cap)]


def _preadv_full(fd: int, mv: memoryview, offset: int) -> None:
    pos = 0
    end = len(mv)
    while pos < end:
        n = os.preadv(fd, [mv[pos:]], offset + pos)
        if n <= 0:
            raise RestoreError(
                f"short read at offset {offset + pos} (wanted {end - pos} "
                f"more bytes) — truncated checkpoint file?")
        pos += n


class _FDCache:
    """Positional-read fd per file, shared across reader threads."""

    def __init__(self) -> None:
        self._fds: Dict[str, int] = {}
        self._lock = threading.Lock()

    def get(self, path: str) -> int:
        with self._lock:
            fd = self._fds.get(path)
            if fd is None:
                fd = os.open(path, os.O_RDONLY)
                self._fds[path] = fd
            return fd

    def close(self) -> None:
        with self._lock:
            for fd in self._fds.values():
                os.close(fd)
            self._fds.clear()


# --------------------------------------------------------------------------
# Shard sources: one stored shard of a logical array, format-specific.

class _ShardSource:
    """Base: a stored shard covering ``index`` of the global array."""

    __slots__ = ("index", "shape", "dtype")

    def __init__(self, index: Region, shape: Tuple[int, ...], dtype):
        self.index = tuple(tuple(p) for p in index)
        self.shape = tuple(shape)
        self.dtype = np.dtype(dtype)

    def byte_ranges(self, local_region: Region):
        """(file_path, file_offset, nbytes) pieces for ``local_region``,
        in C order of the region. None for non-byte-addressable formats."""
        raise NotImplementedError

    def read_fallback(self, local_region: Region) -> np.ndarray:
        """Materialize ``local_region`` without ranged reads."""
        raise NotImplementedError


class _DsllmShard(_ShardSource):
    """Fixed-offset aligned tensor region in a native ``.dsllm`` file."""

    __slots__ = ("path", "offset")

    def __init__(self, path: str, entry):
        index = entry.index if entry.index is not None \
            else tuple((0, d) for d in entry.shape)
        super().__init__(index, entry.shape, entry.dtype)
        self.path = path
        self.offset = entry.offset

    def byte_ranges(self, local_region: Region):
        for off, nb in _contiguous_runs(local_region, self.shape,
                                        self.dtype.itemsize):
            yield self.path, self.offset + off, nb


class _SnapshotShard(_ShardSource):
    """One tensor spread over TorchSnapshot-style chunk files."""

    __slots__ = ("chunks",)

    def __init__(self, index: Region, shape, dtype,
                 chunks: Sequence[Tuple[str, int, int]]):
        super().__init__(index, shape, dtype)
        # (path, lo, hi): byte interval of the flattened tensor per file
        self.chunks = sorted(chunks, key=lambda c: c[1])

    def byte_ranges(self, local_region: Region):
        for off, nb in _contiguous_runs(local_region, self.shape,
                                        self.dtype.itemsize):
            run_lo, run_hi = off, off + nb
            for path, lo, hi in self.chunks:
                a, b = max(run_lo, lo), min(run_hi, hi)
                if a < b:
                    yield path, a - lo, b - a


class _EncodedShard(_ShardSource):
    """A self-contained encoded tensor (e.g. an int8-quantized optimizer
    moment) in a native file: its compressed log chunks decode without a
    chain base, so it restores standalone — decoded at most once per
    restore (thread-safe), then sliced in memory."""

    __slots__ = ("loader",)

    def __init__(self, index: Region, shape, dtype,
                 loader: Callable[[], np.ndarray]):
        super().__init__(index, shape, dtype)
        self.loader = loader

    def byte_ranges(self, local_region: Region):
        return None

    def read_fallback(self, local_region: Region) -> np.ndarray:
        arr = self.loader()
        return arr[tuple(slice(lo, hi) for lo, hi in local_region)]


class _GraphShard(_ShardSource):
    """A shard inside a pickled object graph (sync format): the graph is
    loaded at most once per restore; slicing happens in memory."""

    __slots__ = ("loader", "name")

    def __init__(self, index: Region, shape, dtype,
                 loader: Callable[[], Dict[str, Any]], name: str):
        super().__init__(index, shape, dtype)
        self.loader = loader
        self.name = name

    def byte_ranges(self, local_region: Region):
        return None

    def read_fallback(self, local_region: Region) -> np.ndarray:
        arr = np.asarray(self.loader()[self.name]["data"])
        return arr[tuple(slice(lo, hi) for lo, hi in local_region)]


class _OnceLoader:
    """Thread-safe load-once wrapper around an expensive whole-file read."""

    def __init__(self, fn: Callable[[], Any], nbytes: int,
                 stats: "RestoreStats", stats_lock: threading.Lock):
        self._fn = fn
        self._nbytes = nbytes
        self._stats = stats
        self._stats_lock = stats_lock
        self._lock = threading.Lock()
        self._value: Any = None
        self._loaded = False

    def __call__(self) -> Any:
        with self._lock:
            if not self._loaded:
                self._value = self._fn()
                self._loaded = True
                with self._stats_lock:
                    self._stats.bytes_read += self._nbytes
                    self._stats.n_ranges += 1
        return self._value


# --------------------------------------------------------------------------

class RestoreIndex:
    """Everything learned from one pass over a step directory."""

    def __init__(self, sdir: str):
        self.sdir = sdir
        self.tensors: Dict[str, List[_ShardSource]] = {}
        # Differential steps: encoded (XOR-domain) shards, keyed like
        # ``tensors`` but holding ``(FileReader, TensorEntry)`` pairs —
        # their payloads are compressed log chunks, not byte-addressable
        # regions, and their values only exist relative to a chain base.
        self.delta_tensors: Dict[str, List[Tuple[Any, Any]]] = {}
        self.objects: Dict[str, Callable[[], Any]] = {}
        self.n_files = 0


class _Run:
    """Per-restore mutable state, so one engine instance (e.g. the manager's
    default) can serve concurrent restores without sharing fd caches."""

    __slots__ = ("stats", "lock", "fds", "flow")

    def __init__(self, stats: RestoreStats):
        self.stats = stats
        self.lock = threading.Lock()
        self.fds = _FDCache()
        # flow-link id tying this restore's index→plan→read→assemble spans
        self.flow = obs.flow_id("restore", id(self) & 0xFFFFFF)


class RestoreEngine:
    """Plans and executes parallel ranged restores from any engine format.

    ``threads`` is the ranged-read fan-out width (``1`` gives a serial
    engine with identical results — used by tests and the restore
    benchmark's ablation). ``throttle_mbps`` emulates per-stream storage
    bandwidth exactly like the save-side engines do, so benchmarks can model
    a bandwidth-limited PFS where read parallelism is the paper-world win.
    ``read_chunk_bytes`` caps a single ``preadv`` so large tensors split
    across the pool instead of serializing behind one thread.
    """

    def __init__(self, threads: Optional[int] = None,
                 throttle_mbps: Optional[float] = None,
                 read_chunk_bytes: int = 16 << 20):
        if threads is None:
            threads = min(16, 4 * (os.cpu_count() or 1))
        self.threads = max(1, int(threads))
        self.throttle_mbps = throttle_mbps
        self.read_chunk_bytes = int(read_chunk_bytes)

    # ------------------------------------------------------------- indexing
    def index(self, sdir: str, stats: Optional[RestoreStats] = None,
              stats_lock: Optional[threading.Lock] = None) -> RestoreIndex:
        """One pass over ``sdir``: build the shard directory for whatever
        checkpoint format lives there (same precedence as the writers:
        native ``.dsllm``, then snapshot manifests, then sync pickles)."""
        stats = stats if stats is not None else RestoreStats()
        stats_lock = stats_lock or threading.Lock()
        idx = RestoreIndex(sdir)

        dsllm = sorted(glob.glob(os.path.join(sdir, "*.dsllm")))
        if dsllm:
            for p in dsllm:
                try:
                    rd = FileReader(p)
                except Exception as exc:
                    raise RestoreError(
                        f"corrupt or truncated checkpoint file {p!r}: {exc} "
                        f"(footer unreadable — was the save interrupted?)"
                    ) from exc
                idx.n_files += 1
                for entry in rd.tensors.values():
                    base = entry.name.split("@[", 1)[0]
                    if entry.codec != "raw" and is_chained_codec(entry.codec):
                        idx.delta_tensors.setdefault(base, []).append(
                            (rd, entry))
                    elif entry.codec != "raw":
                        # self-contained encoding (quantized): restorable
                        # standalone through a decode-once shard source
                        region = entry.index if entry.index is not None \
                            else tuple((0, d) for d in entry.shape)
                        comp_nb = sum(c[1] for c in entry.enc_chunks or ())
                        loader = _OnceLoader(
                            (lambda r=rd, e=entry:
                             r.read_encoded_tensor(e.name)
                             .view(np.dtype(e.dtype)).reshape(e.shape)),
                            comp_nb, stats, stats_lock)
                        idx.tensors.setdefault(base, []).append(
                            _EncodedShard(tuple(map(tuple, region)),
                                          entry.shape, entry.dtype, loader))
                    else:
                        idx.tensors.setdefault(base, []).append(
                            _DsllmShard(p, entry))
                for oname, oe in rd.objects.items():
                    idx.objects[oname] = _OnceLoader(
                        (lambda r=rd, n=oname: r.read_object(n)),
                        oe.nbytes, stats, stats_lock)
            return idx

        manifests = sorted(glob.glob(os.path.join(sdir, "manifest_rank*.pkl")))
        snapshot_objects = os.path.join(sdir, "objects.pkl")
        if manifests or os.path.exists(snapshot_objects):
            for mpath in manifests:
                try:
                    with open(mpath, "rb") as f:
                        manifest = pickle.load(f)
                except Exception as exc:
                    raise RestoreError(
                        f"corrupt or truncated manifest {mpath!r}: {exc}"
                    ) from exc
                idx.n_files += 1
                for t in manifest["tensors"]:
                    base = t["name"].split("@[", 1)[0]
                    chunks = []
                    for cpath, lo, hi in t["chunks"]:
                        if not os.path.exists(cpath):  # step dir was moved
                            cpath = os.path.join(sdir,
                                                 os.path.basename(cpath))
                        chunks.append((cpath, lo, hi))
                        idx.n_files += 1
                    index = t["index"] if t["index"] is not None \
                        else tuple((0, d) for d in t["shape"])
                    idx.tensors.setdefault(base, []).append(_SnapshotShard(
                        tuple(map(tuple, index)), t["shape"], t["dtype"],
                        chunks))
            if os.path.exists(snapshot_objects):
                idx.n_files += 1
                nb = os.path.getsize(snapshot_objects)
                try:
                    with open(snapshot_objects, "rb") as f:
                        objs = pickle.load(f)
                except Exception as exc:
                    raise RestoreError(
                        f"corrupt or truncated object file "
                        f"{snapshot_objects!r}: {exc}") from exc
                with stats_lock:
                    stats.bytes_read += nb
                    stats.n_ranges += 1
                for oname, val in objs.items():
                    idx.objects[oname] = (lambda v=val: v)
            return idx

        pkls = sorted(glob.glob(os.path.join(sdir, "*.pkl")))
        if pkls:
            from .baselines import load_sync_rank
            for p in pkls:
                try:
                    with open(p, "rb") as f:
                        graph = pickle.load(f)
                except Exception as exc:
                    raise RestoreError(
                        f"corrupt or truncated checkpoint file {p!r}: {exc}"
                    ) from exc
                nb = os.path.getsize(p)
                idx.n_files += 1
                # count the (unavoidable) whole-graph load once, at index
                # time — the graph is then sliced in memory, never re-read.
                with stats_lock:
                    stats.bytes_read += nb
                    stats.n_ranges += 1
                loader = (lambda g=graph: g)
                for name, rec in graph.items():
                    if name == "__objects__":
                        for oname, val in rec.items():
                            idx.objects[oname] = (lambda v=val: v)
                        continue
                    base = name.split("@[", 1)[0]
                    arr = np.asarray(rec["data"])
                    index = rec["index"] if rec["index"] is not None \
                        else tuple((0, d) for d in arr.shape)
                    idx.tensors.setdefault(base, []).append(_GraphShard(
                        tuple(map(tuple, index)), arr.shape, arr.dtype,
                        loader, name))
            return idx

        raise FileNotFoundError(f"no checkpoint files in {sdir}")

    # ------------------------------------------------------------- planning
    @staticmethod
    def _leaf_regions(leaf) -> Tuple[List[Region], str]:
        """Target regions for one template leaf: one per unique device
        shard of the requested sharding (elastic), or the full array."""
        shape = tuple(leaf.shape)
        full = tuple((0, d) for d in shape)
        if isinstance(leaf, np.ndarray):
            return [full], "numpy"
        sharding = getattr(leaf, "sharding", None)
        if sharding is None:
            return [full], "jax_full"
        try:
            imap = sharding.addressable_devices_indices_map(shape)
        except (AttributeError, TypeError):
            return [full], "jax_full"
        regions: List[Region] = []
        seen = set()
        for index in imap.values():
            region = normalize_index(index, shape)
            if region not in seen:
                seen.add(region)
                regions.append(region)
        return regions or [full], "jax_sharded"

    def _plan_region(self, run: _Run, sources: List[_ShardSource],
                     region: Region, buf: np.ndarray,
                     tasks: List[Callable[[], Tuple[int, int]]],
                     leaf_name: str) -> None:
        """Intersect ``region`` with the stored shards; append read tasks
        that fill ``buf`` (shaped like ``region``) in place."""
        covered = 0
        for src in sources:
            inter = tuple((max(a, c), min(b, d))
                          for (a, b), (c, d) in zip(region, src.index))
            if any(lo >= hi for lo, hi in inter):
                continue
            covered += _volume(inter)
            src_local = tuple((lo - c, hi - c)
                              for (lo, hi), (c, _d) in zip(inter, src.index))
            dst_sl = tuple(slice(lo - a, hi - a)
                           for (lo, hi), (a, _b) in zip(inter, region))
            dst_view = buf[dst_sl] if dst_sl else buf[...]
            self._emit_tasks(run, src, src_local, dst_view, tasks)
        if covered < _volume(region):
            raise RestoreError(
                f"checkpoint does not cover requested region {region} of "
                f"{leaf_name!r} (stored shards cover {covered} of "
                f"{_volume(region)} elements — wrong template shape, or a "
                f"partially written checkpoint?)")

    def _emit_tasks(self, run: _Run, src: _ShardSource, src_local: Region,
                    dst_view: np.ndarray,
                    tasks: List[Callable[[], Tuple[int, int]]]) -> None:
        ranges = src.byte_ranges(src_local)
        if ranges is None or dst_view.dtype != src.dtype \
                or not dst_view.flags["C_CONTIGUOUS"]:
            # Non-byte-addressable source, a dtype-converting restore
            # (template dtype != stored dtype — raw bytes must not land in
            # the destination; numpy assignment casts values), or a
            # destination view whose memory layout differs from the C order
            # of the ranges: read through a scratch intersection buffer.
            def copy_task(src=src, src_local=src_local, dst_view=dst_view):
                arr = self._read_intersection(run, src, src_local)
                dst_view[...] = arr
                return 0, 0  # byte accounting happens inside the source
            tasks.append(copy_task)
            return
        out = dst_view.reshape(-1).view(np.uint8)
        pos = 0
        cap = self.read_chunk_bytes
        for path, off, nb in ranges:
            # split giant runs so they parallelize
            for lo, piece in plan_ranged_slices(nb, cap):
                mv = memoryview(out[pos + lo:pos + lo + piece])
                tasks.append(self._make_pread_task(run, path, off + lo, mv))
            pos += nb

    def _make_pread_task(self, run: _Run, path: str, offset: int,
                         mv: memoryview) -> Callable[[], Tuple[int, int]]:
        def task():
            t0 = time.perf_counter()
            fd = run.fds.get(path)
            _preadv_full(fd, mv, offset)
            if self.throttle_mbps:  # emulate per-stream PFS bandwidth
                target = len(mv) / (self.throttle_mbps * 1e6)
                elapsed = time.perf_counter() - t0
                if target > elapsed:
                    time.sleep(target - elapsed)
            return len(mv), 1
        return task

    def _read_intersection(self, run: _Run, src: _ShardSource,
                           src_local: Region) -> np.ndarray:
        """Scratch-buffer path for non-contiguous destinations."""
        shape = tuple(hi - lo for lo, hi in src_local)
        ranges = src.byte_ranges(src_local)
        if ranges is None:
            return src.read_fallback(src_local)
        tmp = np.empty(shape, dtype=src.dtype)
        out = tmp.reshape(-1).view(np.uint8)
        pos = 0
        nbytes = 0
        n = 0
        t0 = time.perf_counter()
        for path, off, nb in ranges:
            _preadv_full(run.fds.get(path), memoryview(out[pos:pos + nb]),
                         off)
            pos += nb
            nbytes += nb
            n += 1
        with run.lock:
            run.stats.bytes_read += nbytes
            run.stats.n_ranges += n
        if self.throttle_mbps and nbytes:
            target = nbytes / (self.throttle_mbps * 1e6)
            elapsed = time.perf_counter() - t0
            if target > elapsed:
                time.sleep(target - elapsed)
        return tmp

    # ------------------------------------------------------------- restore
    def _run_tasks(self, run: _Run,
                   tasks: List[Callable[[], Tuple[int, int]]]) -> None:
        """Fan the read/apply tasks over the pool; fold I/O accounting."""
        stats = run.stats
        t0 = time.perf_counter()
        if tasks:
            if self.threads == 1:
                for t in tasks:
                    nb, nr = t()
                    stats.bytes_read += nb
                    stats.n_ranges += nr
            else:
                with concurrent.futures.ThreadPoolExecutor(
                        self.threads) as pool:
                    for nb, nr in pool.map(lambda t: t(), tasks):
                        stats.bytes_read += nb
                        stats.n_ranges += nr
        t1 = time.perf_counter()
        stats.read_s += t1 - t0
        if tasks:
            obs.add_span("restore.read", t0, t1, tasks=len(tasks),
                         flow=run.flow)

    def _read_step(self, run: _Run, sdir: str, template: Any):
        """Index ``sdir``, plan per-leaf regions/buffers, execute the
        ranged-read fan-out. Returns ``(treedef, assembled, idx)`` with
        the host buffers filled but not yet assembled into leaves."""
        stats = run.stats
        t0 = time.perf_counter()
        idx = self.index(sdir, stats, run.lock)
        t1 = time.perf_counter()
        stats.index_s += t1 - t0
        obs.add_span("restore.index", t0, t1, dir=os.path.basename(sdir),
                     flow=run.flow, flow_phase="start")
        stats.n_files += idx.n_files

        # ---- plan: regions, buffers, and the full read-task list
        t0 = time.perf_counter()
        leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
        tasks: List[Callable[[], Tuple[int, int]]] = []
        # (kind, leaf, aux, pstr) per template leaf
        assembled: List[Tuple[str, Any, Any, str]] = []
        for path, leaf in leaves:
            pstr = f"state/{_path_str(path)}"
            if isinstance(leaf, (jax.Array, jax.ShapeDtypeStruct,
                                 np.ndarray)):
                if pstr not in idx.tensors:
                    if pstr in idx.delta_tensors:
                        raise RestoreError(
                            f"tensor {pstr!r} is delta-encoded in {sdir!r} "
                            f"— a differential step cannot be restored "
                            f"alone; replay its chain (restore_chain / "
                            f"CheckpointManager.restore)")
                    raise KeyError(
                        f"tensor {pstr!r} not found in checkpoint "
                        f"(have {sorted(idx.tensors)[:5]}...)")
                stats.n_leaves += 1
                regions, kind = self._leaf_regions(leaf)
                dtype = np.dtype(leaf.dtype)
                buffers: Dict[Region, np.ndarray] = {}
                for region in regions:
                    buf = np.empty(
                        tuple(hi - lo for lo, hi in region), dtype)
                    buffers[region] = buf
                    self._plan_region(run, idx.tensors[pstr], region,
                                      buf, tasks, pstr)
                assembled.append((kind, leaf, buffers, pstr))
            else:
                assembled.append(("object", leaf, None, pstr))
        t1 = time.perf_counter()
        stats.plan_s += t1 - t0
        obs.add_span("restore.plan", t0, t1, leaves=len(assembled),
                     tasks=len(tasks), flow=run.flow)

        self._run_tasks(run, tasks)
        return treedef, assembled, idx

    def _assemble(self, run: _Run, treedef, assembled,
                  idx: RestoreIndex) -> Any:
        """Host buffers -> leaves; objects resolved from ``idx`` (for a
        chain restore: the newest step's object log)."""
        stats = run.stats
        t0 = time.perf_counter()
        out = []
        for kind, leaf, aux, pstr in assembled:
            if kind == "object":
                out.append(idx.objects[pstr]()
                           if pstr in idx.objects else leaf)
            elif kind == "numpy":
                out.append(next(iter(aux.values())))
            elif kind == "jax_full":
                out.append(jax.numpy.asarray(next(iter(aux.values()))))
            else:  # jax_sharded
                shape = tuple(leaf.shape)
                buffers = aux

                def cb(index, shape=shape, buffers=buffers):
                    return buffers[normalize_index(index, shape)]
                out.append(jax.make_array_from_callback(
                    shape, leaf.sharding, cb))
        tree = jax.tree_util.tree_unflatten(treedef, out)
        t1 = time.perf_counter()
        stats.assemble_s += t1 - t0
        obs.add_span("restore.assemble", t0, t1, flow=run.flow,
                     flow_phase="end")
        return tree

    def restore(self, sdir: str, template: Any
                ) -> Tuple[Any, RestoreStats]:
        """Rebuild a ``template``-shaped pytree from ``sdir``.

        Array leaves (``jax.Array``/``ShapeDtypeStruct``/``np.ndarray``)
        are reassembled from whichever stored shards intersect each target
        region; non-array leaves come from the object log (or keep their
        template value). Returns ``(tree, stats)``.
        """
        run = _Run(RestoreStats(threads=self.threads))
        try:
            treedef, assembled, idx = self._read_step(run, sdir, template)
            tree = self._assemble(run, treedef, assembled, idx)
            return tree, run.stats
        finally:
            run.fds.close()

    # ------------------------------------------------------- chain restore
    def restore_chain(self, sdirs: Sequence[str], template: Any
                      ) -> Tuple[Any, RestoreStats]:
        """Replay a differential chain: ``sdirs[0]`` is the keyframe step
        directory, ``sdirs[1:]`` the delta steps in chain order.

        The keyframe restores exactly like a full snapshot (same planned
        ranged-read fan-out, elastic across target shardings); each delta
        step's compressed XOR payloads are then decompressed (once per
        stored shard, whatever the target sharding) and folded into the
        in-place host buffers (kernel-backed XOR). Steps apply strictly
        in chain order, and within a step any raw re-saved tensors
        overwrite *before* XOR folds run, so mixed raw/encoded steps are
        deterministic. Objects (RNG state, data-pipeline cursors, step
        metadata) always come from the *newest* step — every save
        persists its objects in full.
        """
        if not sdirs:
            raise ValueError("restore_chain needs at least one step dir")
        run = _Run(RestoreStats(threads=self.threads))
        try:
            treedef, assembled, idx = self._read_step(run, sdirs[0],
                                                      template)
            for sdir in sdirs[1:]:
                idx = self._apply_delta_dir(run, sdir, assembled)
            tree = self._assemble(run, treedef, assembled, idx)
            return tree, run.stats
        finally:
            run.fds.close()

    def _apply_delta_dir(self, run: _Run, sdir: str,
                         assembled) -> RestoreIndex:
        """Fold one delta step's encoded shards into the leaf buffers."""
        stats = run.stats
        t0 = time.perf_counter()
        idx = self.index(sdir, stats, run.lock)
        t1 = time.perf_counter()
        stats.index_s += t1 - t0
        obs.add_span("restore.index", t0, t1, dir=os.path.basename(sdir),
                     delta=True, flow=run.flow)
        stats.n_files += idx.n_files
        xor_tasks: List[Callable[[], Tuple[int, int]]] = []
        raw_tasks: List[Callable[[], Tuple[int, int]]] = []
        t0 = time.perf_counter()
        for kind, leaf, aux, pstr in assembled:
            if kind == "object":
                continue
            enc = idx.delta_tensors.get(pstr, ())
            raw = idx.tensors.get(pstr, ())
            if not enc and not raw:
                raise RestoreError(
                    f"delta step {sdir!r} does not cover tensor {pstr!r} "
                    f"— the chain was built across a reshard without a "
                    f"keyframe?")
            # one task per stored shard: the payload is decompressed once
            # and folded into every intersecting target region
            for rd, entry in enc:
                xor_tasks.append(self._make_delta_task(run, rd, entry,
                                                       aux, pstr))
            if raw:
                # a raw tensor inside a delta step (re-saved whole):
                # overwrite semantics via the normal ranged-read path —
                # executed as a separate batch *before* the XOR folds so
                # mixed raw/encoded steps stay deterministic
                for region, buf in aux.items():
                    self._plan_region(run, list(raw), region, buf,
                                      raw_tasks, pstr)
        t1 = time.perf_counter()
        stats.plan_s += t1 - t0
        obs.add_span("restore.plan", t0, t1, delta=True, flow=run.flow)
        self._run_tasks(run, raw_tasks)
        self._run_tasks(run, xor_tasks)
        return idx

    def _make_delta_task(self, run: _Run, rd, entry,
                         buffers: Dict[Region, np.ndarray], pstr: str
                         ) -> Callable[[], Tuple[int, int]]:
        def task():
            src_index = entry.index if entry.index is not None \
                else tuple((0, d) for d in entry.shape)
            inters = []
            for region, buf in buffers.items():
                inter = tuple((max(a, c), min(b, d))
                              for (a, b), (c, d) in zip(region, src_index))
                if not any(lo >= hi for lo, hi in inter):
                    inters.append((region, buf, inter))
            if not inters:
                return 0, 0
            dtype = np.dtype(entry.dtype)
            if any(dtype != buf.dtype for _r, buf, _i in inters):
                raise RestoreError(
                    f"{pstr!r}: template dtype != stored dtype {dtype} — "
                    f"dtype-converting restore is not defined for XOR "
                    f"delta chains")
            from .state_provider import xor_bytes
            comp_nb = sum(c[1] for c in entry.enc_chunks or ())
            delta = rd.read_encoded_delta(entry.name) \
                .view(dtype).reshape(entry.shape)
            for region, buf, inter in inters:
                src_sl = tuple(slice(lo - c, hi - c)
                               for (lo, hi), (c, _d) in zip(inter,
                                                            src_index))
                dst_sl = tuple(slice(lo - a, hi - a)
                               for (lo, hi), (a, _b) in zip(inter, region))
                dst_view = buf[dst_sl] if dst_sl else buf[...]
                sub = delta[src_sl] if src_sl else delta[...]
                cur = np.ascontiguousarray(dst_view)
                cur_b = cur.reshape(-1).view(np.uint8)
                sub_b = np.ascontiguousarray(sub).reshape(-1).view(np.uint8)
                folded = xor_bytes(cur_b, sub_b) \
                    .view(cur.dtype).reshape(cur.shape)
                if dst_sl:
                    buf[dst_sl] = folded
                else:
                    buf[...] = folded
            return comp_nb, len(entry.enc_chunks or ())
        return task
