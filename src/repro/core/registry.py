"""State-provider registry: declarative routing of state leaves (paper §V-A3).

The paper's composable state providers decouple *what a piece of state is*
(device tensor, optimizer moment, Python object) from *how it moves*. This
module makes that composition user-facing: a
:class:`StateProviderRegistry` holds an **ordered** list of
:class:`ProviderRule`\\ s, and every leaf of a named state domain
(``{"model": params, "optimizer": opt_state, "dataloader": ..., ...}``)
is routed by the **first matching rule** to a provider:

* ``"tensor"``     — raw zero-copy streaming
  (:class:`~repro.core.state_provider.TensorStateProvider`);
* ``"object"``     — lazily-serialized Python state
  (:class:`~repro.core.state_provider.ObjectStateProvider`);
* ``"delta"``      — XOR differential encoding under the manager's
  :class:`~repro.core.policy.DeltaPolicy` chain schedule
  (:class:`~repro.core.state_provider.DeltaStateProvider`);
* ``"quantized"``  — blockwise int8 quantization on the Pallas kernels
  (:class:`~repro.core.state_provider.QuantizedStateProvider`) — e.g.
  optimizer moments at 4× reduction while params stay raw;
* ``"auto"``       — the adaptive default: delta when the save is
  differential, raw otherwise (exactly the pre-registry behavior);
* any name registered through :meth:`StateProviderRegistry.register` — a
  user factory returning a
  :class:`~repro.core.state_provider.TensorStateProvider` subclass.

Rules match on any combination of domain name, state-path regex, dtype,
size thresholds, and leaf kind (tensor vs object). Matching happens once
per leaf at shard-planning time (``core.distributed.plan_shards``); the
resolved :class:`ProviderRoute` rides each
:class:`~repro.core.distributed.ShardRecord`, so single-writer engines and
every rank lane of a multi-writer
:class:`~repro.dist.coordinator.Coordinator` honor the same routing
without re-consulting the registry.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Dict, Iterable, Optional, Sequence, \
    Tuple, Union

#: provider names with built-in construction paths in the engines.
STOCK_PROVIDERS = ("auto", "tensor", "object", "delta", "quantized")

#: stock providers a tensor leaf may route to.
_TENSOR_PROVIDERS = ("auto", "tensor", "delta", "quantized")
#: stock providers an object leaf may route to.
_OBJECT_PROVIDERS = ("auto", "object")


class RegistryError(ValueError):
    """A leaf could not be routed, or a rule references an unknown or
    incompatible provider."""


@dataclasses.dataclass(frozen=True)
class ProviderRoute:
    """The resolved routing decision for one state leaf.

    ``factory`` is set for user-registered providers (the registry attaches
    the callable at routing time so engines never need the registry
    itself); stock providers are constructed by name inside the engine.
    """

    provider: str
    options: Tuple[Tuple[str, Any], ...] = ()
    rule_index: int = -1
    factory: Optional[Callable[..., Any]] = None

    def option(self, key: str, default: Any = None) -> Any:
        for k, v in self.options:
            if k == key:
                return v
        return default


@dataclasses.dataclass(frozen=True)
class ProviderRule:
    """One ordered matching rule. ``None`` predicates match everything, so
    a rule with no predicates is a catch-all; rules are tried in registry
    order and the first match wins (overlaps resolve by position)."""

    provider: str
    domain: Optional[str] = None            # exact state-domain name
    path_regex: Optional[str] = None        # re.search on the full state path
    dtype: Optional[Union[str, Sequence[str]]] = None
    min_nbytes: Optional[int] = None
    max_nbytes: Optional[int] = None        # exclusive upper bound
    kind: Optional[str] = None              # "tensor" | "object"
    options: Tuple[Tuple[str, Any], ...] = ()

    def __post_init__(self):
        if self.kind not in (None, "tensor", "object"):
            raise RegistryError(
                f"rule kind must be 'tensor' or 'object', got {self.kind!r}")
        if self.path_regex is not None:
            object.__setattr__(self, "_re", re.compile(self.path_regex))
        else:
            object.__setattr__(self, "_re", None)
        if isinstance(self.options, dict):
            object.__setattr__(self, "options",
                               tuple(sorted(self.options.items())))

    def matches(self, *, domain: str, path: str, dtype: Optional[str],
                nbytes: Optional[int], kind: str) -> bool:
        if self.kind is not None and self.kind != kind:
            return False
        if self.domain is not None and self.domain != domain:
            return False
        if self._re is not None and not self._re.search(path):
            return False
        if self.dtype is not None:
            allowed = ((self.dtype,) if isinstance(self.dtype, str)
                       else tuple(self.dtype))
            if dtype not in allowed:
                return False
        if self.min_nbytes is not None and (nbytes is None
                                            or nbytes < self.min_nbytes):
            return False
        if self.max_nbytes is not None and (nbytes is None
                                            or nbytes >= self.max_nbytes):
            return False
        return True


class StateProviderRegistry:
    """Ordered, composable leaf→provider routing rules.

    ``strict=True`` turns an unmatched leaf into a hard
    :class:`RegistryError` naming the state path — use it to guarantee
    every domain was consciously routed. The default (non-strict) falls
    through to ``"auto"``/``"object"``, i.e. exactly the behavior of a
    manager without a registry, so adding one rule never silently changes
    how the *rest* of the state is checkpointed.
    """

    def __init__(self, rules: Iterable[ProviderRule] = (),
                 strict: bool = False):
        self.strict = strict
        self._rules: list = []
        self._factories: Dict[str, Callable[..., Any]] = {}
        for r in rules:
            self.add_rule(r)

    # ------------------------------------------------------------- building
    def register(self, name: str, factory: Callable[..., Any]
                 ) -> "StateProviderRegistry":
        """Register a custom tensor-provider factory under ``name``.

        The factory is called per shard as ``factory(record, **kw)`` where
        ``record`` is the :class:`~repro.core.distributed.ShardRecord` and
        ``kw`` are the engine's standard
        :class:`~repro.core.state_provider.TensorStateProvider` constructor
        kwargs (dtype/shape/nbytes/host_array/global_shape/index/
        chunk_bytes/stream_intra_tensor); it must return a
        ``TensorStateProvider`` (subclass) instance. Returns ``self`` for
        chaining."""
        if name in STOCK_PROVIDERS:
            raise RegistryError(
                f"cannot override stock provider {name!r}")
        if not callable(factory):
            raise RegistryError(f"factory for {name!r} is not callable")
        self._factories[name] = factory
        return self

    def add_rule(self, rule: Optional[ProviderRule] = None, /,
                 **kw) -> "StateProviderRegistry":
        """Append a rule (lowest precedence so far). Accepts a prebuilt
        :class:`ProviderRule` or its constructor kwargs. Returns ``self``."""
        if rule is None:
            rule = ProviderRule(**kw)
        elif kw:
            raise TypeError("pass a ProviderRule or kwargs, not both")
        self._rules.append(rule)
        return self

    @property
    def rules(self) -> Tuple[ProviderRule, ...]:
        return tuple(self._rules)

    @classmethod
    def default(cls) -> "StateProviderRegistry":
        """The registry equivalent of "no registry": tensors adapt to the
        save mode (raw, or delta under a DeltaPolicy), objects serialize
        lazily. Append rules *before* these catch-alls to specialize."""
        return cls(rules=[ProviderRule(provider="auto", kind="tensor"),
                          ProviderRule(provider="object", kind="object")])

    # -------------------------------------------------------------- routing
    def _serves_kind(self, provider: str, kind: str) -> bool:
        """Whether ``provider`` can serve leaves of ``kind`` (custom
        factories build tensor providers only)."""
        if provider in self._factories:
            return kind == "tensor"
        return provider in (_TENSOR_PROVIDERS if kind == "tensor"
                            else _OBJECT_PROVIDERS)

    def route(self, *, domain: str, path: str, dtype: Optional[str] = None,
              nbytes: Optional[int] = None, kind: str = "tensor"
              ) -> ProviderRoute:
        """Resolve one leaf. First matching rule wins; unmatched leaves
        fall through to the adaptive default unless ``strict``.

        A provider implies the leaf kind it serves, so a catch-all
        ``ProviderRule(provider="tensor")`` simply does not match object
        leaves (they fall through) — but a rule whose *explicit* ``kind``
        contradicts its provider is a configuration error and raises."""
        for i, rule in enumerate(self._rules):
            if not rule.matches(domain=domain, path=path, dtype=dtype,
                                nbytes=nbytes, kind=kind):
                continue
            name = rule.provider
            custom = name in self._factories
            if not custom and name not in STOCK_PROVIDERS:
                raise RegistryError(
                    f"rule #{i} routes {path!r} to unknown provider "
                    f"{name!r} — register() it or use one of "
                    f"{STOCK_PROVIDERS}")
            if not self._serves_kind(name, kind):
                if rule.kind is not None:
                    other = "tensor" if kind == "object" else "object"
                    raise RegistryError(
                        f"rule #{i} pins kind={rule.kind!r} but routes "
                        f"{path!r} to provider {name!r}, which serves "
                        f"{other} state only")
                continue  # provider-implied kind mismatch: not a match
            if name == "auto" and kind == "object":
                name = "object"
            return ProviderRoute(
                provider=name, options=rule.options, rule_index=i,
                factory=self._factories.get(name))
        if self.strict:
            raise RegistryError(
                f"no provider rule matches state path {path!r} "
                f"(domain={domain!r}, kind={kind}, dtype={dtype}, "
                f"nbytes={nbytes}) and the registry is strict — add a "
                f"matching rule or a catch-all "
                f"ProviderRule(provider='auto')")
        return ProviderRoute(provider="auto" if kind == "tensor"
                             else "object")
