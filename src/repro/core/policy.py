"""Declarative checkpoint policy: the composable public configuration.

The manager grew one constructor kwarg per subsystem (engine tuning,
storage tiers, multi-rank world, differential chains) until the sprawl hid
the architecture. :class:`CheckpointPolicy` makes the composition explicit
— one frozen config object per subsystem, composed into one policy:

* :class:`EnginePolicy`  — which data-movement engine and its lane tuning;
* :class:`StoragePolicy` — where committed steps live (tiers), how many
  survive (retention), and integrity checksums;
* :class:`DistPolicy`    — the multi-rank writer world / coordinator;
* :class:`DeltaPolicy`   — the differential-checkpointing chain schedule;
* a :class:`~repro.core.registry.StateProviderRegistry` routing each
  state leaf to its provider.

Construct managers with ``CheckpointManager.from_policy(directory,
policy)``; the legacy kwarg constructor still works (every old kwarg maps
onto exactly one policy field — see
:meth:`CheckpointPolicy.from_legacy_kwargs`) but emits a
``DeprecationWarning``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

from repro.storage.repository import RetentionPolicy, Tier

from .codecs import DELTA_CODEC
from .registry import StateProviderRegistry


@dataclasses.dataclass(frozen=True)
class EnginePolicy:
    """Data-movement engine selection and lane tuning (paper §V-A)."""

    mode: str = "datastates"
    host_cache_bytes: int = 1 << 30
    flush_threads: int = 4
    chunk_bytes: int = 4 << 20
    throttle_mbps: Optional[float] = None
    restore_threads: Optional[int] = None

    def __post_init__(self):
        if self.host_cache_bytes < 1:
            raise ValueError("host_cache_bytes must be positive")
        if self.flush_threads < 1 or self.chunk_bytes < 1:
            raise ValueError("flush_threads and chunk_bytes must be >= 1")


@dataclasses.dataclass(frozen=True)
class StoragePolicy:
    """Tiered residence + retention of committed steps (repository layer)."""

    tiers: Tuple[Tier, ...] = ()
    retention: Optional[RetentionPolicy] = None
    manifest_checksums: bool = True

    def __post_init__(self):
        # accept any sequence of tiers; freeze to a tuple
        object.__setattr__(self, "tiers", tuple(self.tiers))


@dataclasses.dataclass(frozen=True)
class DistPolicy:
    """Multi-rank writer world (hierarchical two-phase commit).

    ``runtime`` picks the execution domain behind each writer rank:
    ``"thread"`` (default — deterministic in-process lanes, the test
    double) or ``"process"`` (one spawned OS process per rank — real
    isolation, real SIGKILL blast radius). ``node_size`` sets the commit
    tree's fan-in (ranks per node-local aggregator; default groups of 8,
    so small worlds behave single-node).
    """

    world: Optional[int] = None
    coordinator: Optional[Any] = None
    ack_timeout_s: Optional[float] = None
    runtime: str = "thread"
    node_size: Optional[int] = None

    def __post_init__(self):
        if self.world is not None and self.world < 1:
            raise ValueError(f"world must be >= 1, got {self.world}")
        if self.runtime not in ("thread", "process"):
            raise ValueError(
                f"runtime must be 'thread' or 'process', "
                f"got {self.runtime!r}")
        if self.node_size is not None and self.node_size < 1:
            raise ValueError(
                f"node_size must be >= 1, got {self.node_size}")


@dataclasses.dataclass(frozen=True)
class DeltaPolicy:
    """Differential checkpointing on the main engine path (paper §VII).

    Every save streams XOR deltas of each delta-routed tensor against the
    previous save's retained host copy, compressed on the flush lanes —
    except a raw *keyframe* every ``keyframe_every`` saves, on the first
    save of a run, and whenever the shard set / shapes / dtypes change
    (elastic reshard). ``verify_chain_on_restore`` re-audits every chain
    member (sizes + manifest checksums) before a chain restore, so silent
    corruption of a keyframe can never be XOR-amplified into a restored
    state.
    """

    keyframe_every: int = 4
    codec: str = DELTA_CODEC
    verify_chain_on_restore: bool = True

    def __post_init__(self):
        if self.keyframe_every < 1:
            raise ValueError(
                f"keyframe_every must be >= 1, got {self.keyframe_every}")


# Legacy CheckpointManager kwarg → (policy section, field) — the migration
# table in README mirrors this mapping.
LEGACY_KWARG_MAP = {
    "mode": ("engine", "mode"),
    "host_cache_bytes": ("engine", "host_cache_bytes"),
    "flush_threads": ("engine", "flush_threads"),
    "chunk_bytes": ("engine", "chunk_bytes"),
    "throttle_mbps": ("engine", "throttle_mbps"),
    "restore_threads": ("engine", "restore_threads"),
    "tiers": ("storage", "tiers"),
    "retention": ("storage", "retention"),
    "manifest_checksums": ("storage", "manifest_checksums"),
    "world": ("dist", "world"),
    "coordinator": ("dist", "coordinator"),
    "ack_timeout_s": ("dist", "ack_timeout_s"),
    "delta": (None, "delta"),
}


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    """The complete declarative configuration of a checkpoint manager."""

    engine: EnginePolicy = dataclasses.field(default_factory=EnginePolicy)
    storage: StoragePolicy = dataclasses.field(default_factory=StoragePolicy)
    dist: DistPolicy = dataclasses.field(default_factory=DistPolicy)
    delta: Optional[DeltaPolicy] = None
    providers: Optional[StateProviderRegistry] = None

    @classmethod
    def from_legacy_kwargs(cls, **kwargs) -> "CheckpointPolicy":
        """Build a policy from the deprecated flat-kwarg constructor
        surface. Every legacy kwarg maps onto exactly one policy field;
        unknown names raise ``TypeError`` like a normal bad kwarg."""
        sections: dict = {"engine": {}, "storage": {}, "dist": {}}
        top: dict = {}
        for name, value in kwargs.items():
            where = LEGACY_KWARG_MAP.get(name)
            if where is None:
                raise TypeError(
                    f"unknown CheckpointManager argument {name!r}")
            section, field = where
            (top if section is None else sections[section])[field] = value
        return cls(engine=EnginePolicy(**sections["engine"]),
                   storage=StoragePolicy(**sections["storage"]),
                   dist=DistPolicy(**sections["dist"]), **top)

    def replace(self, **kw) -> "CheckpointPolicy":
        return dataclasses.replace(self, **kw)
