"""Hybrid fixed-offset / log-structured-append checkpoint file layout.

Implements the persistent format of DataStates-LLM (paper §V-A5):

* **Tensor region** — tensors have sizes known a priori, so their offsets are
  precomputed and fixed; every tensor start is aligned to ``ALIGN`` bytes so a
  direct-I/O (``O_DIRECT``/liburing-style) backend could be swapped in.
* **Object log region** — serialized Python objects have sizes unknown until
  serialization finishes, so their chunks are appended log-structured starting
  at the end of the tensor region (offsets assigned at append time).
* **Footer** — a trailing metadata header (msgpack) describing the layout of
  both regions, followed by ``u64 footer_len`` + ``MAGIC``, appended last.

Readers open the file, read the trailing 16 bytes, then the footer, and can
lazily fetch any tensor (zero-copy via ``np.memmap``) or object.
"""

from __future__ import annotations

import dataclasses
import io
import os
import pickle
import struct
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import msgpack
import numpy as np

from repro.analysis.locks import declares_lock
from repro.obs import trace as obs
from repro.obs.metrics import metrics as obs_metrics

MAGIC = b"DSLLMCK1"
ALIGN = 4096
_TRAILER = struct.Struct("<Q8s")  # footer_len, magic


def maybe_fsync(fd: int) -> None:
    """fsync unless REPRO_NO_FSYNC=1 (benchmark mode: this container's VM
    disk fsyncs at an erratic 18-44 MB/s, which would swamp the controlled
    write-throttle that emulates the paper's PFS; durability semantics are
    unchanged in production use)."""
    if os.environ.get("REPRO_NO_FSYNC") != "1":
        os.fsync(fd)


def align_up(n: int, align: int = ALIGN) -> int:
    return (n + align - 1) // align * align


@dataclasses.dataclass(frozen=True)
class TensorEntry:
    """A tensor (or tensor shard), either placed at a fixed offset
    (``codec == "raw"``) or encoded into log-appended compressed chunks
    (differential checkpointing: ``codec == "xor+zstd"``)."""

    name: str
    offset: int                    # fixed-region offset; -1 for encoded
    nbytes: int                    # raw (decoded) byte size
    dtype: str
    shape: Tuple[int, ...]
    # Global-shard bookkeeping (which slice of the logical array this is).
    global_shape: Optional[Tuple[int, ...]] = None
    index: Optional[Tuple[Tuple[int, int], ...]] = None  # (start, stop) per dim
    checksum: Optional[int] = None
    codec: str = "raw"
    # Encoded tensors: (file_offset, comp_nbytes, raw_lo, raw_hi, digest)
    # per compressed chunk — raw addressing is explicit, so flush-lane
    # append order never matters for reconstruction. ``digest`` is the
    # position-weighted u32 checksum of the *uncompressed* payload (the
    # fused encoder emits it in the same pass that produced the payload);
    # ``None`` when the save ran without manifest checksums, or in footers
    # written before digests existed (legacy 4-tuples).
    enc_chunks: Optional[List[Tuple[int, int, int, int, Optional[int]]]] = None
    # Raw (fixed-offset) tensors saved with manifest checksums:
    # (raw_lo, raw_hi, digest) per write chunk — the keyframe/raw
    # counterpart of ``enc_chunks`` digests, so verify can localize a
    # flipped chunk inside a keyframe instead of only failing the whole
    # file's checksum. ``None`` in legacy footers or checksum-less saves.
    raw_chunks: Optional[List[Tuple[int, int, Optional[int]]]] = None


@dataclasses.dataclass(frozen=True)
class ObjectEntry:
    """A serialized Python object appended to the log region."""

    name: str
    offset: int
    nbytes: int
    codec: str = "pickle"


@dataclasses.dataclass
class FileLayout:
    """Precomputed layout for one checkpoint file (paper Fig 1 shard file)."""

    tensors: List[TensorEntry]
    tensor_region_end: int  # aligned end of the fixed-offset region

    @classmethod
    def plan(cls, specs: Sequence[Tuple[str, int, str, Tuple[int, ...],
                                        Optional[Tuple[int, ...]],
                                        Optional[Tuple[Tuple[int, int], ...]]]]
             ) -> "FileLayout":
        """Assign fixed, aligned offsets to tensors with known sizes.

        ``specs``: (name, nbytes, dtype, shape, global_shape, index) tuples.
        """
        entries: List[TensorEntry] = []
        cursor = 0
        for name, nbytes, dtype, shape, gshape, index in specs:
            cursor = align_up(cursor)
            entries.append(TensorEntry(name=name, offset=cursor, nbytes=nbytes,
                                       dtype=dtype, shape=tuple(shape),
                                       global_shape=gshape, index=index))
            cursor += nbytes
        return cls(tensors=entries, tensor_region_end=align_up(cursor))


@declares_lock("writer.append", rank=60, attrs=("_append_lock",))
class FileWriter:
    """Positional writer for one checkpoint file.

    Thread-safe: tensor chunks go to fixed offsets with ``os.pwrite`` (no
    shared cursor), object chunks reserve space on an atomic append cursor in
    the log region. The footer is written by :meth:`finalize`.

    With ``track_checksum=True`` the writer accumulates the manifest-
    compatible file checksum *while writing* (every byte lands exactly once
    at a fixed or append-reserved offset, so the streaming accumulator in
    :mod:`repro.storage.file_format` is exact): each pwrite's contribution
    is computed outside any lock and folded under the existing append lock,
    and :attr:`file_checksum` is valid after :meth:`finalize` — the commit
    lane can reuse it instead of re-reading the file.
    """

    def __init__(self, path: str, layout: FileLayout,
                 track_checksum: bool = False):
        import threading

        self.path = path
        self.layout = layout
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._fd = os.open(path, os.O_WRONLY | os.O_CREAT | os.O_TRUNC, 0o644)
        self._append_lock = threading.Lock()
        self._append_cursor = layout.tensor_region_end
        self._objects: List[ObjectEntry] = []
        self._extra_meta: Dict[str, Any] = {}
        # Encoded-tensor bookkeeping (differential checkpointing): static
        # meta declared by the producer, per-chunk records appended by the
        # flush lanes as compressed payloads land in the log region.
        self._enc_meta: Dict[str, Dict[str, Any]] = {}
        self._enc_chunks: Dict[str, List[Tuple[int, int, int, int,
                                               Optional[int]]]] = {}
        # Per-chunk digests of raw fixed-offset writes (keyframes/plain
        # tensors under manifest checksums), recorded by the flush lanes.
        self._raw_chunks: Dict[str, List[Tuple[int, int,
                                               Optional[int]]]] = {}
        self._csum = None
        if track_checksum:
            from repro.storage.file_format import StreamingFileChecksum
            self._csum = StreamingFileChecksum()
        self._file_checksum: Optional[int] = None

    @property
    def file_checksum(self) -> Optional[int]:
        """Manifest-compatible checksum of the finished file — ``None``
        unless tracking was on and :meth:`finalize` completed."""
        return self._file_checksum

    def _pwrite(self, fd: int, data, offset: int) -> None:
        os.pwrite(fd, data, offset)
        if self._csum is not None:
            contrib = self._csum.contribution(offset, data)
            with self._append_lock:
                self._csum.fold(contrib)

    # -- tensor region ------------------------------------------------------
    def write_at(self, offset: int, data) -> None:
        """Write a (chunk of a) tensor at its fixed offset. GIL-released."""
        self._pwrite(self._fd, data, offset)

    # -- object log region ---------------------------------------------------
    def append_object(self, name: str, payload: bytes, codec: str = "pickle"
                      ) -> ObjectEntry:
        with self._append_lock:
            off = self._append_cursor
            self._append_cursor += len(payload)
        self._pwrite(self._fd, payload, off)
        obs_metrics.inc("writer.append_bytes", len(payload))
        entry = ObjectEntry(name=name, offset=off, nbytes=len(payload),
                            codec=codec)
        with self._append_lock:
            self._objects.append(entry)
        return entry

    # -- encoded tensors (differential checkpointing) ------------------------
    def declare_encoded_tensor(self, name: str, *, dtype: str,
                               shape: Tuple[int, ...], nbytes: int,
                               codec: str,
                               global_shape: Optional[Tuple[int, ...]] = None,
                               index: Optional[Tuple[Tuple[int, int], ...]]
                               = None) -> None:
        """Register the static metadata of a tensor whose payload arrives
        as compressed log-append chunks (the footer needs dtype/shape even
        though no fixed-region offset exists)."""
        with self._append_lock:
            self._enc_meta[name] = {
                "dtype": dtype, "shape": tuple(shape), "nbytes": int(nbytes),
                "codec": codec, "global_shape": global_shape, "index": index}

    def append_encoded_chunk(self, name: str, payload: bytes,
                             raw_lo: int, raw_hi: int,
                             digest: Optional[int] = None) -> None:
        """Append one compressed chunk of an encoded tensor; thread-safe
        (called from concurrent flush lanes). ``digest`` is the fused
        encoder's checksum of the *uncompressed* payload, recorded in the
        footer so decode can verify the chunk without a second pass."""
        with self._append_lock:
            off = self._append_cursor
            self._append_cursor += len(payload)
        self._pwrite(self._fd, payload, off)
        obs_metrics.inc("writer.append_bytes", len(payload))
        with self._append_lock:
            self._enc_chunks.setdefault(name, []).append(
                (off, len(payload), int(raw_lo), int(raw_hi),
                 int(digest) if digest is not None else None))

    def record_raw_chunk(self, name: str, raw_lo: int, raw_hi: int,
                         digest: Optional[int]) -> None:
        """Record the per-chunk digest of one raw fixed-offset write;
        thread-safe (called from concurrent flush lanes). The footer gains
        a ``raw_chunks`` list per tensor so verify can localize a flipped
        chunk in a keyframe the same way it can in a delta."""
        with self._append_lock:
            self._raw_chunks.setdefault(name, []).append(
                (int(raw_lo), int(raw_hi),
                 int(digest) if digest is not None else None))

    def set_meta(self, key: str, value: Any) -> None:
        self._extra_meta[key] = value

    # -- footer --------------------------------------------------------------
    def _encoded_entries(self) -> List[TensorEntry]:
        entries = []
        for name, m in sorted(self._enc_meta.items()):
            chunks = sorted(self._enc_chunks.get(name, ()),
                            key=lambda c: c[2])
            covered = 0
            for _off, _nb, lo, hi, _dig in chunks:
                if lo != covered:
                    break
                covered = hi
            if covered != m["nbytes"]:
                raise ValueError(
                    f"encoded tensor {name!r}: chunks cover {covered} of "
                    f"{m['nbytes']} raw bytes — a flush lane lost a chunk")
            # Tensor-level checksum for free: fold the fused per-chunk
            # digests in raw order (same (i+1)-weighted fold the manifest
            # uses for file chunks) — no extra read of the payload.
            csum = None
            if chunks and all(c[4] is not None for c in chunks):
                csum = 0
                for i, c in enumerate(chunks):
                    csum = (csum + (i + 1) * c[4]) % (1 << 32)
            entries.append(TensorEntry(
                name=name, offset=-1, nbytes=m["nbytes"], dtype=m["dtype"],
                shape=m["shape"], global_shape=m["global_shape"],
                index=m["index"], codec=m["codec"], checksum=csum,
                enc_chunks=chunks))
        return entries

    def _with_raw_chunks(self, entries: List[TensorEntry]
                         ) -> List[TensorEntry]:
        """Attach recorded raw-chunk digests to their fixed-offset entries
        and fold them into a tensor-level checksum (same (i+1)-weighted
        fold the encoded path uses) — no extra read of the payload."""
        out = []
        for t in entries:
            chunks = self._raw_chunks.get(t.name)
            if not chunks:
                out.append(t)
                continue
            chunks = sorted(chunks, key=lambda c: c[0])
            covered = 0
            for lo, hi, _dig in chunks:
                if lo != covered:
                    break
                covered = hi
            if covered != t.nbytes:
                raise ValueError(
                    f"raw tensor {t.name!r}: digest records cover "
                    f"{covered} of {t.nbytes} raw bytes — a flush lane "
                    f"lost a chunk record")
            csum = None
            if all(c[2] is not None for c in chunks):
                csum = 0
                for i, c in enumerate(chunks):
                    csum = (csum + (i + 1) * c[2]) % (1 << 32)
            out.append(dataclasses.replace(t, raw_chunks=chunks,
                                           checksum=csum))
        return out

    def finalize(self, tensor_checksums: Optional[Dict[str, int]] = None) -> None:
        tensors = self._with_raw_chunks(self.layout.tensors) \
            + self._encoded_entries()
        if tensor_checksums:
            tensors = [dataclasses.replace(t, checksum=tensor_checksums[t.name])
                       if t.name in tensor_checksums else t
                       for t in tensors]
        footer = {
            "version": 1,
            "tensors": [dataclasses.asdict(t) for t in tensors],
            "objects": [dataclasses.asdict(o) for o in self._objects],
            "meta": self._extra_meta,
        }
        payload = msgpack.packb(footer, use_bin_type=True)
        with self._append_lock:
            fd = self._fd
            if fd < 0:
                # a concurrent abort() (or double finalize) already closed
                # the file — sealing it now would publish a partial file
                raise ValueError(
                    f"{self.path}: finalize() on a closed/aborted writer")
            # take sole ownership of the fd so a racing abort() cannot
            # close it between our writes below
            self._fd = -1
            off = self._append_cursor
            self._append_cursor += len(payload) + _TRAILER.size
        with obs.span("file.finalize", file=os.path.basename(self.path),
                      footer_bytes=len(payload)):
            trailer = _TRAILER.pack(len(payload), MAGIC)
            os.pwrite(fd, payload, off)
            os.pwrite(fd, trailer, off + len(payload))
            if self._csum is not None:
                # single-threaded here (fd ownership was just taken), so
                # fold directly; after this the accumulator covers every
                # byte of the finished file
                self._csum.update(off, payload)
                self._csum.update(off + len(payload), trailer)
                self._file_checksum = self._csum.value
            maybe_fsync(fd)
            os.close(fd)

    def abort(self) -> None:
        """Close the fd without writing a footer. Idempotent and safe to
        call from concurrent error paths."""
        with self._append_lock:
            fd, self._fd = self._fd, -1
        if fd >= 0:
            os.close(fd)


class FileReader:
    """Reader for the hybrid layout; lazy tensor access via memmap."""

    def __init__(self, path: str):
        self.path = path
        size = os.path.getsize(path)
        if size < _TRAILER.size:
            raise ValueError(f"{path}: too small to be a checkpoint file")
        with open(path, "rb") as f:
            f.seek(size - _TRAILER.size)
            footer_len, magic = _TRAILER.unpack(f.read(_TRAILER.size))
            if magic != MAGIC:
                raise ValueError(f"{path}: bad magic {magic!r}")
            f.seek(size - _TRAILER.size - footer_len)
            footer = msgpack.unpackb(f.read(footer_len), raw=False)
        self.footer = footer
        self.tensors: Dict[str, TensorEntry] = {
            t["name"]: TensorEntry(**{
                **t,
                "shape": tuple(t["shape"]),
                "global_shape": (tuple(t["global_shape"])
                                 if t["global_shape"] is not None else None),
                "index": (tuple(map(tuple, t["index"]))
                          if t["index"] is not None else None),
                # legacy footers carry 4-tuples (no per-chunk digest);
                # normalize to 5-tuples with digest=None so every consumer
                # sees one shape
                "enc_chunks": ([tuple(c) + (None,) * (5 - len(c))
                                for c in t["enc_chunks"]]
                               if t.get("enc_chunks") is not None else None),
                # absent in footers written before raw-chunk digests
                "raw_chunks": ([tuple(c) for c in t["raw_chunks"]]
                               if t.get("raw_chunks") is not None else None)})
            for t in footer["tensors"]
        }
        self.objects: Dict[str, ObjectEntry] = {
            o["name"]: ObjectEntry(**o) for o in footer["objects"]
        }
        self.meta: Dict[str, Any] = footer.get("meta", {})

    def tensor_names(self) -> List[str]:
        return list(self.tensors)

    def read_tensor(self, name: str) -> np.ndarray:
        e = self.tensors[name]
        if e.codec != "raw":
            from repro.core.codecs import is_chained_codec
            if is_chained_codec(e.codec):
                raise ValueError(
                    f"{name!r} is {e.codec}-encoded (a differential delta); "
                    f"its value depends on the chain base — restore the step "
                    f"through RestoreEngine.restore_chain / "
                    f"CheckpointManager.restore")
            # self-contained encoding (e.g. int8 quantized): decode in place
            return self.read_encoded_tensor(name) \
                .view(np.dtype(e.dtype)).reshape(e.shape)
        mm = np.memmap(self.path, mode="r", dtype=np.uint8,
                       offset=e.offset, shape=(e.nbytes,))
        return np.asarray(mm).view(np.dtype(e.dtype)).reshape(e.shape)

    def read_encoded_delta(self, name: str) -> np.ndarray:
        """Decompressed (but still XOR-domain) bytes of an encoded tensor,
        assembled in raw order. Used by chain replay. Chunks that carry a
        fused-encode digest are integrity-verified as they are read."""
        from repro.core.codecs import payload_digest
        from repro.core.reduction import _decompress
        e = self.tensors[name]
        if e.codec == "raw":
            raise ValueError(f"{name!r} is raw, not encoded")
        out = np.empty(e.nbytes, dtype=np.uint8)
        with open(self.path, "rb") as f:
            for off, comp_nb, lo, hi, dig in sorted(e.enc_chunks or (),
                                                    key=lambda c: c[2]):
                f.seek(off)
                raw = _decompress(f.read(comp_nb))
                if len(raw) != hi - lo:
                    raise ValueError(
                        f"{name!r} chunk [{lo}:{hi}) decompressed to "
                        f"{len(raw)} B — corrupt delta payload")
                if dig is not None and payload_digest(raw) != dig:
                    raise ValueError(
                        f"{name!r} chunk [{lo}:{hi}) digest mismatch: "
                        f"stored {dig:#010x}, read "
                        f"{payload_digest(raw):#010x} — corrupt delta "
                        f"payload")
                out[lo:hi] = np.frombuffer(raw, dtype=np.uint8)
        return out

    def read_encoded_tensor(self, name: str) -> np.ndarray:
        """Raw (decoded) bytes of a *self-contained* encoded tensor
        (e.g. ``int8q+zstd`` quantized payloads), assembled in raw order.
        Chained codecs (XOR deltas) must go through
        :meth:`read_encoded_delta` + chain replay instead."""
        from repro.core.codecs import decode_chunk_payload, is_chained_codec
        from repro.core.reduction import _decompress
        e = self.tensors[name]
        if e.codec == "raw":
            raise ValueError(f"{name!r} is raw, not encoded")
        if is_chained_codec(e.codec):
            raise ValueError(
                f"{name!r} is {e.codec}-encoded (a differential delta); "
                f"restore it through chain replay, not standalone decode")
        out = np.empty(e.nbytes, dtype=np.uint8)
        covered = 0
        with open(self.path, "rb") as f:
            for off, comp_nb, lo, hi, dig in sorted(e.enc_chunks or (),
                                                    key=lambda c: c[2]):
                if lo != covered:
                    break
                f.seek(off)
                payload = _decompress(f.read(comp_nb))
                # decode verifies the fused digest while dequantizing
                out[lo:hi] = decode_chunk_payload(e.codec, payload, lo, hi,
                                                 expect_digest=dig)
                covered = hi
        if covered != e.nbytes:
            # without this, a gap in the chunk list would silently hand
            # uninitialized buffer bytes to the restored tensor
            raise ValueError(
                f"{name!r}: encoded chunks cover {covered} of {e.nbytes} "
                f"raw bytes — corrupt or truncated footer")
        return out

    def locate_corrupt_chunks(self) -> List[str]:
        """Re-read every tensor chunk that carries a footer digest (raw
        ``raw_chunks`` and encoded ``enc_chunks`` alike) and return a
        human-readable locator per mismatch, e.g.
        ``"w00 raw chunk [0:16777216)"``. Empty list = every digested
        chunk verifies. Verify-time localization: when a file-level
        checksum fails, this names the flipped chunk instead of leaving a
        multi-GB haystack."""
        from repro.core.codecs import payload_digest
        from repro.core.reduction import _decompress
        bad: List[str] = []
        with open(self.path, "rb") as f:
            for name, e in sorted(self.tensors.items()):
                for lo, hi, dig in e.raw_chunks or ():
                    if dig is None:
                        continue
                    f.seek(e.offset + lo)
                    data = f.read(hi - lo)
                    if len(data) != hi - lo \
                            or payload_digest(data) != dig:
                        bad.append(f"{name} raw chunk [{lo}:{hi})")
                for off, comp_nb, lo, hi, dig in e.enc_chunks or ():
                    if dig is None:
                        continue
                    f.seek(off)
                    try:
                        raw = _decompress(f.read(comp_nb))
                    except Exception:
                        bad.append(f"{name} {e.codec} chunk [{lo}:{hi})")
                        continue
                    if payload_digest(raw) != dig:
                        bad.append(f"{name} {e.codec} chunk [{lo}:{hi})")
        return bad

    def read_object_raw(self, name: str) -> bytes:
        """Serialized payload bytes (used by offline consolidation)."""
        e = self.objects[name]
        with open(self.path, "rb") as f:
            f.seek(e.offset)
            return f.read(e.nbytes)

    def read_object(self, name: str) -> Any:
        e = self.objects[name]
        payload = self.read_object_raw(name)
        if e.codec == "pickle":
            return pickle.loads(payload)
        if e.codec == "msgpack":
            return msgpack.unpackb(payload, raw=False)
        raise ValueError(f"unknown codec {e.codec}")
