"""Distributed shard planning: map a sharded pytree onto per-rank files.

Reproduces the checkpoint composition of Fig 1(c,d): every device ("rank")
owns the shards resident on it; replicated shards (pure DP replicas) are
written once, by the lowest-id owner (the paper's DeepSpeed setup likewise
writes each logical shard exactly once). The shard boundaries are whatever
the training layout dictates — the planner never reshards (paper §IV-C).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np


PathShard = Tuple[str, Tuple[Tuple[int, int], ...]]  # (leaf path, shard index)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def normalize_index(index, shape) -> Tuple[Tuple[int, int], ...]:
    """Convert a shard's tuple-of-slices index into ((start, stop), ...)."""
    out = []
    for sl, dim in zip(index, shape):
        start = 0 if sl.start is None else int(sl.start)
        stop = dim if sl.stop is None else int(sl.stop)
        out.append((start, stop))
    return tuple(out)


@dataclasses.dataclass
class ShardRecord:
    """One device shard of one pytree leaf, assigned to an owning rank."""

    leaf_path: str
    tensor_name: str            # unique name within the rank file
    rank: int                   # owning device id
    index: Tuple[Tuple[int, int], ...]
    global_shape: Tuple[int, ...]
    shape: Tuple[int, ...]
    dtype: str
    nbytes: int
    data: Any                   # jax single-device array or numpy array
    device_resident: bool


def _is_array_leaf(leaf) -> bool:
    return isinstance(leaf, (jax.Array, np.ndarray))


def plan_shards(tree, group: str) -> Tuple[List[ShardRecord], Dict[str, Any]]:
    """Flatten ``tree``; return shard records for arrays + dict of host objects.

    Replicated shards are deduplicated to their lowest-device-id owner.
    """
    records: List[ShardRecord] = []
    objects: Dict[str, Any] = {}
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves:
        pstr = f"{group}/{_path_str(path)}"
        if isinstance(leaf, jax.Array):
            seen: Dict[Tuple, int] = {}
            for shard in leaf.addressable_shards:
                idx = normalize_index(shard.index, leaf.shape)
                if idx in seen:
                    continue  # replica; lowest device id wins (sorted below)
                seen[idx] = shard.device.id
            # second pass: keep the lowest-id owner per unique index
            owners: Dict[Tuple, Tuple[int, Any]] = {}
            for shard in leaf.addressable_shards:
                idx = normalize_index(shard.index, leaf.shape)
                cur = owners.get(idx)
                if cur is None or shard.device.id < cur[0]:
                    owners[idx] = (shard.device.id, shard.data)
            for idx, (dev_id, data) in sorted(owners.items()):
                shape = tuple(b - a for a, b in idx)
                dtype = str(leaf.dtype)
                nbytes = int(np.prod(shape)) * np.dtype(dtype).itemsize \
                    if shape else np.dtype(dtype).itemsize
                suffix = ",".join(f"{a}:{b}" for a, b in idx)
                records.append(ShardRecord(
                    leaf_path=pstr,
                    tensor_name=f"{pstr}@[{suffix}]",
                    rank=dev_id, index=idx,
                    global_shape=tuple(leaf.shape),
                    shape=shape, dtype=dtype, nbytes=int(nbytes),
                    data=data, device_resident=True))
        elif isinstance(leaf, np.ndarray):
            idx = tuple((0, d) for d in leaf.shape)
            suffix = ",".join(f"{a}:{b}" for a, b in idx)
            records.append(ShardRecord(
                leaf_path=pstr, tensor_name=f"{pstr}@[{suffix}]",
                rank=0, index=idx, global_shape=tuple(leaf.shape),
                shape=tuple(leaf.shape), dtype=str(leaf.dtype),
                nbytes=int(leaf.nbytes), data=leaf, device_resident=False))
        else:
            objects[pstr] = leaf
    return records, objects


def group_by_rank(records: Sequence[ShardRecord]
                  ) -> Dict[int, List[ShardRecord]]:
    by_rank: Dict[int, List[ShardRecord]] = {}
    for r in records:
        by_rank.setdefault(r.rank, []).append(r)
    return by_rank
