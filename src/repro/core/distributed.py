"""Distributed shard planning: map a sharded pytree onto per-rank files.

Reproduces the checkpoint composition of Fig 1(c,d): every device ("rank")
owns the shards resident on it; replicated shards (pure DP replicas) are
written once each (the dedup invariant), but instead of always electing the
lowest-id owner — which serializes every replicated byte behind rank 0 while
the rest of the replica group idles — ownership is *balanced*: within each
replica group (the set of devices holding identical copies of a shard),
shards are distributed greedily by byte count, largest first, to the
least-loaded member. No device is assigned more than ⌈group bytes / group
size⌉ plus one shard's worth of its group's replicated bytes (the classic
LPT bound), so a multi-writer save drains every rank's I/O lane at once
(ByteCheckpoint's balanced writer assignment). The shard boundaries are
whatever the training layout dictates — the planner never reshards
(paper §IV-C).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np


PathShard = Tuple[str, Tuple[Tuple[int, int], ...]]  # (leaf path, shard index)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def normalize_index(index, shape) -> Tuple[Tuple[int, int], ...]:
    """Convert a shard's tuple-of-slices index into ((start, stop), ...)."""
    out = []
    for sl, dim in zip(index, shape):
        start = 0 if sl.start is None else int(sl.start)
        stop = dim if sl.stop is None else int(sl.stop)
        out.append((start, stop))
    return tuple(out)


@dataclasses.dataclass
class ShardRecord:
    """One device shard of one pytree leaf, assigned to an owning rank.

    ``domain`` is the leaf's state-domain name (the first component of its
    state path — ``"model"`` for ``state/model/...``); ``route`` is the
    :class:`~repro.core.registry.ProviderRoute` resolved by the manager's
    registry at plan time (``None`` → the engine's adaptive default).
    Routes ride the record so every consumer — the single-writer engine
    and each rank lane of a multi-writer coordinator — honors the same
    per-domain provider decision without re-consulting the registry.
    """

    leaf_path: str
    tensor_name: str            # unique name within the rank file
    rank: int                   # owning device id
    index: Tuple[Tuple[int, int], ...]
    global_shape: Tuple[int, ...]
    shape: Tuple[int, ...]
    dtype: str
    nbytes: int
    data: Any                   # jax single-device array or numpy array
    device_resident: bool
    domain: str = "state"
    route: Optional[Any] = None  # ProviderRoute | None


def assign_replica_writers(
        shards: Sequence[Tuple[Any, int, Dict[int, Any]]],
        initial_load: Optional[Dict[int, int]] = None,
) -> Dict[Any, int]:
    """Pick one writer per replicated shard, balanced within replica groups.

    ``shards`` is ``(key, nbytes, {device_id: data})`` per unique shard;
    the returned map is ``key -> owning device id``. Shards sharing the
    same replica group (identical candidate device set) are spread over
    that group greedily by byte count, largest first, onto the
    least-loaded member (ties to the lowest device id) — so within every
    group no device carries more than ⌈group bytes / group size⌉ plus one
    shard of the group's bytes, and each shard gets exactly one writer.

    ``initial_load`` seeds the per-device byte counters (default 0): the
    coordinator's dead-rank reassignment reuses this balance to spread an
    evicted writer's shard slice over *already-loaded* survivors, so the
    extra bytes land on the least-loaded lanes instead of stacking onto
    one.
    """
    by_group: Dict[Tuple[int, ...], List[Tuple[int, Any]]] = {}
    for key, nbytes, replicas in shards:
        by_group.setdefault(tuple(sorted(replicas)), []).append((nbytes, key))
    owners: Dict[Any, int] = {}
    for devices, members in by_group.items():
        load = {d: int((initial_load or {}).get(d, 0)) for d in devices}
        # sort by descending size, then key, for a deterministic plan
        for nbytes, key in sorted(members, key=lambda m: (-m[0], str(m[1]))):
            dev = min(devices, key=lambda d: (load[d], d))
            owners[key] = dev
            load[dev] += nbytes
    return owners


def state_domain(path_str: str, group: str) -> str:
    """State-domain name of a leaf: the first component of its path within
    the tree (``"model"`` for a leaf under ``{"model": ...}``), or the
    group itself for a bare (single-leaf / non-mapping-rooted) tree."""
    head = path_str.split("/", 1)[0]
    return head or group


def plan_shards(tree, group: str, registry=None
                ) -> Tuple[List[ShardRecord], Dict[str, Any]]:
    """Flatten ``tree``; return shard records for arrays + dict of host objects.

    Replicated shards are deduplicated — each unique shard is written
    exactly once — with writers balanced across replica groups by byte
    count (see :func:`assign_replica_writers`).

    With ``registry`` (a
    :class:`~repro.core.registry.StateProviderRegistry`), every leaf —
    tensor shards *and* object leaves — is routed through the ordered
    rules here, at plan time: tensor shards carry their resolved
    :class:`~repro.core.registry.ProviderRoute` on the record (sized per
    *shard*, so byte-threshold rules see what each writer actually
    moves), and object leaves are validated (a strict registry turns an
    unmatched or mis-routed leaf into an error naming its state path
    before any I/O starts).
    """
    records: List[ShardRecord] = []
    objects: Dict[str, Any] = {}
    # (pstr, idx) -> {device_id: shard data}, in traversal order
    replicas: Dict[Tuple[str, Tuple], Dict[int, Any]] = {}
    shapes: Dict[str, Tuple[int, ...]] = {}
    dtypes: Dict[str, str] = {}
    domains: Dict[str, str] = {}
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves:
        p = _path_str(path)
        pstr = f"{group}/{p}"
        domain = state_domain(p, group)
        if isinstance(leaf, jax.Array):
            shapes[pstr] = tuple(leaf.shape)
            dtypes[pstr] = str(leaf.dtype)
            domains[pstr] = domain
            for shard in leaf.addressable_shards:
                idx = normalize_index(shard.index, leaf.shape)
                replicas.setdefault((pstr, idx), {})[shard.device.id] = \
                    shard.data
        elif isinstance(leaf, np.ndarray):
            idx = tuple((0, d) for d in leaf.shape)
            suffix = ",".join(f"{a}:{b}" for a, b in idx)
            route = None
            if registry is not None:
                route = registry.route(
                    domain=domain, path=pstr, dtype=str(leaf.dtype),
                    nbytes=int(leaf.nbytes), kind="tensor")
            records.append(ShardRecord(
                leaf_path=pstr, tensor_name=f"{pstr}@[{suffix}]",
                rank=0, index=idx, global_shape=tuple(leaf.shape),
                shape=tuple(leaf.shape), dtype=str(leaf.dtype),
                nbytes=int(leaf.nbytes), data=leaf, device_resident=False,
                domain=domain, route=route))
        else:
            objects[pstr] = leaf
            if registry is not None:
                # objects always stream through ObjectStateProvider; the
                # routing pass exists for validation — strict registries
                # surface unmatched/mis-routed leaves by state path here
                registry.route(domain=domain, path=pstr, dtype=None,
                               nbytes=None, kind="object")
    if replicas:
        shard_meta = []
        for (pstr, idx), by_dev in replicas.items():
            shape = tuple(b - a for a, b in idx)
            itemsize = np.dtype(dtypes[pstr]).itemsize
            nbytes = int(np.prod(shape)) * itemsize if shape else itemsize
            shard_meta.append(((pstr, idx), int(nbytes), by_dev))
        owners = assign_replica_writers(shard_meta)
        for (pstr, idx), nbytes, by_dev in shard_meta:
            dev_id = owners[(pstr, idx)]
            shape = tuple(b - a for a, b in idx)
            suffix = ",".join(f"{a}:{b}" for a, b in idx)
            route = None
            if registry is not None:
                route = registry.route(
                    domain=domains[pstr], path=pstr, dtype=dtypes[pstr],
                    nbytes=nbytes, kind="tensor")
            records.append(ShardRecord(
                leaf_path=pstr,
                tensor_name=f"{pstr}@[{suffix}]",
                rank=dev_id, index=idx,
                global_shape=shapes[pstr],
                shape=shape, dtype=dtypes[pstr], nbytes=nbytes,
                data=by_dev[dev_id], device_resident=True,
                domain=domains[pstr], route=route))
    return records, objects


def group_by_rank(records: Sequence[ShardRecord]
                  ) -> Dict[int, List[ShardRecord]]:
    by_rank: Dict[int, List[ShardRecord]] = {}
    for r in records:
        by_rank.setdefault(r.rank, []).append(r)
    return by_rank
