"""Tensor chunk codecs for encoded (non-raw) checkpoint payloads.

The engine's flush lanes treat any chunk with ``codec != "raw"`` the same
way: compress the producer-encoded payload and log-append it with explicit
raw-range addressing (``layout.FileWriter.append_encoded_chunk``). What
differs per codec is (a) how the producer turns raw tensor bytes into the
payload and (b) how a reader turns the decompressed payload back into raw
bytes — and, crucially, whether that inversion is *self-contained* or
*chained*:

* **chained** codecs (``xor+zstd`` — differential checkpointing) encode a
  chunk relative to a previous checkpoint's bytes; their payloads only
  have meaning during chain replay (``RestoreEngine.restore_chain``).
* **self-contained** codecs (``int8q+zstd`` — blockwise int8 quantization
  of fp32 state, built on the Pallas kernels in ``kernels/quantize.py``)
  decode standalone, so a quantized tensor restores like any raw tensor,
  including through selective (per-domain) restore.

This module is the single registry both sides consult: providers name a
codec on each :class:`~repro.core.state_provider.Chunk`, and
``layout.FileReader`` / ``core.restore`` dispatch decode through
:func:`decode_chunk_payload` / classify through :func:`is_chained_codec`.

``int8q`` payload layout (before the flush lane's zstd/zlib compression),
covering raw fp32 bytes ``[raw_lo, raw_hi)`` of the tensor:

    u32 n_rows | u32 raw_nbytes | f32 scales[n_rows] | i8 q[n_rows * 256]

Rows are the kernel's native (256-lane) quantization rows: the raw bytes
are viewed as fp32, padded to whole rows, and each row gets a symmetric
per-row scale ``max|x|/127``. Decode dequantizes and truncates the pad.
"""

from __future__ import annotations

import struct
from typing import Callable, Dict

import numpy as np

#: fp32 elements per quantization row (the Pallas kernel's lane width).
INT8_ROW_ELEMS = 256
#: raw bytes per quantization row.
INT8_ROW_BYTES = INT8_ROW_ELEMS * 4
#: the kernel's row-tile granularity (grid dimension), see kernels/quantize.
_KERNEL_ROW_TILE = 256

_INT8_HEADER = struct.Struct("<II")

DELTA_CODEC = "xor+zstd"
INT8_CODEC = "int8q+zstd"


class CodecError(ValueError):
    """A payload failed to decode (corrupt, truncated, or wrong codec)."""


def codec_base(codec: str) -> str:
    """``"int8q+zstd"`` → ``"int8q"`` (strip the host-compression suffix)."""
    return codec.split("+", 1)[0]


def is_chained_codec(codec: str) -> bool:
    """True for codecs whose payloads only decode relative to a chain base
    (differential XOR deltas); such tensors cannot restore standalone."""
    return codec != "raw" and codec_base(codec) == "xor"


# --------------------------------------------------------------------- int8q

def _pad_rows(x: np.ndarray) -> np.ndarray:
    """Pad an (R, 256) fp32 block to the kernel's row-tile multiple."""
    pad = (-x.shape[0]) % _KERNEL_ROW_TILE
    if pad:
        x = np.concatenate([x, np.zeros((pad, INT8_ROW_ELEMS), np.float32)])
    return x


def encode_int8_block(raw: np.ndarray) -> bytes:
    """Quantize one chunk of raw fp32 bytes into an ``int8q`` payload.

    ``raw`` is a uint8 view of the chunk's raw bytes; its length need not
    be a multiple of a row (the tensor tail) — the pad is zeros, which
    quantize exactly and are truncated by :func:`decode_int8_block`.
    """
    from repro.kernels import ops as kops  # deferred: jax import is heavy

    raw = np.ascontiguousarray(raw, dtype=np.uint8)
    raw_nbytes = raw.nbytes
    pad = (-raw_nbytes) % INT8_ROW_BYTES
    if pad:
        raw = np.concatenate([raw, np.zeros(pad, np.uint8)])
    f32 = raw.view(np.float32).reshape(-1, INT8_ROW_ELEMS)
    n_rows = f32.shape[0]
    q, scales = kops.quantize_int8(_pad_rows(f32))
    q = np.asarray(q)[:n_rows]
    scales = np.asarray(scales)[:n_rows]
    return (_INT8_HEADER.pack(n_rows, raw_nbytes)
            + scales.astype(np.float32).tobytes()
            + q.astype(np.int8).tobytes())


def decode_int8_block(payload: bytes, raw_lo: int, raw_hi: int) -> np.ndarray:
    """Inverse of :func:`encode_int8_block`: dequantized raw bytes of
    ``[raw_lo, raw_hi)`` as a fresh uint8 array of length ``raw_hi-raw_lo``.
    Lossy-bounded: each fp32 value is within one quantization step
    (``row max|x| / 127``) of the original."""
    from repro.kernels import ops as kops  # deferred: jax import is heavy

    if len(payload) < _INT8_HEADER.size:
        raise CodecError("int8q payload shorter than its header")
    n_rows, raw_nbytes = _INT8_HEADER.unpack_from(payload)
    if raw_nbytes != raw_hi - raw_lo:
        raise CodecError(
            f"int8q payload declares {raw_nbytes} raw bytes, chunk "
            f"addressing says [{raw_lo}:{raw_hi}) — corrupt payload")
    want = _INT8_HEADER.size + n_rows * 4 + n_rows * INT8_ROW_ELEMS
    if len(payload) != want:
        raise CodecError(
            f"int8q payload is {len(payload)} B, expected {want} B for "
            f"{n_rows} rows — truncated or corrupt")
    off = _INT8_HEADER.size
    scales = np.frombuffer(payload, np.float32, n_rows, off).reshape(-1, 1)
    q = np.frombuffer(payload, np.int8, n_rows * INT8_ROW_ELEMS,
                      off + n_rows * 4).reshape(-1, INT8_ROW_ELEMS)
    pad = (-n_rows) % _KERNEL_ROW_TILE
    if pad:
        q = np.concatenate([q, np.zeros((pad, INT8_ROW_ELEMS), np.int8)])
        scales = np.concatenate([scales, np.ones((pad, 1), np.float32)])
    deq = np.asarray(kops.dequantize_int8(q, scales))[:n_rows]
    out = deq.astype(np.float32).reshape(-1).view(np.uint8)
    return np.array(out[:raw_nbytes])


# ------------------------------------------------------------------ registry

#: self-contained decoders: codec base → fn(payload, raw_lo, raw_hi) → u8.
_DECODERS: Dict[str, Callable[[bytes, int, int], np.ndarray]] = {
    "int8q": decode_int8_block,
}


def decode_chunk_payload(codec: str, payload: bytes,
                         raw_lo: int, raw_hi: int) -> np.ndarray:
    """Decode one decompressed encoded-chunk payload back to raw bytes.

    Only valid for self-contained codecs; chained codecs (XOR deltas) must
    go through chain replay instead."""
    if is_chained_codec(codec):
        raise CodecError(
            f"codec {codec!r} is chained (differential) — its payloads "
            f"only decode during chain replay, not standalone")
    fn = _DECODERS.get(codec_base(codec))
    if fn is None:
        raise CodecError(f"unknown tensor chunk codec {codec!r}")
    return fn(payload, raw_lo, raw_hi)
