"""Tensor chunk codecs for encoded (non-raw) checkpoint payloads.

The engine's flush lanes treat any chunk with ``codec != "raw"`` the same
way: compress the producer-encoded payload and log-append it with explicit
raw-range addressing (``layout.FileWriter.append_encoded_chunk``). What
differs per codec is (a) how the producer turns raw tensor bytes into the
payload and (b) how a reader turns the decompressed payload back into raw
bytes — and, crucially, whether that inversion is *self-contained* or
*chained*:

* **chained** codecs (``xor+zstd`` — differential checkpointing) encode a
  chunk relative to a previous checkpoint's bytes; their payloads only
  have meaning during chain replay (``RestoreEngine.restore_chain``).
* **self-contained** codecs (``int8q+zstd`` — blockwise int8 quantization
  of fp32 state, built on the Pallas kernels in ``kernels/quantize.py``)
  decode standalone, so a quantized tensor restores like any raw tensor,
  including through selective (per-domain) restore.

This module is the single registry both sides consult: providers name a
codec on each :class:`~repro.core.state_provider.Chunk`, and
``layout.FileReader`` / ``core.restore`` dispatch decode through
:func:`decode_chunk_payload` / classify through :func:`is_chained_codec`.

Encode is **one-pass** (``kernels/fused.py``): each route's encoder returns
``(payload, digest)`` from a single read of the staged bytes — the digest is
the position-weighted u32 checksum of the uncompressed payload, stored per
chunk in the file footer and re-verified on decode. On a real TPU the fused
Pallas kernels produce payload + digest in one kernel invocation; without
one, the bit-identical NumPy oracles in ``kernels/ref.py`` run instead
(interpret-mode Pallas is a correctness harness, ~20 MB/s). Digests are
skipped (``None``) when the save runs with manifest checksums disabled.

``int8q`` payload layout (before the flush lane's zstd/zlib compression),
covering raw fp32 bytes ``[raw_lo, raw_hi)`` of the tensor:

    u32 n_rows | u32 raw_nbytes | f32 scales[n_rows] | i8 q[n_rows * 256]

Rows are the kernel's native (256-lane) quantization rows: the raw bytes
are viewed as fp32, padded to whole rows, and each row gets a symmetric
per-row scale ``max|x|/127``. Decode dequantizes and truncates the pad.
"""

from __future__ import annotations

import struct
from typing import Callable, Dict

import numpy as np

#: fp32 elements per quantization row (the Pallas kernel's lane width).
INT8_ROW_ELEMS = 256
#: raw bytes per quantization row.
INT8_ROW_BYTES = INT8_ROW_ELEMS * 4
#: the kernel's row-tile granularity (grid dimension), see kernels/quantize.
_KERNEL_ROW_TILE = 256

_INT8_HEADER = struct.Struct("<II")

DELTA_CODEC = "xor+zstd"
INT8_CODEC = "int8q+zstd"


class CodecError(ValueError):
    """A payload failed to decode (corrupt, truncated, or wrong codec)."""


def codec_base(codec: str) -> str:
    """``"int8q+zstd"`` → ``"int8q"`` (strip the host-compression suffix)."""
    return codec.split("+", 1)[0]


def is_chained_codec(codec: str) -> bool:
    """True for codecs whose payloads only decode relative to a chain base
    (differential XOR deltas); such tensors cannot restore standalone."""
    return codec != "raw" and codec_base(codec) == "xor"


# ------------------------------------------------------------ chunk digests

def _header_digest(n_rows: int, raw_nbytes: int) -> int:
    """Digest contribution of the two ``int8q`` header words (idx 0 and 1)."""
    from repro.kernels.checksum import WEIGHT_BASE
    return (n_rows * WEIGHT_BASE + raw_nbytes * (WEIGHT_BASE + 1)) \
        & 0xFFFFFFFF


def payload_digest(payload) -> int:
    """Position-weighted u32 digest of an uncompressed payload's bytes.

    The read-side oracle: every fused encoder's digest equals this function
    over the payload it emitted (``tests/test_fused_kernels.py`` is the
    proof)."""
    from repro.kernels import ref as kref

    return kref.checksum_np_bytes(payload)


def int8_encoded_nbytes(raw_nbytes: int) -> int:
    """Exact ``int8q`` payload size for a chunk of ``raw_nbytes`` — known
    *before* encoding, so the encode budget can reserve the encoded
    footprint up front (once per chunk, not once per pass)."""
    n_rows = -(-raw_nbytes // INT8_ROW_BYTES)
    return _INT8_HEADER.size + n_rows * 4 + n_rows * INT8_ROW_ELEMS


# --------------------------------------------------------------------- int8q

def _pad_rows(x: np.ndarray) -> np.ndarray:
    """Pad an (R, 256) fp32 block to the kernel's row-tile multiple."""
    pad = (-x.shape[0]) % _KERNEL_ROW_TILE
    if pad:
        x = np.concatenate([x, np.zeros((pad, INT8_ROW_ELEMS), np.float32)])
    return x


def encode_int8_block(raw: np.ndarray, with_digest: bool = False):
    """Quantize one chunk of raw fp32 bytes into an ``int8q`` payload.

    One fused pass: returns ``(payload, digest)`` where ``digest`` is the
    checksum of the packed payload (or ``None`` when ``with_digest`` is
    off). ``raw`` is a uint8 view of the chunk's raw bytes; its length need
    not be a multiple of a row (the tensor tail) — the pad is zeros, which
    quantize exactly and are truncated by :func:`decode_int8_block`.
    """
    from repro.kernels import ops as kops  # deferred: jax import is heavy
    from repro.kernels import ref as kref

    raw = np.ascontiguousarray(raw, dtype=np.uint8)
    raw_nbytes = raw.nbytes
    pad = (-raw_nbytes) % INT8_ROW_BYTES
    if pad:
        raw = np.concatenate([raw, np.zeros(pad, np.uint8)])
    f32 = raw.view(np.float32).reshape(-1, INT8_ROW_ELEMS)
    n_rows = f32.shape[0]
    digest = None
    if kops.host_fastpath():
        if with_digest:
            q, scales, area = kref.fused_quantize_checksum_ref(
                _pad_rows(f32), n_rows)
            digest = (_header_digest(n_rows, raw_nbytes) + area) & 0xFFFFFFFF
        else:
            q, scales = kref.quantize_int8_ref(_pad_rows(f32))
    else:
        q, scales, area = kops.fused_quantize_int8(_pad_rows(f32), n_rows)
        if with_digest:
            digest = (_header_digest(n_rows, raw_nbytes) + int(area)) \
                & 0xFFFFFFFF
    q = np.asarray(q)[:n_rows]
    scales = np.asarray(scales)[:n_rows]
    payload = (_INT8_HEADER.pack(n_rows, raw_nbytes)
               + scales.astype(np.float32).tobytes()
               + q.astype(np.int8).tobytes())
    return payload, digest


def decode_int8_block(payload: bytes, raw_lo: int, raw_hi: int,
                      expect_digest=None) -> np.ndarray:
    """Inverse of :func:`encode_int8_block`: dequantized raw bytes of
    ``[raw_lo, raw_hi)`` as a fresh uint8 array of length ``raw_hi-raw_lo``.
    Lossy-bounded: each fp32 value is within one quantization step
    (``row max|x| / 127``) of the original. With ``expect_digest`` the
    payload is integrity-verified while decoding (fused on TPU)."""
    from repro.kernels import ops as kops  # deferred: jax import is heavy
    from repro.kernels import ref as kref

    if len(payload) < _INT8_HEADER.size:
        raise CodecError("int8q payload shorter than its header")
    n_rows, raw_nbytes = _INT8_HEADER.unpack_from(payload)
    if raw_nbytes != raw_hi - raw_lo:
        raise CodecError(
            f"int8q payload declares {raw_nbytes} raw bytes, chunk "
            f"addressing says [{raw_lo}:{raw_hi}) — corrupt payload")
    want = _INT8_HEADER.size + n_rows * 4 + n_rows * INT8_ROW_ELEMS
    if len(payload) != want:
        raise CodecError(
            f"int8q payload is {len(payload)} B, expected {want} B for "
            f"{n_rows} rows — truncated or corrupt")
    off = _INT8_HEADER.size
    scales = np.frombuffer(payload, np.float32, n_rows, off).reshape(-1, 1)
    q = np.frombuffer(payload, np.int8, n_rows * INT8_ROW_ELEMS,
                      off + n_rows * 4).reshape(-1, INT8_ROW_ELEMS)
    pad = (-n_rows) % _KERNEL_ROW_TILE
    qp, sp = q, scales
    if pad:
        qp = np.concatenate([q, np.zeros((pad, INT8_ROW_ELEMS), np.int8)])
        sp = np.concatenate([scales, np.ones((pad, 1), np.float32)])
    if kops.host_fastpath():
        if expect_digest is not None:
            got = (_header_digest(n_rows, raw_nbytes)
                   + kref.int8_payload_digest_ref(q, scales, n_rows)) \
                & 0xFFFFFFFF
            if got != expect_digest:
                raise CodecError(
                    f"int8q payload digest mismatch: stored "
                    f"{expect_digest:#010x}, decoded {got:#010x} — "
                    f"corrupt chunk")
        # q(int8) -> f32 multiply is exactly rounded: bit-identical to the
        # dequantize kernel on any backend
        deq = qp.astype(np.float32)[:n_rows] * scales
    else:
        deq, area = kops.fused_dequantize_int8(qp, sp, n_rows)
        if expect_digest is not None:
            got = (_header_digest(n_rows, raw_nbytes) + int(area)) \
                & 0xFFFFFFFF
            if got != expect_digest:
                raise CodecError(
                    f"int8q payload digest mismatch: stored "
                    f"{expect_digest:#010x}, decoded {got:#010x} — "
                    f"corrupt chunk")
        deq = np.asarray(deq)[:n_rows]
    out = deq.astype(np.float32).reshape(-1).view(np.uint8)
    return np.array(out[:raw_nbytes])


# --------------------------------------------------------------------- delta

def _u32_words(b: np.ndarray) -> np.ndarray:
    """Flat u32 view of a byte array (zero-padded tail, alignment-safe)."""
    b = b.reshape(-1).view(np.uint8)
    pad = (-b.size) % 4
    if pad:
        b = np.concatenate([b, np.zeros(pad, np.uint8)])
    if not b.flags["C_CONTIGUOUS"] or b.ctypes.data % 4:
        b = b.copy()
    return b.view(np.uint32)


def encode_delta_chunk(cur: np.ndarray, prev: np.ndarray,
                       with_digest: bool = False):
    """XOR-delta one chunk: ``(delta_bytes_u8, digest|None)`` in one pass.

    ``cur`` (the staged bytes) is read exactly once; the digest covers the
    delta payload as stored (computed from the XOR output, which on TPU
    never leaves the kernel's VMEM tile)."""
    from repro.kernels import ops as kops  # deferred: jax import is heavy
    from repro.kernels import ref as kref

    nbytes = cur.nbytes
    digest = None
    if kops.host_fastpath():
        if with_digest:
            delta, digest = kref.fused_xor_checksum_ref(
                _u32_words(cur), _u32_words(prev))
        else:
            delta = np.bitwise_xor(_u32_words(cur), _u32_words(prev))
    else:
        delta, dig = kops.fused_xor_checksum(cur, prev)
        delta = np.asarray(delta)
        if with_digest:
            digest = int(dig)
    return delta.view(np.uint8)[:nbytes], digest


# ------------------------------------------------------------------ registry

#: self-contained decoders:
#: codec base → fn(payload, raw_lo, raw_hi, expect_digest) → u8.
_DECODERS: Dict[str, Callable[..., np.ndarray]] = {
    "int8q": decode_int8_block,
}


def decode_chunk_payload(codec: str, payload: bytes,
                         raw_lo: int, raw_hi: int,
                         expect_digest=None) -> np.ndarray:
    """Decode one decompressed encoded-chunk payload back to raw bytes.

    Only valid for self-contained codecs; chained codecs (XOR deltas) must
    go through chain replay instead. ``expect_digest`` (from the footer's
    per-chunk record) makes the decode integrity-verifying."""
    if is_chained_codec(codec):
        raise CodecError(
            f"codec {codec!r} is chained (differential) — its payloads "
            f"only decode during chain replay, not standalone")
    fn = _DECODERS.get(codec_base(codec))
    if fn is None:
        raise CodecError(f"unknown tensor chunk codec {codec!r}")
    return fn(payload, raw_lo, raw_hi, expect_digest)
