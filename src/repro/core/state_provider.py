"""Composable state providers (paper §V-A3).

A *state provider* (SP) encapsulates per-data-structure knowledge — residency
(device vs. host), type (byte-addressable tensor vs. Python object), layout,
and (de)serialization needs — and exposes a uniform, stream-oriented view to
the data-movement engine: an iterator of :class:`Chunk` byte ranges. The
engine stays agnostic to heterogeneity and only optimizes multi-tier I/O.

* :class:`TensorStateProvider` — zero-copy. Host-resident tensors stream
  memoryviews of their own buffers; device-resident tensors stream views of
  their staged copy in the pinned :class:`~repro.core.host_cache.HostCache`
  reservation, chunk by chunk as D2H staging progresses (so flushing of a
  tensor overlaps with staging of its own tail — paper §V-A4 / Fig 15).
* :class:`ObjectStateProvider` — serializes Python objects (pickle/msgpack)
  lazily at stream time; its chunks carry no fixed offset and are appended
  log-structured (paper §V-A5).
* :class:`CompositeStateProvider` — hierarchical composition: plans the
  fixed-offset tensor region for one file, orders the stream tensors-first
  (largest first) so object serialization overlaps with bulk tensor I/O.
* :class:`QuantizedStateProvider` — blockwise int8 quantization of fp32
  state on the Pallas kernels (self-contained ``int8q+zstd`` payloads, so
  quantized tensors restore standalone — see :mod:`repro.core.codecs`).
* :class:`DeltaStateProvider` — differential checkpointing on the main
  engine path (paper §VII / ByteCheckpoint): XOR-deltas each staged chunk
  against a retained previous-snapshot copy held in a
  :class:`SnapshotCache` (inside the same pinned host-cache budget), and
  emits ``codec="xor+zstd"`` chunks that the engine's flush lanes compress
  and log-append. Keyframe saves stream raw (fixed-offset) chunks while
  refreshing the snapshot cache, so the chain can restart at any time.
"""

from __future__ import annotations

import dataclasses
import pickle
import threading
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, \
    Tuple

import msgpack
import numpy as np

from repro.analysis.locks import declares_lock
from repro.obs import trace as obs
from repro.obs.metrics import metrics as obs_metrics

from .codecs import (DELTA_CODEC, INT8_CODEC, INT8_ROW_BYTES,
                     encode_delta_chunk, encode_int8_block,
                     int8_encoded_nbytes, payload_digest)
from .host_cache import HostCache, Reservation
from .layout import FileLayout, align_up

DEFAULT_CHUNK_BYTES = 16 * 1024 * 1024


@dataclasses.dataclass
class Chunk:
    """One byte range to persist. ``offset is None`` → log-append."""

    name: str
    kind: str                      # "tensor" | "object"
    data: Any                      # memoryview | bytes
    offset: Optional[int] = None   # fixed file offset; None = append
    codec: str = "raw"
    last: bool = False             # last chunk of this logical item
    # For encoded (``codec != "raw"``) tensor chunks: which byte range of
    # the *raw* tensor this chunk encodes — the flush lane compresses the
    # payload, so raw addressing must travel with the chunk.
    raw_range: Optional[Tuple[int, int]] = None
    # Integrity digest of the (uncompressed) encoded payload, emitted by
    # the fused encoder in the same pass that produced ``data``; recorded
    # per chunk in the file footer. None when checksums are off.
    digest: Optional[int] = None
    # Invoked by the flush lane once this chunk's payload is written (or
    # its write failed) — encoded chunks use it to credit the producer's
    # in-flight byte budget.
    on_flushed: Optional[Callable[[], None]] = None


@declares_lock("encode.budget", rank=56, attrs=("_cond",))
class EncodeBudget:
    """Caps the bytes of freshly-allocated encoded (XOR) payloads queued
    between producer and flush lanes.

    Raw-path chunks are zero-copy views into budgeted cache reservations,
    but delta chunks are fresh heap arrays: an unbounded flush queue would
    transiently hold ~one full uncompressed state copy outside the pinned
    host-cache budget (producers XOR at memcpy speed, flush lanes drain at
    compress+disk speed). Producers acquire before allocating; the flush
    lane credits back after the write — always, including error paths, so
    a failed save cannot starve the producer. A single over-cap request is
    admitted when nothing is in flight, so the cap never deadlocks.
    """

    def __init__(self, cap_bytes: int):
        self.cap = int(cap_bytes)
        self._used = 0
        self._cond = threading.Condition()

    def acquire(self, nbytes: int) -> None:
        with self._cond:
            while self._used > 0 and self._used + nbytes > self.cap:
                self._cond.wait(timeout=60.0)
            self._used += nbytes

    def release(self, nbytes: int) -> None:
        with self._cond:
            self._used -= nbytes
            self._cond.notify_all()


@dataclasses.dataclass(frozen=True)
class DeltaSaveSpec:
    """One save's position in a delta chain (decided by the manager).

    ``keyframe=True`` → stream full raw tensors (and refresh the snapshot
    cache); ``keyframe=False`` → stream XOR deltas against the snapshot
    cache, with ``base_step`` naming the previous save in the chain and
    ``chain_depth`` counting hops back to the keyframe (keyframe = 0).
    """

    step: int
    keyframe: bool
    base_step: Optional[int] = None
    chain_depth: int = 0
    codec: str = DELTA_CODEC

    def manifest_meta(self) -> Dict[str, Any]:
        return {"keyframe": self.keyframe, "base_step": self.base_step,
                "chain_depth": self.chain_depth, "codec": self.codec}


@declares_lock("snapshot.cache", rank=54, attrs=("_lock",))
class SnapshotCache:
    """Per-engine retained previous-snapshot copies, one per tensor name.

    Entries live inside the engine's pinned :class:`HostCache`, so the
    snapshot budget and the staging budget share one back-pressure pool
    (the cache must hold previous-version + in-flight-version bytes for a
    delta save — checked up front by the engine). Thread-safe for the
    per-name access pattern the engine uses (consecutive saves are gated,
    so no two saves mutate the same entry concurrently).
    """

    def __init__(self, cache: HostCache, reserve_timeout_s: float = 60.0):
        self._cache = cache
        self._timeout = reserve_timeout_s
        self._lock = threading.Lock()
        self._entries: Dict[str, Reservation] = {}

    def names(self) -> List[str]:
        with self._lock:
            return list(self._entries)

    def nbytes(self) -> int:
        with self._lock:
            return sum(r.nbytes for r in self._entries.values())

    def view(self, name: str) -> Optional[memoryview]:
        with self._lock:
            res = self._entries.get(name)
        return None if res is None else res.view

    def ensure(self, name: str, nbytes: int) -> memoryview:
        """Reservation for ``name`` sized ``nbytes`` (re-reserved on size
        change). Raises :class:`~.host_cache.CacheFullError` rather than
        deadlocking when the pool cannot hold it."""
        with self._lock:
            res = self._entries.get(name)
            if res is not None and res.nbytes == nbytes:
                return res.view
            if res is not None:
                del self._entries[name]
        if res is not None:
            res.release()
        res = self._cache.reserve(nbytes, timeout=self._timeout)
        with self._lock:
            self._entries[name] = res
        return res.view

    def retain_only(self, names: Sequence[str]) -> None:
        """Drop entries for tensors no longer in the shard set (elastic
        reshard forced a keyframe with a new name set)."""
        keep = set(names)
        with self._lock:
            doomed = [(n, r) for n, r in self._entries.items()
                      if n not in keep]
            for n, _r in doomed:
                del self._entries[n]
        for _n, r in doomed:
            r.release()

    def clear(self) -> None:
        self.retain_only(())


class StateProvider:
    """Base: a named producer of checkpoint chunks."""

    name: str

    def chunks(self) -> Iterator[Chunk]:
        raise NotImplementedError

    def nbytes_hint(self) -> Optional[int]:
        """Size if known a priori (tensors), else None (serialized objects)."""
        return None


@declares_lock("provider.stage", rank=58, attrs=("_cond",))
class TensorStateProvider(StateProvider):
    """Zero-copy SP for a byte-addressable tensor (host or device resident).

    For device arrays, :meth:`bind_reservation` attaches the pinned-cache
    reservation and :meth:`notify_staged` is called by the staging thread as
    bytes land; :meth:`chunks` yields each chunk as soon as its bytes are
    staged, enabling flush/staging overlap within a single large tensor.
    """

    def __init__(self, name: str, *, dtype: str, shape: Tuple[int, ...],
                 nbytes: int,
                 host_array: Optional[np.ndarray] = None,
                 global_shape: Optional[Tuple[int, ...]] = None,
                 index: Optional[Tuple[Tuple[int, int], ...]] = None,
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 stream_intra_tensor: bool = True):
        self.name = name
        self.dtype = dtype
        self.shape = tuple(shape)
        self.nbytes = int(nbytes)
        self.global_shape = global_shape
        self.index = index
        self.chunk_bytes = chunk_bytes
        # False = legacy engines: flush only once the whole tensor is staged.
        self.stream_intra_tensor = stream_intra_tensor
        self.offset: Optional[int] = None  # assigned by composite layout plan
        # host-resident path
        self._host_array = host_array
        # device-resident path
        self._reservation: Optional[Reservation] = None
        self._staged = 0
        self._cond = threading.Condition()
        self._released = False
        # Set by the engine when the save runs with manifest checksums:
        # raw chunks then carry a per-chunk digest of their bytes,
        # recorded in the file footer so verify can localize a flipped
        # chunk inside a keyframe/raw tensor — not just fail the whole
        # file. Encoded providers override the digest with their fused
        # encoder's output instead.
        self.checksum_chunks: bool = False

    # -- residency wiring ----------------------------------------------------
    @property
    def device_resident(self) -> bool:
        return self._host_array is None

    def bind_reservation(self, res: Reservation) -> None:
        self._reservation = res

    @property
    def reservation(self) -> Optional[Reservation]:
        return self._reservation

    def notify_staged(self, nbytes_total: int) -> None:
        """Staging thread reports cumulative bytes landed in the cache."""
        with self._cond:
            self._staged = nbytes_total
            self._cond.notify_all()

    def release(self) -> None:
        """Free the cache reservation once all chunks are flushed."""
        with self._cond:
            if self._released:
                return
            self._released = True
        if self._reservation is not None:
            self._reservation.release()

    # -- StateProvider -------------------------------------------------------
    def nbytes_hint(self) -> Optional[int]:
        return self.nbytes

    def _byte_view(self) -> memoryview:
        if self._host_array is not None:
            arr = np.ascontiguousarray(self._host_array)
            return memoryview(arr).cast("B")
        assert self._reservation is not None, (
            f"device tensor {self.name} streamed before staging was bound")
        return self._reservation.view

    def chunks(self) -> Iterator[Chunk]:
        view = self._byte_view()
        n = self.nbytes
        pos = 0
        while pos < n:
            end = min(pos + self.chunk_bytes, n)
            if self._host_array is None:
                # Wait until staging has landed these bytes (partial-tensor
                # overlap: flush the head while the tail is still in DMA).
                with self._cond:
                    while self._staged < end:
                        self._cond.wait()
            yield Chunk(name=self.name, kind="tensor", data=view[pos:end],
                        offset=self.offset + pos if self.offset is not None else None,
                        raw_range=(pos, end), last=end >= n,
                        digest=self._raw_digest(view[pos:end]))
            pos = end

    def _raw_digest(self, data) -> Optional[int]:
        """Per-chunk digest of a raw chunk's bytes while they are hot from
        the staging copy. Deliberately *not* counted against
        ``engine.bytes_encode_read`` — that counter is the encoded routes'
        single-read-of-staged-bytes equality and raw chunks never encode."""
        if not self.checksum_chunks:
            return None
        with obs.span("encode.digest", tensor=self.name, bytes=len(data)):
            return payload_digest(np.frombuffer(data, dtype=np.uint8))


def xor_bytes(cur: np.ndarray, prev: np.ndarray) -> np.ndarray:
    """Bit-exact XOR of two equal-length byte arrays; returns a fresh
    uint8 array. Pallas delta kernel (``kernels/delta.py``) on TPU, NumPy
    on host — XOR has one right answer, so the paths are trivially
    bit-identical (and the differential suite checks anyway)."""
    from repro.kernels import ops as kops  # deferred: jax import is heavy
    if kops.host_fastpath():
        return np.bitwise_xor(np.asarray(cur).view(np.uint8),
                              np.asarray(prev).view(np.uint8))
    out = np.asarray(kops.delta_xor(cur, prev)).view(np.uint8)
    return out[:cur.nbytes]


class DeltaStateProvider(TensorStateProvider):
    """Differential SP: streams XOR deltas against the previous snapshot.

    Two modes, chosen per save by the manager's chain tracker
    (:class:`DeltaSaveSpec`):

    * **keyframe** — behaves like :class:`TensorStateProvider` (raw chunks
      at fixed offsets) but additionally copies each staged chunk into the
      engine's :class:`SnapshotCache`, re-arming the chain;
    * **delta** — each staged chunk is XORed against the retained snapshot
      bytes (kernel-backed), the snapshot entry is advanced to the current
      bytes, and the XOR payload is emitted as a ``codec="xor+zstd"``
      log-append chunk (``offset=None`` — encoded tensors never occupy the
      fixed region, so bytes-on-disk shrink with the delta). Compression
      happens downstream on the engine's flush lanes, keeping capture and
      producer latency flat.

    XOR is associative and order-insensitive, so restore may fold a chain
    of deltas onto the keyframe in any order (``RestoreEngine.restore_chain``).
    """

    def __init__(self, name: str, *, prev: memoryview, keyframe: bool,
                 codec: str = DELTA_CODEC, **kw):
        super().__init__(name, **kw)
        self.keyframe = keyframe
        self.delta_codec = codec
        self.enc_codec = codec  # uniform encoded-provider attribute
        self._prev = prev
        # set by the engine: fired exactly once when this provider's chunk
        # stream ends (exhausted, closed, or abandoned by a failed
        # producer) — the signal that its snapshot-cache entry is settled
        # and the next save may start streaming.
        self.on_stream_end: Optional[Callable[[], None]] = None
        # Set by the engine to the save's `captured` event: streaming (and
        # with it every producer-lane memcpy/XOR) is deferred until the
        # device is fully drained, so the D2H staging lane never contends
        # with encode work for the GIL — capture latency (the metric that
        # blocks training) stays identical to the raw path; the XOR +
        # compress pipeline runs in the shadow of the next iteration.
        # Applied to keyframe mode too, deliberately: the keyframe's
        # snapshot-cache refresh is a producer-lane memcpy that measurably
        # (~2×) inflated capture when overlapped with staging; trading
        # async persist tail for zero training stall is the right side of
        # that bargain.
        self.capture_gate: Optional[threading.Event] = None
        # Set by the engine: bounds in-flight freshly-allocated XOR
        # payload bytes between producer and flush lanes.
        self.encode_budget: Optional[EncodeBudget] = None
        # checksum_chunks (inherited) additionally makes the fused encoder
        # emit a per-chunk payload digest in the same pass as the delta.
        assert len(prev) == self.nbytes, (
            f"snapshot cache entry for {name} is {len(prev)} B, "
            f"tensor is {self.nbytes} B")

    @property
    def fixed_offset(self) -> bool:
        """Keyframes live in the planned fixed-offset region; deltas are
        compressed downstream and log-appended."""
        return self.keyframe

    def _signal_stream_end(self) -> None:
        cb, self.on_stream_end = self.on_stream_end, None
        if cb is not None:
            cb()

    def chunks(self) -> Iterator[Chunk]:
        try:
            if self.capture_gate is not None:
                self.capture_gate.wait()
            view = self._byte_view()
            prev = np.frombuffer(self._prev, dtype=np.uint8)
            n = self.nbytes
            pos = 0
            while pos < n:
                end = min(pos + self.chunk_bytes, n)
                if self._host_array is None:
                    with self._cond:
                        while self._staged < end:
                            self._cond.wait()
                cur = np.frombuffer(view[pos:end], dtype=np.uint8)
                if self.keyframe:
                    # refresh the snapshot, stream the raw bytes; the
                    # per-chunk digest rides the same pass while the bytes
                    # are hot from the snapshot memcpy, closing the
                    # keyframe half of the verify-localization story
                    prev[pos:end] = cur
                    yield Chunk(name=self.name, kind="tensor",
                                data=view[pos:end],
                                offset=self.offset + pos
                                if self.offset is not None else None,
                                raw_range=(pos, end), last=end >= n,
                                digest=self._raw_digest(view[pos:end]))
                else:
                    nb = end - pos
                    budget = self.encode_budget
                    on_flushed = None
                    if budget is not None:
                        budget.acquire(nb)
                        on_flushed = (lambda b=budget, nb=nb: b.release(nb))
                    try:
                        with obs.span("encode.delta", tensor=self.name,
                                      bytes=nb, fused=True):
                            base = prev[pos:end]
                            delta, digest = encode_delta_chunk(
                                cur, base, with_digest=self.checksum_chunks)
                            # advance the chain base without touching the
                            # staged bytes again: base ^ delta == cur bit-
                            # exactly, and delta is already in cache — the
                            # fused pass above is the chunk's only read of
                            # cur
                            np.bitwise_xor(base, delta, out=base)
                            obs_metrics.inc("engine.bytes_encode_read", nb)
                    except BaseException:
                        # the chunk will never reach a flush lane, so
                        # nobody else can credit the budget back — a leak
                        # here would shrink every later save's headroom
                        if budget is not None:
                            budget.release(nb)
                        raise
                    yield Chunk(name=self.name, kind="tensor", data=delta,
                                offset=None, codec=self.delta_codec,
                                raw_range=(pos, end), last=end >= n,
                                digest=digest, on_flushed=on_flushed)
                pos = end
        finally:
            self._signal_stream_end()


class QuantizedStateProvider(TensorStateProvider):
    """Compressed SP: blockwise int8 quantization of fp32 state (4×).

    Built on the Pallas quantize kernels (``kernels/quantize.py``) via
    :func:`~repro.core.codecs.encode_int8_block`: each staged chunk is cut
    on quantization-row boundaries, quantized with per-row symmetric
    scales, and emitted as a self-contained ``codec="int8q+zstd"``
    log-append payload that the engine's flush lanes compress — like the
    delta path, encoded tensors never occupy the fixed region, so bytes
    on disk shrink to ~¼ + scales. Unlike the delta path the payloads are
    **self-contained** (no chain base), so a quantized tensor restores
    standalone — including through selective per-domain restore — at
    bounded loss (one quantization step per value).

    The natural routing target is optimizer moments
    (``ProviderRule(domain="optimizer", dtype="float32",
    provider="quantized")``) while params stay raw or delta-encoded —
    the registry's dtype predicate keeps non-fp32 leaves (step counters,
    int state) away from this provider; routing one here is a hard error
    at construction, not silent corruption.
    """

    def __init__(self, name: str, *, codec: str = INT8_CODEC, **kw):
        super().__init__(name, **kw)
        if np.dtype(self.dtype) != np.float32:
            raise ValueError(
                f"QuantizedStateProvider requires float32 state; "
                f"{name!r} is {self.dtype} — scope the registry rule "
                f"with dtype='float32'")
        self.enc_codec = codec
        # chunk boundaries must land on whole quantization rows so every
        # payload decodes independently
        self.chunk_bytes = max(
            INT8_ROW_BYTES,
            self.chunk_bytes - self.chunk_bytes % INT8_ROW_BYTES)
        # same engine wiring as DeltaStateProvider: encode work (a Pallas
        # kernel call per chunk) is deferred behind the save's captured
        # event so the D2H staging lane never contends with it, and fresh
        # payload allocations are bounded by the engine's encode budget.
        self.capture_gate: Optional[threading.Event] = None
        self.encode_budget: Optional[EncodeBudget] = None
        # checksum_chunks (inherited): fused per-chunk payload digests,
        # enabled by the engine when the save runs with manifest checksums

    @property
    def fixed_offset(self) -> bool:
        return False

    def chunks(self) -> Iterator[Chunk]:
        if self.capture_gate is not None:
            self.capture_gate.wait()
        view = self._byte_view()
        n = self.nbytes
        pos = 0
        while pos < n:
            end = min(pos + self.chunk_bytes, n)
            if self._host_array is None:
                with self._cond:
                    while self._staged < end:
                        self._cond.wait()
            raw = np.frombuffer(view[pos:end], dtype=np.uint8)
            # the int8q payload size is known a priori, so the encoded
            # footprint is reserved *before* the encode allocates it —
            # exactly once per chunk, not once per pass
            enc_nb = int8_encoded_nbytes(end - pos)
            budget = self.encode_budget
            on_flushed = None
            if budget is not None:
                budget.acquire(enc_nb)
                on_flushed = (lambda b=budget, nb=enc_nb: b.release(nb))
            try:
                with obs.span("encode.int8", tensor=self.name,
                              bytes=end - pos, fused=True):
                    payload, digest = encode_int8_block(
                        raw, with_digest=self.checksum_chunks)
                    obs_metrics.inc("engine.bytes_encode_read", end - pos)
            except BaseException:
                # see DeltaStateProvider: un-yielded chunks must credit
                # their own reservation back on the way out
                if budget is not None:
                    budget.release(enc_nb)
                raise
            assert len(payload) == enc_nb
            yield Chunk(name=self.name, kind="tensor", data=payload,
                        offset=None, codec=self.enc_codec,
                        raw_range=(pos, end), last=end >= n,
                        digest=digest, on_flushed=on_flushed)
            pos = end


class ObjectStateProvider(StateProvider):
    """SP for non-tensor Python state (dicts, RNG seeds, config, ...).

    Serialization happens lazily inside :meth:`chunks` — i.e. on the engine's
    producer thread, *after* tensor chunks have been enqueued — so it overlaps
    with bulk tensor I/O instead of blocking the training loop (§V-A5).
    """

    def __init__(self, name: str, obj: Any, codec: str = "pickle",
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 preserialized: Optional[bytes] = None):
        self.name = name
        self._obj = obj
        self.codec = codec
        self.chunk_bytes = chunk_bytes
        self._preserialized = preserialized
        self.serialized_nbytes: Optional[int] = (
            len(preserialized) if preserialized is not None else None)

    def serialize(self) -> bytes:
        if self._preserialized is not None:  # legacy blocking-upfront engines
            return self._preserialized
        if self.codec == "pickle":
            payload = pickle.dumps(self._obj, protocol=pickle.HIGHEST_PROTOCOL)
        elif self.codec == "msgpack":
            payload = msgpack.packb(self._obj, use_bin_type=True)
        else:
            raise ValueError(f"unknown codec {self.codec}")
        self.serialized_nbytes = len(payload)
        return payload

    def chunks(self) -> Iterator[Chunk]:
        payload = self.serialize()
        n = len(payload)
        if n == 0:
            yield Chunk(name=self.name, kind="object", data=b"",
                        codec=self.codec, last=True)
            return
        for pos in range(0, n, self.chunk_bytes):
            end = min(pos + self.chunk_bytes, n)
            yield Chunk(name=self.name, kind="object",
                        data=payload[pos:end], codec=self.codec,
                        last=end >= n)


class CompositeStateProvider(StateProvider):
    """Hierarchical composition of SPs targeting one checkpoint file.

    Responsibilities (paper §V-A3): (a) compute sizes/offsets for the fixed
    region, (b) group/order chunks for the persistent layout, (c) stream
    tensors first — largest first — so the engine is busy with bulk I/O while
    object serialization proceeds.
    """

    def __init__(self, name: str, providers: Sequence[StateProvider]):
        self.name = name
        self.tensor_providers: List[TensorStateProvider] = [
            p for p in providers if isinstance(p, TensorStateProvider)]
        self.object_providers: List[ObjectStateProvider] = [
            p for p in providers if isinstance(p, ObjectStateProvider)]
        composites = [p for p in providers if isinstance(p, CompositeStateProvider)]
        for c in composites:  # hierarchical merge
            self.tensor_providers.extend(c.tensor_providers)
            self.object_providers.extend(c.object_providers)
        self._layout: Optional[FileLayout] = None

    def plan_layout(self) -> FileLayout:
        """Fix tensor offsets (largest-first order = stream order).

        Only providers with ``fixed_offset`` (raw tensors, keyframes) get
        fixed-region offsets; encoded providers (delta mode) are excluded —
        their compressed chunks log-append, so the file never reserves
        their raw footprint."""
        if self._layout is None:
            self.tensor_providers.sort(key=lambda p: -p.nbytes)
            fixed = [p for p in self.tensor_providers
                     if getattr(p, "fixed_offset", True)]
            specs = [(p.name, p.nbytes, p.dtype, p.shape, p.global_shape,
                      p.index) for p in fixed]
            self._layout = FileLayout.plan(specs)
            for p, entry in zip(fixed, self._layout.tensors):
                p.offset = entry.offset
        return self._layout

    def encoded_providers(self) -> List[TensorStateProvider]:
        return [p for p in self.tensor_providers
                if not getattr(p, "fixed_offset", True)]

    def nbytes_hint(self) -> Optional[int]:
        return sum(p.nbytes for p in self.tensor_providers)

    def chunks(self) -> Iterator[Chunk]:
        self.plan_layout()
        for p in self.tensor_providers:   # bulk zero-copy I/O first
            yield from p.chunks()
        for p in self.object_providers:   # serialization overlapped w/ flush
            yield from p.chunks()
