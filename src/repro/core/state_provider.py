"""Composable state providers (paper §V-A3).

A *state provider* (SP) encapsulates per-data-structure knowledge — residency
(device vs. host), type (byte-addressable tensor vs. Python object), layout,
and (de)serialization needs — and exposes a uniform, stream-oriented view to
the data-movement engine: an iterator of :class:`Chunk` byte ranges. The
engine stays agnostic to heterogeneity and only optimizes multi-tier I/O.

* :class:`TensorStateProvider` — zero-copy. Host-resident tensors stream
  memoryviews of their own buffers; device-resident tensors stream views of
  their staged copy in the pinned :class:`~repro.core.host_cache.HostCache`
  reservation, chunk by chunk as D2H staging progresses (so flushing of a
  tensor overlaps with staging of its own tail — paper §V-A4 / Fig 15).
* :class:`ObjectStateProvider` — serializes Python objects (pickle/msgpack)
  lazily at stream time; its chunks carry no fixed offset and are appended
  log-structured (paper §V-A5).
* :class:`CompositeStateProvider` — hierarchical composition: plans the
  fixed-offset tensor region for one file, orders the stream tensors-first
  (largest first) so object serialization overlaps with bulk tensor I/O.
"""

from __future__ import annotations

import dataclasses
import pickle
import threading
from typing import Any, Callable, Iterator, List, Optional, Sequence, Tuple

import msgpack
import numpy as np

from .host_cache import HostCache, Reservation
from .layout import FileLayout, align_up

DEFAULT_CHUNK_BYTES = 16 * 1024 * 1024


@dataclasses.dataclass
class Chunk:
    """One byte range to persist. ``offset is None`` → log-append."""

    name: str
    kind: str                      # "tensor" | "object"
    data: Any                      # memoryview | bytes
    offset: Optional[int] = None   # fixed file offset; None = append
    codec: str = "raw"
    last: bool = False             # last chunk of this logical item


class StateProvider:
    """Base: a named producer of checkpoint chunks."""

    name: str

    def chunks(self) -> Iterator[Chunk]:
        raise NotImplementedError

    def nbytes_hint(self) -> Optional[int]:
        """Size if known a priori (tensors), else None (serialized objects)."""
        return None


class TensorStateProvider(StateProvider):
    """Zero-copy SP for a byte-addressable tensor (host or device resident).

    For device arrays, :meth:`bind_reservation` attaches the pinned-cache
    reservation and :meth:`notify_staged` is called by the staging thread as
    bytes land; :meth:`chunks` yields each chunk as soon as its bytes are
    staged, enabling flush/staging overlap within a single large tensor.
    """

    def __init__(self, name: str, *, dtype: str, shape: Tuple[int, ...],
                 nbytes: int,
                 host_array: Optional[np.ndarray] = None,
                 global_shape: Optional[Tuple[int, ...]] = None,
                 index: Optional[Tuple[Tuple[int, int], ...]] = None,
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 stream_intra_tensor: bool = True):
        self.name = name
        self.dtype = dtype
        self.shape = tuple(shape)
        self.nbytes = int(nbytes)
        self.global_shape = global_shape
        self.index = index
        self.chunk_bytes = chunk_bytes
        # False = legacy engines: flush only once the whole tensor is staged.
        self.stream_intra_tensor = stream_intra_tensor
        self.offset: Optional[int] = None  # assigned by composite layout plan
        # host-resident path
        self._host_array = host_array
        # device-resident path
        self._reservation: Optional[Reservation] = None
        self._staged = 0
        self._cond = threading.Condition()
        self._released = False

    # -- residency wiring ----------------------------------------------------
    @property
    def device_resident(self) -> bool:
        return self._host_array is None

    def bind_reservation(self, res: Reservation) -> None:
        self._reservation = res

    @property
    def reservation(self) -> Optional[Reservation]:
        return self._reservation

    def notify_staged(self, nbytes_total: int) -> None:
        """Staging thread reports cumulative bytes landed in the cache."""
        with self._cond:
            self._staged = nbytes_total
            self._cond.notify_all()

    def release(self) -> None:
        """Free the cache reservation once all chunks are flushed."""
        with self._cond:
            if self._released:
                return
            self._released = True
        if self._reservation is not None:
            self._reservation.release()

    # -- StateProvider -------------------------------------------------------
    def nbytes_hint(self) -> Optional[int]:
        return self.nbytes

    def _byte_view(self) -> memoryview:
        if self._host_array is not None:
            arr = np.ascontiguousarray(self._host_array)
            return memoryview(arr).cast("B")
        assert self._reservation is not None, (
            f"device tensor {self.name} streamed before staging was bound")
        return self._reservation.view

    def chunks(self) -> Iterator[Chunk]:
        view = self._byte_view()
        n = self.nbytes
        pos = 0
        while pos < n:
            end = min(pos + self.chunk_bytes, n)
            if self._host_array is None:
                # Wait until staging has landed these bytes (partial-tensor
                # overlap: flush the head while the tail is still in DMA).
                with self._cond:
                    while self._staged < end:
                        self._cond.wait()
            yield Chunk(name=self.name, kind="tensor", data=view[pos:end],
                        offset=self.offset + pos if self.offset is not None else None,
                        last=end >= n)
            pos = end


class ObjectStateProvider(StateProvider):
    """SP for non-tensor Python state (dicts, RNG seeds, config, ...).

    Serialization happens lazily inside :meth:`chunks` — i.e. on the engine's
    producer thread, *after* tensor chunks have been enqueued — so it overlaps
    with bulk tensor I/O instead of blocking the training loop (§V-A5).
    """

    def __init__(self, name: str, obj: Any, codec: str = "pickle",
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 preserialized: Optional[bytes] = None):
        self.name = name
        self._obj = obj
        self.codec = codec
        self.chunk_bytes = chunk_bytes
        self._preserialized = preserialized
        self.serialized_nbytes: Optional[int] = (
            len(preserialized) if preserialized is not None else None)

    def serialize(self) -> bytes:
        if self._preserialized is not None:  # legacy blocking-upfront engines
            return self._preserialized
        if self.codec == "pickle":
            payload = pickle.dumps(self._obj, protocol=pickle.HIGHEST_PROTOCOL)
        elif self.codec == "msgpack":
            payload = msgpack.packb(self._obj, use_bin_type=True)
        else:
            raise ValueError(f"unknown codec {self.codec}")
        self.serialized_nbytes = len(payload)
        return payload

    def chunks(self) -> Iterator[Chunk]:
        payload = self.serialize()
        n = len(payload)
        if n == 0:
            yield Chunk(name=self.name, kind="object", data=b"",
                        codec=self.codec, last=True)
            return
        for pos in range(0, n, self.chunk_bytes):
            end = min(pos + self.chunk_bytes, n)
            yield Chunk(name=self.name, kind="object",
                        data=payload[pos:end], codec=self.codec,
                        last=end >= n)


class CompositeStateProvider(StateProvider):
    """Hierarchical composition of SPs targeting one checkpoint file.

    Responsibilities (paper §V-A3): (a) compute sizes/offsets for the fixed
    region, (b) group/order chunks for the persistent layout, (c) stream
    tensors first — largest first — so the engine is busy with bulk I/O while
    object serialization proceeds.
    """

    def __init__(self, name: str, providers: Sequence[StateProvider]):
        self.name = name
        self.tensor_providers: List[TensorStateProvider] = [
            p for p in providers if isinstance(p, TensorStateProvider)]
        self.object_providers: List[ObjectStateProvider] = [
            p for p in providers if isinstance(p, ObjectStateProvider)]
        composites = [p for p in providers if isinstance(p, CompositeStateProvider)]
        for c in composites:  # hierarchical merge
            self.tensor_providers.extend(c.tensor_providers)
            self.object_providers.extend(c.object_providers)
        self._layout: Optional[FileLayout] = None

    def plan_layout(self) -> FileLayout:
        """Fix tensor offsets (largest-first order = stream order)."""
        if self._layout is None:
            self.tensor_providers.sort(key=lambda p: -p.nbytes)
            specs = [(p.name, p.nbytes, p.dtype, p.shape, p.global_shape, p.index)
                     for p in self.tensor_providers]
            self._layout = FileLayout.plan(specs)
            for p, entry in zip(self.tensor_providers, self._layout.tensors):
                p.offset = entry.offset
        return self._layout

    def nbytes_hint(self) -> Optional[int]:
        return sum(p.nbytes for p in self.tensor_providers)

    def chunks(self) -> Iterator[Chunk]:
        self.plan_layout()
        for p in self.tensor_providers:   # bulk zero-copy I/O first
            yield from p.chunks()
        for p in self.object_providers:   # serialization overlapped w/ flush
            yield from p.chunks()
