"""Tiered checkpoint repository: catalog, cascade flush, retention GC.

Sits between the data-movement engine (which gets bytes off the device
fast) and durable storage (where those bytes live). The repository owns:

* the **catalog** — one atomically-written manifest per committed step
  under ``<root>/.catalog/``. A step is visible iff its manifest exists;
  an in-flight marker (written before any data file) distinguishes crash
  victims from legacy pre-repository directories, so ``latest_step`` can
  never select a half-written checkpoint (crash consistency by
  construction);
* the **cascade flusher** — a background thread replicating committed
  steps from the fast local tier to remote tiers (peer memory, simulated
  object store with multipart upload), overlapped with training: the
  paper's multi-tier pipeline extended past host memory (TierCheck's
  cascade);
* **retention GC** — keep-last-N / keep-every-K / pinned-step policies
  applied per tier, never deleting the newest complete step, pinned
  steps, in-flight saves, or anything mid-cascade.

Restore resolution falls back tier-by-tier: a step GC'd from (or never
present on) the local tier is re-hydrated from the first remote tier that
holds a complete copy, verified against its manifest, before the parallel
``RestoreEngine`` reads it.
"""

from __future__ import annotations

import contextlib
import dataclasses
import glob
import logging
import os
import queue
import re
import shutil
import threading
import time

from repro.obs import trace as obs
from repro.obs.metrics import metrics as obs_metrics
import uuid
from typing import Any, Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.locks import declares_lock

from .backend import BackendError, LocalBackend, StorageBackend
from .manifest import (FileEntry, StepManifest, file_checksum,
                       probe_step_complete)

CATALOG_DIR = ".catalog"
_STEP_RE = re.compile(r"step-(\d+)\.json$")
_MARKER_RE = re.compile(r"inflight-(\d+)$")

logger = logging.getLogger(__name__)


def step_dirname(step: int) -> str:
    return f"global_step{step}"


def entry_name(step: int) -> str:
    return f"step-{step:012d}.json"


def marker_name(step: int) -> str:
    return f"inflight-{step:012d}"


def catalog_key(step: int) -> str:
    return f"{CATALOG_DIR}/{entry_name(step)}"


def marker_key(step: int) -> str:
    return f"{CATALOG_DIR}/{marker_name(step)}"


def data_key(step: int, filename: str) -> str:
    return f"{step_dirname(step)}/{filename}"


# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RetentionPolicy:
    """Which committed steps a tier keeps (pins/newest are always kept)."""

    keep_last_n: Optional[int] = None
    keep_every_k: Optional[int] = None

    def retained(self, steps: Sequence[int]) -> Set[int]:
        steps = sorted(steps)
        if self.keep_last_n is None and self.keep_every_k is None:
            return set(steps)
        keep: Set[int] = set()
        if self.keep_last_n:
            keep.update(steps[-self.keep_last_n:])
        if self.keep_every_k:
            keep.update(s for s in steps if s % self.keep_every_k == 0)
        return keep


@dataclasses.dataclass
class Tier:
    """One storage tier: a named backend plus its retention policy."""

    name: str
    backend: StorageBackend
    retention: Optional[RetentionPolicy] = None


@dataclasses.dataclass
class VerifyResult:
    step: int
    ok: bool
    missing: List[str] = dataclasses.field(default_factory=list)
    size_mismatch: List[str] = dataclasses.field(default_factory=list)
    checksum_mismatch: List[str] = dataclasses.field(default_factory=list)
    # per-chunk localization of checksum mismatches, e.g.
    # "w00.dsllm: w00 raw chunk [0:16777216)" — only for container files
    # whose footer carries per-chunk digests
    chunk_mismatch: List[str] = dataclasses.field(default_factory=list)

    @property
    def problems(self) -> List[str]:
        return (self.missing + [f"{n} (size)" for n in self.size_mismatch]
                + [f"{n} (checksum)" for n in self.checksum_mismatch]
                + [f"{n} (chunk)" for n in self.chunk_mismatch])


@dataclasses.dataclass
class GCReport:
    deleted_steps: List[int] = dataclasses.field(default_factory=list)
    deleted_orphans: List[int] = dataclasses.field(default_factory=list)
    remote_deleted: Dict[str, List[int]] = dataclasses.field(
        default_factory=dict)
    bytes_freed: int = 0
    seconds: float = 0.0
    dry_run: bool = False


@dataclasses.dataclass
class CascadeEvent:
    step: int
    tier: str
    nbytes: int
    t_start: float
    t_end: float

    @property
    def seconds(self) -> float:
        return self.t_end - self.t_start


# ---------------------------------------------------------------------------
# Catalog scanning (module-level so `core.checkpoint.latest_step` can stay a
# plain function over a directory, with no repository instance required).

def _dir_size(sdir: str) -> int:
    total = 0
    for dirpath, _dirs, files in os.walk(sdir):
        for fn in files:
            try:
                total += os.path.getsize(os.path.join(dirpath, fn))
            except OSError:
                pass
    return total


def scan_catalog(root: str) -> Tuple[Set[int], Set[int]]:
    """(steps with a catalog entry, steps with an in-flight marker)."""
    cdir = os.path.join(root, CATALOG_DIR)
    entries: Set[int] = set()
    markers: Set[int] = set()
    if os.path.isdir(cdir):
        for n in os.listdir(cdir):
            m = _STEP_RE.match(n)
            if m:
                entries.add(int(m.group(1)))
                continue
            m = _MARKER_RE.match(n)
            if m:
                markers.add(int(m.group(1)))
    return entries, markers


def step_dirs(root: str) -> Dict[int, str]:
    out = {}
    for d in glob.glob(os.path.join(root, "global_step*")):
        m = re.search(r"global_step(\d+)$", d)
        if m and os.path.isdir(d):
            out[int(m.group(1))] = d
    return out


def committed_steps(root: str) -> List[int]:
    """Steps eligible for resume, ascending.

    Committed = catalog entry present (and the local data directory still
    exists), or a legacy manifest-less directory with no in-flight marker
    that passes the per-format completeness probe. A directory carrying an
    in-flight marker but no manifest is a crash victim — never eligible.
    """
    entries, markers = scan_catalog(root)
    dirs = step_dirs(root)
    steps = []
    for step, sdir in dirs.items():
        if step in entries:
            steps.append(step)
        elif step in markers:
            continue  # crash victim: data landed, manifest never committed
        elif probe_step_complete(sdir):
            steps.append(step)  # legacy pre-repository directory
    return sorted(steps)


def orphan_steps(root: str) -> List[int]:
    """Steps with on-disk data (or a stale marker) but no catalog entry and
    no passing completeness probe — crash victims awaiting GC."""
    entries, markers = scan_catalog(root)
    dirs = step_dirs(root)
    orphans = set()
    for step, sdir in dirs.items():
        if step in entries:
            continue
        if step in markers or not probe_step_complete(sdir):
            orphans.add(step)
    # markers whose data directory never appeared (crash inside makedirs)
    orphans.update(m for m in markers
                   if m not in entries and m not in dirs)
    return sorted(orphans)


# ---------------------------------------------------------------------------
@declares_lock("repository.state", rank=40, attrs=("_lock",))
class CheckpointRepository:
    """Tiered, catalog-backed home for checkpoint steps.

    ``root`` is the fast local tier (tier 0) — the directory the engines
    write into. ``remote_tiers`` are ordered fast→durable; committed steps
    cascade to them in the background when ``auto_cascade`` is on.
    """

    def __init__(self, root: str, remote_tiers: Sequence[Tier] = (),
                 *, retention: Optional[RetentionPolicy] = None,
                 checksum: bool = True, auto_cascade: bool = True,
                 auto_gc: bool = True):
        self.root = os.path.abspath(root)
        self.remote_tiers: List[Tier] = list(remote_tiers)
        names = [t.name for t in self.remote_tiers]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tier names: {names}")
        self.retention = retention
        self.checksum = checksum
        self.auto_gc = auto_gc
        self.catalog_dir = os.path.join(self.root, CATALOG_DIR)
        try:
            os.makedirs(self.catalog_dir, exist_ok=True)
        except OSError:
            # Read-only mount (e.g. serving from a snapshot of a legacy,
            # pre-repository directory): catalog reads degrade to the
            # completeness probe; catalog writes will fail loudly.
            pass
        self._local = LocalBackend(self.root)
        self._fleet: Optional[Any] = None  # repro.fleet.FleetFabric
        self._lock = threading.Lock()  # declared: repository.state (r40)
        self._active: Set[int] = set()        # begun in this process
        self._mid_cascade: Set[int] = set()
        self._reading: Dict[int, int] = {}    # restore refcounts
        self._manifest_cache: Dict[int, StepManifest] = {}
        self.cascade_log: List[CascadeEvent] = []
        self.cascade_errors: List[Tuple[int, str]] = []
        self.gc_log: List[GCReport] = []
        self._cascade_q: Optional["queue.Queue[Optional[int]]"] = None
        self._cascade_thread: Optional[threading.Thread] = None
        if self.remote_tiers and auto_cascade:
            self._cascade_q = queue.Queue()
            self._cascade_thread = threading.Thread(
                target=self._cascade_worker, daemon=True,
                name="repo-cascade")
            self._cascade_thread.start()

    # ------------------------------------------------------------- locations
    def step_dir(self, step: int) -> str:
        return os.path.join(self.root, step_dirname(step))

    def _entry_path(self, step: int) -> str:
        return os.path.join(self.catalog_dir, entry_name(step))

    def _marker_path(self, step: int) -> str:
        return os.path.join(self.catalog_dir, marker_name(step))

    # ------------------------------------------------------------- lifecycle
    def begin_step(self, step: int) -> str:
        """Declare a save in flight: marker first, so a crash at any later
        point leaves an identifiable orphan. Re-saving a committed step
        retracts its catalog entry and *clears the old data files* — the
        engine only rewrites the files of the new shard layout, and a
        stale extra shard surviving into the new manifest would be
        silently blessed (checksummed) and restored."""
        # A cascade of the same step still in flight would read files
        # while the engine rewrites them; let it finish (or fail) first.
        # Rewind-resaves of an already-cascaded step are rare, and the
        # cascade is bounded by the remote tier's bandwidth.
        while True:
            with self._lock:
                busy = step in self._mid_cascade
                if not busy:
                    self._active.add(step)
                    self._manifest_cache.pop(step, None)
                    break
            time.sleep(0.01)
        # Rewind-resave: any committed step whose delta chain passes
        # through this step was XOR-encoded against the bytes about to be
        # replaced — replaying it over the new bytes would restore
        # garbage that passes every checksum. Retract such dependents
        # everywhere before touching the data.
        self._retract_delta_dependents(step)
        try:
            os.unlink(self._entry_path(step))
        except FileNotFoundError:
            pass
        self._local.put(marker_key(step), str(time.time()).encode("ascii"))
        sdir = self.step_dir(step)
        if os.path.isdir(sdir):
            shutil.rmtree(sdir)
        os.makedirs(sdir, exist_ok=True)
        return sdir

    def _retract_delta_dependents(self, step: int) -> None:
        """Turn committed delta steps that depend on ``step`` into
        invisible orphans (local catalog entry → in-flight marker; remote
        tier copies deleted). Chains only point backwards, so on the
        normal forward-progress path (``step`` newer than everything
        committed) this scans nothing."""
        later = [s for s in self.steps() if s > step]
        for s in later:
            try:
                # strict walk: a truncated/lenient chain could silently
                # omit `step` and leave a stale dependent committed
                chain = self.chain_steps(s, strict=True)
                dependent = step in chain
            except (BackendError, OSError, ValueError):
                # cannot prove s is independent of the bytes being
                # replaced — correctness over retention: retract it
                dependent = True
            if not dependent:
                continue
            while True:  # let an in-flight cascade of s finish first
                with self._lock:
                    busy = s in self._mid_cascade
                if not busy:
                    break
                time.sleep(0.01)
            try:
                os.unlink(self._entry_path(s))
            except FileNotFoundError:
                pass
            self._local.put(marker_key(s), str(time.time()).encode("ascii"))
            with self._lock:
                self._manifest_cache.pop(s, None)
            for tier in self.remote_tiers:
                try:
                    if self.tier_has_step(tier, s):
                        self._delete_tier_step(tier, s)
                except BackendError:
                    pass  # best effort: a tier failing deletes is failing
                          # reads too; the local retraction already makes
                          # the step invisible to this repository

    def abort_step(self, step: int) -> None:
        """A save failed after ``begin_step``: the marker stays (the step
        is an orphan for GC), but it is no longer an *active* save."""
        with self._lock:
            self._active.discard(step)

    def commit_step(self, step: int, *, engine_mode: Optional[str] = None,
                    meta: Optional[Dict[str, Any]] = None,
                    expect_ranks: Optional[int] = None,
                    writers: Optional[Sequence[int]] = None,
                    nodes: Optional[Dict[int, Any]] = None) -> StepManifest:
        """Make a fully-persisted step visible: build its manifest (sizes +
        kernel checksums) and write it atomically *last*.

        ``expect_ranks`` enables the multi-rank phase-2 gate: the manifest
        build validates every rank's phase-1 vote (see
        :meth:`StepManifest.build`) and raises instead of committing a
        partially-written step. ``writers`` narrows the expected voter
        set (a coordinator that reassigned a dead rank's shards passes
        the survivors); ``nodes`` additionally audits the hierarchical
        commit tree's node-aggregator votes."""
        sdir = self.step_dir(step)
        tb0 = time.perf_counter()
        manifest = StepManifest.build(sdir, step, engine_mode=engine_mode,
                                      checksum=self.checksum, meta=meta,
                                      expect_ranks=expect_ranks,
                                      writers=writers, nodes=nodes)
        if not manifest.files:
            raise BackendError(
                f"refusing to commit empty step directory {sdir!r}")
        # record the manifest build (vote validation + checksum hashing)
        # duration in the manifest itself: `storage.cli stats` reads it
        # back from any repository, no in-process stats needed
        manifest.meta["commit"] = {"build_s": time.perf_counter() - tb0}
        with obs.span("manifest.write", step=step):
            self._local.put(catalog_key(step), manifest.to_json_bytes())
        try:
            os.unlink(self._marker_path(step))
        except FileNotFoundError:
            pass
        with self._lock:
            self._active.discard(step)
            self._manifest_cache[step] = manifest
            if self._cascade_q is not None:
                self._mid_cascade.add(step)
                self._cascade_q.put(step)
        if self.auto_gc and self.retention is not None:
            self.gc()
        return manifest

    # --------------------------------------------------------------- catalog
    def steps(self) -> List[int]:
        """Committed steps across *all* tiers (a step GC'd locally but
        still held by a remote tier remains resumable via re-hydration)."""
        steps = set(committed_steps(self.root))
        for tier in self.remote_tiers:
            steps.update(self.tier_steps(tier))
        return sorted(steps)

    def local_steps(self) -> List[int]:
        return committed_steps(self.root)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def orphans(self) -> List[int]:
        with self._lock:
            active = set(self._active)
        return [s for s in orphan_steps(self.root) if s not in active]

    def manifest(self, step: int) -> StepManifest:
        with self._lock:
            cached = self._manifest_cache.get(step)
        if cached is not None:
            return cached
        m = StepManifest.from_json_bytes(self._local.get(catalog_key(step)))
        with self._lock:
            self._manifest_cache[step] = m
        return m

    def has_manifest(self, step: int) -> bool:
        return os.path.isfile(self._entry_path(step))

    def manifest_any_tier(self, step: int) -> StepManifest:
        """Manifest from the local catalog, else the first remote tier
        holding the step (a chain base GC'd locally is still a chain
        base — its metadata must stay reachable)."""
        try:
            return self.manifest(step)
        except (BackendError, OSError, ValueError):
            for tier in self.remote_tiers:
                try:
                    if self.tier_has_step(tier, step):
                        m = StepManifest.from_json_bytes(
                            tier.backend.get(catalog_key(step)))
                        with self._lock:
                            self._manifest_cache[step] = m
                        return m
                except (BackendError, OSError, ValueError):
                    continue
            raise

    # ----------------------------------------------------------- delta chains
    def delta_base(self, step: int) -> Optional[int]:
        """Base step of a differential step, or None for keyframes / full
        snapshots / steps without readable chain metadata."""
        try:
            m = self.manifest_any_tier(step)
        except (BackendError, OSError, ValueError):
            return None
        d = (m.meta or {}).get("delta") or {}
        if d.get("keyframe", True):
            return None
        return d.get("base_step")

    def chain_steps(self, step: int, *, strict: bool = False) -> List[int]:
        """``[keyframe, ..., step]`` for a differential step (ascending);
        ``[step]`` for keyframes / full snapshots / manifest-less steps.

        Lenient mode (the default — GC/audit callers) treats an
        unreadable ancestor manifest or corrupt base metadata as the
        chain root and returns what it could walk; ``strict=True``
        (restore) raises instead, so a broken chain is never silently
        replayed from mid-way."""
        chain = [step]
        seen = {step}
        cur = step
        while True:
            try:
                m = self.manifest_any_tier(cur)
            except (BackendError, OSError, ValueError):
                if strict and cur != step:
                    raise
                return list(reversed(chain))  # legacy/unreadable root
            d = (m.meta or {}).get("delta") or {}
            if d.get("keyframe", True):
                return list(reversed(chain))
            base = d.get("base_step")
            if base is None or base in seen:
                if strict:
                    raise BackendError(
                        f"step {step}: corrupt delta-chain metadata at "
                        f"step {cur} (base_step={base})")
                return list(reversed(chain))
            chain.append(base)
            seen.add(base)
            cur = base

    def chain_closure(self, steps: Iterable[int]) -> Set[int]:
        """``steps`` plus every chain ancestor (base, base-of-base, ...)
        down to each keyframe — the retention unit of differential
        checkpointing: a retained/pinned delta step pins its whole chain."""
        out: Set[int] = set(steps)
        stack = list(out)
        while stack:
            base = self.delta_base(stack.pop())
            if base is not None and base not in out:
                out.add(base)
                stack.append(base)
        return out

    # ------------------------------------------------------------------ pins
    @property
    def _pins_path(self) -> str:
        return os.path.join(self.catalog_dir, "pins.json")

    def pins(self) -> Set[int]:
        try:
            import json
            with open(self._pins_path) as f:
                return set(json.load(f).get("pinned", []))
        except (OSError, ValueError):
            return set()

    def _write_pins(self, pinned: Set[int]) -> None:
        import json
        self._local.put(f"{CATALOG_DIR}/pins.json",
                        json.dumps({"pinned": sorted(pinned)}).encode())

    def pin(self, step: int) -> None:
        self._write_pins(self.pins() | {step})

    def unpin(self, step: int) -> None:
        self._write_pins(self.pins() - {step})

    # ---------------------------------------------------------------- verify
    def verify_step(self, step: int, *, check_checksums: bool = True
                    ) -> VerifyResult:
        """Re-audit a committed step's local files against its manifest."""
        manifest = self.manifest(step)
        res = VerifyResult(step=step, ok=True)
        sdir = self.step_dir(step)
        for fe in manifest.files:
            path = os.path.join(sdir, fe.name)
            if not os.path.isfile(path):
                res.missing.append(fe.name)
                continue
            if os.path.getsize(path) != fe.nbytes:
                res.size_mismatch.append(fe.name)
                continue
            if check_checksums and fe.checksum is not None \
                    and file_checksum(path) != fe.checksum:
                res.checksum_mismatch.append(fe.name)
                for loc in self._locate_chunks(path):
                    res.chunk_mismatch.append(f"{fe.name}: {loc}")
        res.ok = not res.problems
        return res

    @staticmethod
    def _locate_chunks(path: str) -> List[str]:
        """Narrow a whole-file checksum mismatch to the damaged chunk(s)
        using the per-chunk digests in the container footer (raw/keyframe
        and encoded routes both record them). Best-effort: a file too
        damaged to parse stays localized at file granularity."""
        if not path.endswith(".dsllm"):
            return []
        try:
            from repro.core.layout import FileReader
            return FileReader(path).locate_corrupt_chunks()
        except Exception:  # noqa: BLE001 — footer itself may be damaged
            return []

    def _local_complete(self, step: int) -> bool:
        """Catalog entry present and every file on disk at manifest size."""
        if not self.has_manifest(step):
            return False
        try:
            manifest = self.manifest(step)
        except (BackendError, ValueError):
            return False
        sdir = self.step_dir(step)
        for fe in manifest.files:
            path = os.path.join(sdir, fe.name)
            if not os.path.isfile(path) \
                    or os.path.getsize(path) != fe.nbytes:
                return False
        return True

    # --------------------------------------------------------------- cascade
    def tier_has_step(self, tier: Tier, step: int) -> bool:
        """Complete-on-tier test: the manifest object is uploaded last, so
        its presence implies every data object landed."""
        return tier.backend.exists(catalog_key(step))

    def tier_steps(self, tier: Tier) -> List[int]:
        steps = []
        for key in tier.backend.list(f"{CATALOG_DIR}/step-"):
            m = _STEP_RE.search(key)
            if m:
                steps.append(int(m.group(1)))
        return sorted(steps)

    def cascade_step(self, step: int) -> None:
        """Replicate one committed step to every remote tier (synchronous;
        the background worker calls this off the training path)."""
        for tier in self.remote_tiers:
            self._cascade_step_to_tier(step, tier)

    def _cascade_step_to_tier(self, step: int, tier: Tier,
                              _depth: int = 0) -> None:
        """One step onto one tier — chains ship whole or not at all: a
        differential step's ancestors are uploaded first (recursively), so
        the tier never holds a delta whose keyframe it cannot produce."""
        if _depth > 4096:
            raise BackendError(
                f"step {step}: delta-chain recursion exceeded sanity bound")
        manifest = self.manifest(step)
        sdir = self.step_dir(step)
        payload = manifest.to_json_bytes()
        d = (manifest.meta or {}).get("delta") or {}
        base = None if d.get("keyframe", True) else d.get("base_step")
        if base is not None and not self.tier_has_step(tier, base):
            if not self._local_complete(base):
                raise BackendError(
                    f"step {step}: chain base {base} is neither on tier "
                    f"{tier.name!r} nor complete locally — shipping "
                    f"nothing (chains cascade whole or not at all)")
            self._cascade_step_to_tier(base, tier, _depth + 1)
        if self.tier_has_step(tier, step):
            # Identical manifest ⇒ identical bytes already landed. A
            # *different* manifest means the step was re-saved after an
            # earlier cascade (rewind): re-upload, or a later local GC
            # would re-hydrate the stale bytes.
            if tier.backend.get(catalog_key(step)) == payload:
                return
            tier.backend.delete(catalog_key(step))  # invisible first
        t0 = time.perf_counter()
        nbytes = 0
        uploaded: List[str] = []
        try:
            for fe in manifest.files:
                key = data_key(step, fe.name)
                nbytes += tier.backend.put_file(
                    key, os.path.join(sdir, fe.name))
                uploaded.append(key)
            # manifest last: the step is visible on the tier iff complete
            tier.backend.put(catalog_key(step), payload)
            # drop data objects a superseded upload left behind that
            # the new manifest no longer references
            expected = {data_key(step, fe.name)
                        for fe in manifest.files}
            for key in tier.backend.list(f"{step_dirname(step)}/"):
                if key not in expected:
                    tier.backend.delete(key)
        except BaseException:
            # Never leak manifest-less data objects: tier GC only
            # enumerates cataloged steps, so stragglers would be
            # undeletable (and could wedge a capacity-bound tier).
            for key in uploaded:
                try:
                    tier.backend.delete(key)
                except BaseException:  # noqa: BLE001
                    pass
            raise
        t1 = time.perf_counter()
        with self._lock:
            self.cascade_log.append(CascadeEvent(
                step=step, tier=tier.name, nbytes=nbytes,
                t_start=t0, t_end=t1))
        obs_metrics.inc("repo.cascade_bytes", nbytes)
        obs.add_span("cascade.upload", t0, t1, step=step, tier=tier.name,
                     bytes=nbytes, flow=obs.flow_id("save", step))

    def _cascade_worker(self) -> None:
        q = self._cascade_q
        assert q is not None
        while True:
            step = q.get()
            if step is None:
                q.task_done()
                return
            try:
                self.cascade_step(step)
            except BaseException as exc:  # noqa: BLE001
                with self._lock:
                    self.cascade_errors.append((step, repr(exc)))
            finally:
                with self._lock:
                    self._mid_cascade.discard(step)
                q.task_done()

    def wait_cascaded(self) -> None:
        if self._cascade_q is not None:
            self._cascade_q.join()

    # -------------------------------------------------------------- restore
    def attach_fleet(self, fabric: Optional[Any]) -> None:
        """Route this repository's remote re-hydration through a fleet
        distribution fabric (``repro.fleet.FleetFabric``). The fabric's
        cache/peer-exchange path replaces direct tier reads on restore
        resolution; any fabric failure degrades back to direct tier
        fetches. Pass ``None`` to detach."""
        self._fleet = fabric

    def resolve_for_restore(self, step: int) -> str:
        """Local directory for ``step``, re-hydrating tier-by-tier.

        Preference order: complete local copy → fetch from the first
        remote tier holding a complete copy (verified against the
        manifest, staged, then atomically renamed into place) → whatever
        partial local directory exists (the restore engine produces the
        precise failure, and resume-level fallback moves to an older
        step).
        """
        sdir = self.step_dir(step)
        if self._local_complete(step):
            return sdir
        fetch_exc: Optional[BaseException] = None
        if self._fleet is not None:
            try:
                got = self._fleet.fetch_step(self, step)
                if got is not None:
                    return got
            except (BackendError, OSError, ValueError) as exc:
                # the fabric degrades to direct tier reads below
                fetch_exc = exc
        for tier in self.remote_tiers:
            try:
                if not self.tier_has_step(tier, step):
                    continue
                return self._fetch_from_tier(tier, step)
            except (BackendError, OSError, ValueError) as exc:
                # this tier's copy is damaged or unreachable — a lower
                # tier may still hold a good one
                fetch_exc = exc
                continue
        if os.path.isdir(sdir):
            return sdir
        if fetch_exc is not None:
            raise BackendError(
                f"step {step}: every tier holding a copy failed to "
                f"produce a verified one") from fetch_exc
        raise FileNotFoundError(
            f"step {step} not present on any tier of {self.root}")

    def _fetch_from_tier(self, tier: Tier, step: int) -> str:
        manifest = StepManifest.from_json_bytes(
            tier.backend.get(catalog_key(step)))
        staging = self.new_staging_dir(step)
        try:
            for fe in manifest.files:
                tier.backend.get_file(data_key(step, fe.name),
                                      os.path.join(staging, fe.name))
            return self.admit_fetched_step(step, manifest, staging,
                                           source=f"tier {tier.name!r}")
        except BaseException:
            shutil.rmtree(staging, ignore_errors=True)
            raise

    def new_staging_dir(self, step: int) -> str:
        """Private staging directory for a step being re-hydrated (one per
        fetch attempt; the caller owns cleanup on failure)."""
        staging = os.path.join(self.catalog_dir, "staging",
                               f"step-{step}-{uuid.uuid4().hex[:8]}")
        os.makedirs(staging, exist_ok=True)
        return staging

    def admit_fetched_step(self, step: int, manifest: StepManifest,
                           staging: str, *, source: str = "fetch") -> str:
        """Verify a fully-staged fetch against its manifest and publish it
        atomically. The single sanctioned re-hydration publish: direct
        tier fetches and the fleet fabric both funnel through here, so
        unverified bytes can never become a visible local step. Raises
        (leaving ``staging`` for the caller to clean up) on any size or
        checksum mismatch."""
        for fe in manifest.files:
            dst = os.path.join(staging, fe.name)
            if not os.path.isfile(dst):
                raise BackendError(
                    f"{source} staged step {step} without {fe.name}")
            if os.path.getsize(dst) != fe.nbytes:
                raise BackendError(
                    f"{source} returned {fe.name} with "
                    f"{os.path.getsize(dst)} B, manifest says "
                    f"{fe.nbytes} B")
            if fe.checksum is not None \
                    and file_checksum(dst) != fe.checksum:
                raise BackendError(
                    f"{source} returned {fe.name} with a checksum "
                    f"mismatch (bitrot in remote storage?)")
        sdir = self.step_dir(step)
        if os.path.isdir(sdir):
            shutil.rmtree(sdir)
        # This IS the sanctioned rehydration helper: every file was
        # size- and checksum-verified into a private staging dir, and
        # the one-shot directory rename is the atomic publish step
        # (manifest re-admission below still happens last).
        os.replace(staging, sdir)  # ckptlint: disable=CKPT302
        # re-admit to the local catalog so the next resolve is a local hit
        self._local.put(catalog_key(step), manifest.to_json_bytes())
        with self._lock:
            self._manifest_cache[step] = manifest
        return sdir

    # -------------------------------------------------------------------- gc
    def local_footprint_bytes(self) -> int:
        return sum(_dir_size(d) for d in step_dirs(self.root).values())

    @contextlib.contextmanager
    def reading(self, step: int):
        """Context manager protecting ``step`` from GC while a restore
        reads its files (the background committer's auto-GC runs
        concurrently with restores)."""
        with self._lock:
            self._reading[step] = self._reading.get(step, 0) + 1
        try:
            yield
        finally:
            with self._lock:
                n = self._reading.get(step, 0) - 1
                if n <= 0:
                    self._reading.pop(step, None)
                else:
                    self._reading[step] = n

    def _protected(self, steps: Sequence[int]) -> Set[int]:
        with self._lock:
            protected = set(self._active) | set(self._mid_cascade) \
                | set(self._reading)
        protected |= self.pins()
        if steps:
            protected.add(max(steps))  # never delete the newest complete
        return protected

    def _orphan_age_s(self, step: int) -> float:
        """Seconds since the orphan's save started (marker timestamp, or
        the directory mtime for marker-less probe failures).

        Ages are clamped to >= 0: both sources are wall-clock, so a clock
        step backwards between the save and the GC sweep yields a negative
        difference — uncamped, that makes the orphan look *eternally
        fresh* relative to any grace window arithmetic built on top, or
        (worse, for large jumps) lets a live in-flight save age past the
        grace instantly when the clock steps forward again. A negative age
        means "the marker is from the future": the only safe reading is
        "this save just started" (age 0 → inside any grace window)."""
        age = None
        try:
            with open(self._marker_path(step)) as f:
                age = time.time() - float(f.read().strip())
        except (OSError, ValueError):
            try:
                age = time.time() - os.path.getmtime(self.step_dir(step))
            except OSError:
                return float("inf")
        if age < 0:
            logger.warning(
                "orphan step %d has a future-dated marker/mtime (%.3fs "
                "ahead): wall clock stepped backwards; treating the "
                "orphan as fresh (age 0)", step, -age)
            return 0.0
        return age

    def gc(self, *, include_orphans: bool = False, dry_run: bool = False,
           retention: Optional[RetentionPolicy] = None,
           orphan_grace_s: float = 0.0) -> GCReport:
        """Apply retention. Never touches the newest complete step, pinned
        steps, active saves, or steps still cascading.

        In-flight protection is process-local (``_active``); an admin
        process (the CLI) cannot see a live training job's active save,
        which looks exactly like a crash orphan. ``orphan_grace_s`` covers
        that: orphans younger than the grace window are left alone."""
        t0 = time.perf_counter()
        report = GCReport(dry_run=dry_run)
        steps = self.local_steps()
        protected = self._protected(self.steps())
        policy = retention or self.retention
        retained = policy.retained(steps) if policy else set(steps)
        # chain-aware: a kept delta step keeps its keyframe and every
        # intermediate delta — collecting any ancestor would orphan the
        # whole tail of the chain.
        retained = self.chain_closure(retained
                                      | (protected & set(steps)))
        for step in steps:
            if step in retained or step in protected:
                continue
            report.deleted_steps.append(step)
            report.bytes_freed += _dir_size(self.step_dir(step))
            if not dry_run:
                self._delete_local_step(step)
        if include_orphans:
            for step in self.orphans():
                if step in protected:
                    continue
                if orphan_grace_s and \
                        self._orphan_age_s(step) < orphan_grace_s:
                    continue
                report.deleted_orphans.append(step)
                report.bytes_freed += _dir_size(self.step_dir(step))
                if not dry_run:
                    self._delete_local_step(step)
        for tier in self.remote_tiers:
            if tier.retention is None:
                continue
            tsteps = self.tier_steps(tier)
            keep = self.chain_closure(
                tier.retention.retained(tsteps)
                | (self._protected(tsteps) & set(tsteps)))
            doomed = [s for s in tsteps if s not in keep]
            if doomed:
                report.remote_deleted[tier.name] = doomed
            if not dry_run:
                for s in doomed:
                    self._delete_tier_step(tier, s)
        t1 = time.perf_counter()
        report.seconds = t1 - t0
        if not dry_run:
            with self._lock:
                self.gc_log.append(report)
            obs_metrics.inc("repo.gc_bytes_freed", report.bytes_freed)
            obs.add_span("gc", t0, t1, bytes_freed=report.bytes_freed,
                         steps=len(report.deleted_steps))
        return report

    def _delete_local_step(self, step: int) -> None:
        # catalog entry first: the step disappears from the catalog before
        # its data does, so a crash mid-GC leaves an orphan, never a
        # committed step with missing files.
        for path in (self._entry_path(step), self._marker_path(step)):
            try:
                os.unlink(path)
            except FileNotFoundError:
                pass
        with self._lock:
            self._manifest_cache.pop(step, None)
        shutil.rmtree(self.step_dir(step), ignore_errors=True)

    def _delete_tier_step(self, tier: Tier, step: int) -> None:
        tier.backend.delete(catalog_key(step))  # invisible first
        for key in tier.backend.list(f"{step_dirname(step)}/"):
            tier.backend.delete(key)

    # ------------------------------------------------------------------ misc
    def drain(self) -> None:
        self.wait_cascaded()

    def close(self) -> None:
        if self._cascade_q is not None:
            self._cascade_q.put(None)
            if self._cascade_thread is not None:
                self._cascade_thread.join(timeout=60)
            self._cascade_q = None
            self._cascade_thread = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
