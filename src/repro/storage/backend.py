"""Pluggable storage backends for the tiered checkpoint repository.

A backend is a flat key→blob namespace (keys use ``/`` separators). Three
implementations cover the tiers the repository cares about:

* :class:`LocalBackend` — POSIX directory tree. Every ``put`` is atomic
  (temp file + ``os.replace``), so a control object (catalog entry, pin
  file) is visible iff it is complete, even across a crash.
* :class:`MemoryBackend` — an in-memory peer tier (models replicating a
  checkpoint into a peer node's RAM, TierCheck's first cascade hop) with an
  optional capacity bound.
* :class:`ObjectStoreBackend` — a simulated object store (S3-style): flat
  keys, multipart upload for large blobs, and configurable per-request
  latency plus bandwidth so cascade/tiering behavior is benchmarkable on a
  single box. Objects become visible only at ``complete_multipart`` /
  ``put`` time — never partially.

All backends are thread-safe: the cascade flusher writes from a background
thread while restores may read concurrently.
"""

from __future__ import annotations

import os
import shutil
import threading
import time
import uuid
from typing import Dict, List, Optional, Tuple

DEFAULT_PART_BYTES = 8 << 20


class BackendError(RuntimeError):
    """A storage-tier operation failed (missing key, capacity, bad upload)."""


class StorageBackend:
    """Abstract flat key→blob store; the unit the repository tiers over."""

    name = "base"
    supports_multipart = False

    # -- required primitives -------------------------------------------------
    def put(self, key: str, data: bytes) -> None:
        """Store ``data`` under ``key`` atomically (visible iff complete)."""
        raise NotImplementedError

    def get(self, key: str) -> bytes:
        raise NotImplementedError

    def delete(self, key: str) -> None:
        """Remove ``key``; missing keys are a no-op."""
        raise NotImplementedError

    def exists(self, key: str) -> bool:
        raise NotImplementedError

    def list(self, prefix: str = "") -> List[str]:
        """All keys starting with ``prefix``, sorted."""
        raise NotImplementedError

    def size(self, key: str) -> int:
        raise NotImplementedError

    # -- ranged reads --------------------------------------------------------
    def get_range(self, key: str, offset: int, nbytes: int) -> bytes:
        """Read ``nbytes`` of ``key`` starting at ``offset`` (slice
        semantics: short reads past EOF return the available tail).

        The fleet fabric's peer exchange is built on this — each replica
        pulls a disjoint slice of a shard file, so the default whole-blob
        fallback defeats the purpose; real tiers override it with a
        byte-accurate path (``pread``, HTTP ``Range``)."""
        return self.get(key)[offset:offset + nbytes]

    # -- file helpers (override where a cheaper path exists) -----------------
    def put_file(self, key: str, path: str,
                 part_bytes: int = DEFAULT_PART_BYTES) -> int:
        """Upload a local file; returns bytes transferred."""
        with open(path, "rb") as f:
            data = f.read()
        self.put(key, data)
        return len(data)

    def get_file(self, key: str, path: str) -> int:
        """Download ``key`` into ``path`` (atomic); returns bytes."""
        data = self.get(key)
        tmp = f"{path}.tmp-{os.getpid()}-{uuid.uuid4().hex[:8]}"
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, path)
        return len(data)

    def close(self) -> None:
        pass


# ---------------------------------------------------------------------------
class LocalBackend(StorageBackend):
    """POSIX directory tier: keys map to paths under ``root``."""

    name = "local"

    def __init__(self, root: str):
        self.root = os.path.abspath(root)
        os.makedirs(self.root, exist_ok=True)

    def _path(self, key: str) -> str:
        path = os.path.abspath(os.path.join(self.root, key))
        if not (path == self.root or path.startswith(self.root + os.sep)):
            raise BackendError(f"key {key!r} escapes backend root")
        return path

    def put(self, key: str, data: bytes) -> None:
        path = self._path(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = f"{path}.tmp-{os.getpid()}-{uuid.uuid4().hex[:8]}"
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, path)

    def get(self, key: str) -> bytes:
        try:
            with open(self._path(key), "rb") as f:
                return f.read()
        except FileNotFoundError as exc:
            raise BackendError(f"no such key {key!r}") from exc

    def delete(self, key: str) -> None:
        path = self._path(key)
        try:
            os.unlink(path)
        except FileNotFoundError:
            return
        # prune now-empty parent directories up to (not including) root
        parent = os.path.dirname(path)
        while parent != self.root:
            try:
                os.rmdir(parent)
            except OSError:
                break
            parent = os.path.dirname(parent)

    def exists(self, key: str) -> bool:
        return os.path.isfile(self._path(key))

    def list(self, prefix: str = "") -> List[str]:
        keys = []
        for dirpath, _dirs, files in os.walk(self.root):
            for fn in files:
                rel = os.path.relpath(os.path.join(dirpath, fn), self.root)
                key = rel.replace(os.sep, "/")
                if key.startswith(prefix):
                    keys.append(key)
        return sorted(keys)

    def size(self, key: str) -> int:
        try:
            return os.path.getsize(self._path(key))
        except OSError as exc:
            raise BackendError(f"no such key {key!r}") from exc

    def get_range(self, key: str, offset: int, nbytes: int) -> bytes:
        try:
            with open(self._path(key), "rb") as f:
                f.seek(offset)
                return f.read(nbytes)
        except FileNotFoundError as exc:
            raise BackendError(f"no such key {key!r}") from exc

    def put_file(self, key: str, path: str,
                 part_bytes: int = DEFAULT_PART_BYTES) -> int:
        dst = self._path(key)
        os.makedirs(os.path.dirname(dst), exist_ok=True)
        tmp = f"{dst}.tmp-{os.getpid()}-{uuid.uuid4().hex[:8]}"
        shutil.copyfile(path, tmp)
        os.replace(tmp, dst)
        return os.path.getsize(dst)

    def get_file(self, key: str, path: str) -> int:
        src = self._path(key)
        if not os.path.isfile(src):
            raise BackendError(f"no such key {key!r}")
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = f"{path}.tmp-{os.getpid()}-{uuid.uuid4().hex[:8]}"
        shutil.copyfile(src, tmp)
        os.replace(tmp, path)
        return os.path.getsize(path)


# ---------------------------------------------------------------------------
class MemoryBackend(StorageBackend):
    """In-memory peer tier (a peer node's RAM) with an optional capacity."""

    name = "memory"

    def __init__(self, capacity_bytes: Optional[int] = None):
        self.capacity = capacity_bytes
        self._blobs: Dict[str, bytes] = {}
        self._lock = threading.Lock()

    def used_bytes(self) -> int:
        with self._lock:
            return sum(len(b) for b in self._blobs.values())

    def put(self, key: str, data: bytes) -> None:
        data = bytes(data)
        with self._lock:
            if self.capacity is not None:
                used = sum(len(b) for k, b in self._blobs.items() if k != key)
                if used + len(data) > self.capacity:
                    raise BackendError(
                        f"memory tier full: {used + len(data)} B would "
                        f"exceed capacity {self.capacity} B")
            self._blobs[key] = data

    def get(self, key: str) -> bytes:
        with self._lock:
            try:
                return self._blobs[key]
            except KeyError as exc:
                raise BackendError(f"no such key {key!r}") from exc

    def delete(self, key: str) -> None:
        with self._lock:
            self._blobs.pop(key, None)

    def exists(self, key: str) -> bool:
        with self._lock:
            return key in self._blobs

    def list(self, prefix: str = "") -> List[str]:
        with self._lock:
            return sorted(k for k in self._blobs if k.startswith(prefix))

    def size(self, key: str) -> int:
        return len(self.get(key))

    def get_range(self, key: str, offset: int, nbytes: int) -> bytes:
        with self._lock:
            try:
                return self._blobs[key][offset:offset + nbytes]
            except KeyError as exc:
                raise BackendError(f"no such key {key!r}") from exc


# ---------------------------------------------------------------------------
class ObjectStoreBackend(StorageBackend):
    """Simulated object store: multipart upload + latency/bandwidth model.

    ``latency_s`` is added to every request (the per-request round trip of a
    remote store); ``bandwidth_mbps`` throttles payload transfer in both
    directions. Both default to "free" so tests run fast; benchmarks dial
    them in to model a throttled remote tier.

    The bandwidth model is a **shared pipe**: concurrent requests split the
    configured bandwidth, they do not each get a private copy of it. Each
    transfer reserves the next window on a single pipe timeline (a
    monotonic high-water mark advanced under the lock), so N concurrent
    readers of one checkpoint collectively finish no sooner than
    ``total_bytes / bandwidth`` — the contention the fleet-warmstart
    benchmark exists to measure. Latency stays per-request (round trips
    overlap across connections; bytes on the wire do not).
    """

    name = "object"
    supports_multipart = True

    def __init__(self, latency_s: float = 0.0,
                 bandwidth_mbps: Optional[float] = None,
                 part_bytes: int = DEFAULT_PART_BYTES):
        self.latency_s = latency_s
        self.bandwidth_mbps = bandwidth_mbps
        self.part_bytes = int(part_bytes)
        self._blobs: Dict[str, bytes] = {}
        self._uploads: Dict[str, Tuple[str, Dict[int, bytes]]] = {}
        self._lock = threading.Lock()
        self._pipe_free_at = 0.0  # monotonic time the shared pipe drains
        self.stats = {"n_requests": 0, "bytes_in": 0, "bytes_out": 0,
                      "n_multipart": 0}

    # -- simulation ----------------------------------------------------------
    def _simulate(self, nbytes: int, direction: str) -> None:
        done_at = None
        with self._lock:
            self.stats["n_requests"] += 1
            self.stats["bytes_in" if direction == "in" else "bytes_out"] \
                += nbytes
            if self.bandwidth_mbps and nbytes:
                # reserve this transfer's slot on the shared pipe; the
                # sleep itself happens outside the lock
                start = max(time.monotonic(), self._pipe_free_at)
                self._pipe_free_at = start \
                    + nbytes / (self.bandwidth_mbps * 1e6)
                done_at = self._pipe_free_at
        if done_at is not None:
            delay = (done_at - time.monotonic()) + self.latency_s
        else:
            delay = self.latency_s
        if delay > 0:
            time.sleep(delay)

    # -- blob API ------------------------------------------------------------
    def put(self, key: str, data: bytes) -> None:
        data = bytes(data)
        self._simulate(len(data), "in")
        with self._lock:
            self._blobs[key] = data

    def get(self, key: str) -> bytes:
        with self._lock:
            blob = self._blobs.get(key)
        if blob is None:
            self._simulate(0, "out")
            raise BackendError(f"no such key {key!r}")
        self._simulate(len(blob), "out")
        return blob

    def delete(self, key: str) -> None:
        self._simulate(0, "in")
        with self._lock:
            self._blobs.pop(key, None)

    def exists(self, key: str) -> bool:
        self._simulate(0, "out")
        with self._lock:
            return key in self._blobs

    def list(self, prefix: str = "") -> List[str]:
        self._simulate(0, "out")
        with self._lock:
            return sorted(k for k in self._blobs if k.startswith(prefix))

    def size(self, key: str) -> int:
        with self._lock:
            try:
                return len(self._blobs[key])
            except KeyError as exc:
                raise BackendError(f"no such key {key!r}") from exc

    def get_range(self, key: str, offset: int, nbytes: int) -> bytes:
        """HTTP ``Range``-style partial GET: only the requested slice
        crosses the (simulated) wire — the fleet's peer exchange depends
        on this being byte-accurate."""
        with self._lock:
            blob = self._blobs.get(key)
        if blob is None:
            self._simulate(0, "out")
            raise BackendError(f"no such key {key!r}")
        part = blob[offset:offset + nbytes]
        self._simulate(len(part), "out")
        return part

    # -- multipart upload ----------------------------------------------------
    def initiate_multipart(self, key: str) -> str:
        self._simulate(0, "in")
        upload_id = uuid.uuid4().hex
        with self._lock:
            self._uploads[upload_id] = (key, {})
            self.stats["n_multipart"] += 1
        return upload_id

    def upload_part(self, upload_id: str, part_number: int,
                    data: bytes) -> None:
        data = bytes(data)
        self._simulate(len(data), "in")
        with self._lock:
            if upload_id not in self._uploads:
                raise BackendError(f"unknown upload {upload_id!r}")
            self._uploads[upload_id][1][part_number] = data

    def complete_multipart(self, upload_id: str) -> None:
        """Assemble parts in part-number order; the key becomes visible
        only now — an aborted/crashed upload never surfaces a partial
        object."""
        self._simulate(0, "in")
        with self._lock:
            try:
                key, parts = self._uploads.pop(upload_id)
            except KeyError as exc:
                raise BackendError(f"unknown upload {upload_id!r}") from exc
            if not parts:
                raise BackendError(f"upload {upload_id!r} has no parts")
            self._blobs[key] = b"".join(parts[i] for i in sorted(parts))

    def abort_multipart(self, upload_id: str) -> None:
        self._simulate(0, "in")
        with self._lock:
            self._uploads.pop(upload_id, None)

    # -- file helpers --------------------------------------------------------
    def put_file(self, key: str, path: str,
                 part_bytes: Optional[int] = None) -> int:
        part = int(part_bytes or self.part_bytes)
        total = os.path.getsize(path)
        if total <= part:
            return super().put_file(key, path)
        upload_id = self.initiate_multipart(key)
        try:
            with open(path, "rb") as f:
                n = 0
                while True:
                    chunk = f.read(part)
                    if not chunk:
                        break
                    self.upload_part(upload_id, n, chunk)
                    n += 1
            self.complete_multipart(upload_id)
        except BaseException:
            self.abort_multipart(upload_id)
            raise
        return total
