"""Tiered checkpoint repository — durable, managed storage for checkpoints.

The paper's engine (``repro.core``) solves data *movement*: lazy device→host
capture into a pinned cache and streamlined async flush (§V-A). This package
solves data *residence* — where committed checkpoints live, how they stay
trustworthy, and how many of them exist — the paper's §VII future work
("multi-tier hierarchies beyond host memory", "integrity of persisted
state"), informed by two systems from PAPERS.md:

* **TierCheck** (arXiv 2605.17821): high-frequency checkpointing survives
  only if the fast tier drains somewhere durable. Our
  :class:`~repro.storage.repository.CheckpointRepository` extends the
  paper's device→host→file pipeline with an async **cascade flusher** that
  replicates committed steps from local NVMe-class storage to remote tiers
  (peer memory, object store) in the background, overlapped with training,
  and restores fall back tier-by-tier when the fast copy is gone.
* **ByteCheckpoint** (arXiv 2407.20143): checkpoints become manageable at
  fleet scale through a unified **catalog** over heterogeneous backends.
  Ours is a per-step manifest (file list, sizes, kernel-computed
  checksums, engine metadata) written atomically *after* all data files —
  a step is visible iff it is complete, so a crash mid-save can never be
  selected by ``latest_step()`` (the seed's resume-from-half-a-checkpoint
  bug is structurally impossible).

Layers:

``backend``     pluggable :class:`~repro.storage.backend.StorageBackend`
                tiers — local POSIX, in-memory peer, simulated object
                store with multipart upload + latency/bandwidth model;
``manifest``    per-step :class:`~repro.storage.manifest.StepManifest` +
                Pallas-checksum integrity + legacy completeness probe;
``repository``  the catalog, cascade flusher, retention GC
                (keep-last-N / keep-every-K / pins), tier-by-tier restore
                resolution;
``cli``         ``python -m repro.storage.cli {ls,verify,pin,unpin,gc}``.
"""

from .backend import (BackendError, LocalBackend, MemoryBackend,
                      ObjectStoreBackend, StorageBackend)
from .manifest import (FileEntry, ManifestError, RankManifest, StepManifest,
                       detect_format, file_checksum, probe_step_complete,
                       rank_manifest_name, read_rank_manifests)
from .repository import (CascadeEvent, CheckpointRepository, GCReport,
                         RetentionPolicy, Tier, VerifyResult,
                         committed_steps, orphan_steps)

__all__ = [
    "BackendError", "LocalBackend", "MemoryBackend", "ObjectStoreBackend",
    "StorageBackend",
    "FileEntry", "ManifestError", "RankManifest", "StepManifest",
    "detect_format", "file_checksum", "probe_step_complete",
    "rank_manifest_name", "read_rank_manifests",
    "CascadeEvent", "CheckpointRepository", "GCReport", "RetentionPolicy",
    "Tier", "VerifyResult", "committed_steps", "orphan_steps",
]
