"""Streaming (write-side) file checksums for the ``.dsllm`` format.

``storage.manifest.file_checksum`` hashes a finished file by re-reading it
in fixed 4 MiB chunks and folding the per-chunk position-weighted digests as
``sum((i+1) * digest_i) mod 2^32``. That read-back pass used to run on the
commit lane — every persisted byte crossed the page cache twice.

The whole construction is *linear over bytes at absolute file positions*: a
byte ``v`` at position ``p`` contributes exactly

    (p // CHUNK + 1) * (v << 8*(p % 4)) * weight((p % CHUNK) // 4)   mod 2^32

where ``weight(j) = WEIGHT_BASE + (j % WEIGHT_MOD)`` is the checksum
kernel's per-word weight and unwritten gaps read (and hash) as zeros. So a
writer that never overwrites a byte — ``layout.FileWriter``'s append
discipline: offsets are assigned once and the cursor only moves forward —
can accumulate the exact same checksum *while writing*, one
:meth:`StreamingFileChecksum.contribution` per pwrite, and the commit lane
reuses the result instead of re-reading the file.

``contribution`` is pure compute (safe outside any lock); folding it into
the running total is a single modular add the writer performs under its
existing append lock. No new lock is introduced.
"""

from __future__ import annotations

import numpy as np

from repro.kernels.checksum import WEIGHT_BASE, WEIGHT_MOD
from repro.storage.manifest import CHECKSUM_CHUNK_BYTES

_U32_MASK = 0xFFFFFFFF


class StreamingFileChecksum:
    """Incremental, write-order-independent ``file_checksum`` accumulator.

    Valid only when every byte is written at most once (zero-filled gaps are
    fine — zeros are digest-neutral). ``layout.FileWriter`` guarantees this
    by construction; anything that rewrites in place must fall back to the
    read-back :func:`repro.storage.manifest.file_checksum`.
    """

    def __init__(self, chunk_bytes: int = CHECKSUM_CHUNK_BYTES):
        assert chunk_bytes % 4 == 0
        self._chunk_words = chunk_bytes // 4
        self._total = 0

    @property
    def value(self) -> int:
        """The checksum of the file as written so far (== what
        ``file_checksum`` would return after re-reading it)."""
        return self._total

    def contribution(self, offset: int, data) -> int:
        """Checksum contribution of ``data`` written at absolute ``offset``.

        Pure compute — no accumulator state is touched, so callers can run
        it outside the writer lock and :meth:`fold` the result under it.
        """
        if isinstance(data, np.ndarray):
            b = np.ascontiguousarray(data).reshape(-1).view(np.uint8)
        else:
            b = np.frombuffer(memoryview(data), dtype=np.uint8)
        if b.size == 0:
            return 0
        # Align to u32 words: zero-pad the head (offset % 4) and the tail.
        head = offset % 4
        w0 = offset // 4
        pad_tail = (-(head + b.size)) % 4
        if head or pad_tail:
            b = np.concatenate([np.zeros(head, np.uint8), b,
                                np.zeros(pad_tail, np.uint8)])
        if not b.flags["C_CONTIGUOUS"] or b.ctypes.data % 4:
            b = b.copy()
        words = b.view(np.uint32).astype(np.uint64)
        w = w0 + np.arange(words.size, dtype=np.uint64)
        weight = WEIGHT_BASE + (w % self._chunk_words) % WEIGHT_MOD
        chunk_factor = w // self._chunk_words + 1
        # uint64 products/sums wrap mod 2^64, which is exact mod 2^32.
        total = int(np.sum(words * weight * chunk_factor, dtype=np.uint64))
        return total & _U32_MASK

    def fold(self, contribution: int) -> None:
        """Add one :meth:`contribution` — O(1); call under the writer lock."""
        self._total = (self._total + contribution) & _U32_MASK

    def update(self, offset: int, data) -> None:
        """``fold(contribution(offset, data))`` for single-threaded callers."""
        self.fold(self.contribution(offset, data))
