"""Per-step manifests: the crash-consistency unit of the repository.

A step is *committed* iff its manifest exists in the catalog. The manifest
is computed from the fully-persisted step directory (file list, sizes,
per-file integrity checksums via the Pallas kernel in
``repro.kernels.checksum``) and written atomically *last*, so a crash at
any earlier point leaves an invisible (orphaned) step instead of a
restorable-looking half checkpoint — ByteCheckpoint's catalog discipline.

Checksums reuse the save path's position-weighted u32 kernel: the file is
walked in fixed 4 MiB chunks (one jit trace total — the kernel shape never
changes), each chunk checksummed on device, and the chunk digests folded
order-sensitively, so block reorder/truncation within *and* across chunks
is caught.

Multi-rank saves add a second manifest layer — the *two-phase commit*.
Each writer rank persists its shard files, then writes a per-rank
:class:`RankManifest` (``rankNNNNN.manifest.json``, atomic tmp+rename):
the rank's phase-1 "prepared" vote, listing its files with sizes and
checksums computed on the rank's own lane. Only after every rank has
voted does the coordinator commit the global :class:`StepManifest` —
phase 2 — and :meth:`StepManifest.build` with ``expect_ranks=N``
cross-checks the votes first: all N rank manifests present, every
declared file on disk at its declared size, and no undeclared shard
files. A crash or straggler at any earlier point leaves a step with data
files (and possibly some votes) but no global manifest — invisible to
``latest_step``/restore/cascade, exactly like a single-writer crash
victim. Per-rank checksums are *reused* by the global manifest, so the
commit path never recomputes what the rank lanes already hashed in
parallel.

:func:`probe_step_complete` is the legacy-compatibility path: step
directories written before the repository existed have no manifest, so
eligibility falls back to a per-format completeness probe (``.dsllm``
trailer magic, snapshot chunk inventory, sync pickle parse).
"""

from __future__ import annotations

import dataclasses
import glob
import json
import os
import pickle
import re
import struct
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

MANIFEST_VERSION = 1
RANK_MANIFEST_VERSION = 1
NODE_MANIFEST_VERSION = 1
CHECKSUM_CHUNK_BYTES = 4 << 20
CHECKSUM_ALGO = "pallas-weighted-u32-chunk4m-v1"

_RANK_MANIFEST_RE = re.compile(r"^rank(\d+)\.manifest\.json$")
_NODE_MANIFEST_RE = re.compile(r"^node(\d+)\.manifest\.json$")

# Filenames that belong to the repository, not the checkpoint payload.
_CONTROL_SUFFIXES = (".tmp",)


class ManifestError(ValueError):
    """A manifest failed to build or validate (e.g. incomplete phase-1
    votes of a multi-rank save) — the step must not be committed."""


def file_checksum(path: str,
                  chunk_bytes: int = CHECKSUM_CHUNK_BYTES) -> int:
    """Position-weighted u32 checksum of a file's bytes (kernel-backed).

    Fixed-shape chunks keep the jit cache to a single trace; the chunk
    digests are combined as ``sum((i+1) * digest_i) mod 2^32`` so chunk
    reordering changes the result. The file length is recorded separately
    in the manifest, so zero-padding of the tail chunk is not a blind spot.

    Per-chunk digests dispatch through ``tensor_checksum_fast``: the Pallas
    kernel on a real TPU, its bit-identical NumPy oracle on host (interpret
    mode is a correctness harness, not a data path). Save lanes avoid this
    re-read entirely when the writer streamed the checksum
    (:mod:`repro.storage.file_format`); verify/audit paths call it on
    purpose — re-reading the bytes on disk is the point.
    """
    from repro.kernels import ops as kops  # deferred: jax import is heavy

    total = 0
    with open(path, "rb") as f:
        i = 0
        while True:
            buf = f.read(chunk_bytes)
            if not buf:
                break
            arr = np.frombuffer(buf, dtype=np.uint8)
            if len(arr) < chunk_bytes:
                arr = np.concatenate(
                    [arr, np.zeros(chunk_bytes - len(arr), np.uint8)])
            digest = kops.tensor_checksum_fast(arr)
            total = (total + (i + 1) * digest) % (1 << 32)
            i += 1
    return total


@dataclasses.dataclass(frozen=True)
class FileEntry:
    """One checkpoint file inside a step.

    ``codec`` records how the file's tensor payload is encoded
    (differential checkpointing): ``"raw"`` for full snapshots/keyframes,
    ``"xor+zstd"`` for delta files — chain-aware GC and ``cli verify``
    use it to tell chain roots from dependents. ``None`` for non-tensor
    files (votes, legacy formats).

    ``domains`` records which state domains the file carries and how each
    was routed — ``{"model": {"providers": ["tensor"], "codecs":
    ["raw"]}, "optimizer": {"providers": ["quantized"], ...}}`` — the
    per-file ``(domain, provider, codec)`` catalog entry that selective
    (per-domain) restore and fleet tooling read. ``None`` for files
    written before provider routing (or non-native formats)."""

    name: str
    nbytes: int
    checksum: Optional[int] = None
    codec: Optional[str] = None
    domains: Optional[Dict[str, Any]] = None


def dsllm_file_meta(path: str) -> Optional[Dict[str, Any]]:
    """Footer ``meta`` dict of one ``.dsllm`` file (written by the
    engine's file plan). ``None`` when unreadable."""
    try:
        from repro.core.layout import FileReader
        return FileReader(path).meta or {}
    except Exception:
        return None


def dsllm_file_codec(path: str) -> Optional[str]:
    """Tensor codec of one ``.dsllm`` file, from its footer meta.
    ``None`` when unreadable / not declared."""
    meta = dsllm_file_meta(path)
    d = (meta or {}).get("delta") or {}
    if not d:
        return None
    return "raw" if d.get("keyframe", True) else d.get("codec", "raw")


def rank_manifest_name(rank: int) -> str:
    return f"rank{rank:05d}.manifest.json"


@dataclasses.dataclass
class RankManifest:
    """One writer rank's phase-1 vote: "my shard files are durable".

    Written atomically (tmp + rename) by the rank itself after its engine
    reports persistence, *before* the rank acks the coordinator. Lists the
    rank's files with sizes and checksums — computed on the rank's lane,
    in parallel with the other ranks, so the global commit can reuse them
    instead of re-hashing the whole step serially.
    """

    rank: int
    world: int
    step: int
    files: List[FileEntry]
    checksum_algo: Optional[str] = None
    created_unix: float = 0.0
    version: int = RANK_MANIFEST_VERSION

    def to_json_bytes(self) -> bytes:
        d = dataclasses.asdict(self)
        d["files"] = [dataclasses.asdict(f) for f in self.files]
        return json.dumps(d, indent=1, sort_keys=True).encode()

    @classmethod
    def from_json_bytes(cls, data: bytes) -> "RankManifest":
        d = json.loads(data.decode())
        files = [FileEntry(**f) for f in d.pop("files", [])]
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(files=files, **{k: v for k, v in d.items() if k in known})

    @classmethod
    def build(cls, sdir: str, *, rank: int, world: int, step: int,
              filenames: List[str], checksum: bool = True,
              precomputed: Optional[Dict[str, int]] = None
              ) -> "RankManifest":
        """``precomputed`` maps basenames to checksums the rank's writers
        streamed while persisting (``FileWriter(track_checksum=True)``) —
        bit-identical to ``file_checksum`` by construction, so the vote
        reuses them instead of re-reading its own shard files."""
        files = []
        pre = precomputed or {}
        for n in sorted(filenames):
            path = os.path.join(sdir, n)
            if not checksum:
                csum = None
            elif n in pre:
                csum = int(pre[n])
            else:
                csum = file_checksum(path)
            files.append(FileEntry(
                name=n, nbytes=os.path.getsize(path), checksum=csum))
        return cls(rank=rank, world=world, step=step, files=files,
                   checksum_algo=CHECKSUM_ALGO if checksum else None,
                   created_unix=time.time())

    def write(self, sdir: str) -> str:
        """Atomic write (tmp + rename): the vote either exists complete or
        not at all — a crash mid-write never leaves a parseable vote."""
        from repro.core.layout import maybe_fsync
        path = os.path.join(sdir, rank_manifest_name(self.rank))
        tmp = f"{path}.tmp"
        with open(tmp, "wb") as f:
            f.write(self.to_json_bytes())
            f.flush()
            maybe_fsync(f.fileno())
        os.replace(tmp, path)
        return path


def read_rank_manifests(sdir: str) -> Dict[int, RankManifest]:
    """All parseable phase-1 votes in a step directory, keyed by rank."""
    out: Dict[int, RankManifest] = {}
    for n in sorted(os.listdir(sdir)):
        if not _RANK_MANIFEST_RE.match(n):
            continue
        try:
            with open(os.path.join(sdir, n), "rb") as f:
                rm = RankManifest.from_json_bytes(f.read())
        except (OSError, ValueError) as exc:
            raise ManifestError(f"unreadable rank manifest {n!r}: {exc}") \
                from exc
        out[rm.rank] = rm
    return out


def node_manifest_name(node: int) -> str:
    return f"node{node:05d}.manifest.json"


@dataclasses.dataclass
class NodeManifest:
    """One node-local aggregator's vote in the hierarchical commit tree.

    Written atomically by the node's aggregator (its lowest writer rank)
    only after *every* member rank of the node has cast its own phase-1
    :class:`RankManifest` vote — the node barrier completed. ``votes``
    lists the member rank-manifest files themselves (sizes + checksums),
    so the global committer can audit "this whole subtree prepared"
    against n_nodes small files instead of re-reading every rank's vote
    state: barrier fan-in and commit validation both scale O(nodes), not
    O(ranks). A node with a dead or stalled member never writes its
    manifest — the missing ``nodeNNNNN.manifest.json`` names the failed
    subtree.
    """

    node: int
    step: int
    world: int
    ranks: List[int]
    votes: List[FileEntry]
    checksum_algo: Optional[str] = None
    created_unix: float = 0.0
    version: int = NODE_MANIFEST_VERSION

    def to_json_bytes(self) -> bytes:
        d = dataclasses.asdict(self)
        d["votes"] = [dataclasses.asdict(v) for v in self.votes]
        return json.dumps(d, indent=1, sort_keys=True).encode()

    @classmethod
    def from_json_bytes(cls, data: bytes) -> "NodeManifest":
        d = json.loads(data.decode())
        votes = [FileEntry(**v) for v in d.pop("votes", [])]
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(votes=votes, **{k: v for k, v in d.items() if k in known})

    @classmethod
    def build(cls, sdir: str, *, node: int, ranks: List[int], step: int,
              world: int, checksum: bool = True) -> "NodeManifest":
        votes = []
        for r in sorted(ranks):
            path = os.path.join(sdir, rank_manifest_name(r))
            if not os.path.isfile(path):
                raise ManifestError(
                    f"step {step}: node {node} aggregating before rank "
                    f"{r} voted — {rank_manifest_name(r)!r} missing")
            votes.append(FileEntry(
                name=rank_manifest_name(r), nbytes=os.path.getsize(path),
                checksum=file_checksum(path) if checksum else None))
        return cls(node=node, step=step, world=world,
                   ranks=sorted(ranks), votes=votes,
                   checksum_algo=CHECKSUM_ALGO if checksum else None,
                   created_unix=time.time())

    def write(self, sdir: str) -> str:
        """Atomic write (tmp + rename), same discipline as the rank vote."""
        from repro.core.layout import maybe_fsync
        path = os.path.join(sdir, node_manifest_name(self.node))
        tmp = f"{path}.tmp"
        with open(tmp, "wb") as f:
            f.write(self.to_json_bytes())
            f.flush()
            maybe_fsync(f.fileno())
        os.replace(tmp, path)
        return path


def read_node_manifests(sdir: str) -> Dict[int, NodeManifest]:
    """All parseable node-aggregator votes in a step dir, keyed by node."""
    out: Dict[int, NodeManifest] = {}
    for n in sorted(os.listdir(sdir)):
        if not _NODE_MANIFEST_RE.match(n):
            continue
        try:
            with open(os.path.join(sdir, n), "rb") as f:
                nm = NodeManifest.from_json_bytes(f.read())
        except (OSError, ValueError) as exc:
            raise ManifestError(f"unreadable node manifest {n!r}: {exc}") \
                from exc
        out[nm.node] = nm
    return out


def _validate_node_votes(sdir: str, step: int, world: int,
                         nodes: Dict[int, Any], *,
                         checksum: bool = True) -> None:
    """Audit the hierarchical commit tree's node-aggregator layer: every
    node with writers wrote its manifest, covering exactly its member
    ranks' votes at the recorded sizes (and checksums when enabled). A
    failed subtree never writes its node manifest, so the missing/extra
    set names exactly which aggregator's collective broke."""
    expect = {int(nid): sorted(int(r) for r in ranks)
              for nid, ranks in nodes.items() if ranks}
    nms = read_node_manifests(sdir)
    missing = sorted(set(expect) - set(nms))
    if missing:
        raise ManifestError(
            f"step {step}: node manifests missing for nodes {missing} — "
            f"those aggregator subtrees never completed; refusing to "
            f"commit")
    extra = sorted(set(nms) - set(expect))
    if extra:
        raise ManifestError(
            f"step {step}: unexpected node manifests {extra} (expected "
            f"nodes {sorted(expect)}) — a foreign aggregator voted")
    for nid, nranks in expect.items():
        nm = nms[nid]
        if sorted(nm.ranks) != nranks or nm.world != world \
                or nm.step != step:
            raise ManifestError(
                f"step {step}: node manifest {nid} covers ranks "
                f"{sorted(nm.ranks)} (world {nm.world}, step {nm.step}); "
                f"coordinator expects ranks {nranks} of world {world}")
        for ve in nm.votes:
            path = os.path.join(sdir, ve.name)
            if not os.path.isfile(path) \
                    or os.path.getsize(path) != ve.nbytes:
                raise ManifestError(
                    f"step {step}: node {nid} recorded vote {ve.name!r} "
                    f"at {ve.nbytes} B but the file is missing or "
                    f"resized — a vote changed after aggregation")
            if checksum and ve.checksum is not None \
                    and file_checksum(path) != ve.checksum:
                raise ManifestError(
                    f"step {step}: vote {ve.name!r} checksum mismatch "
                    f"vs node {nid}'s aggregation — a vote was "
                    f"rewritten after the node collective")


@dataclasses.dataclass
class StepManifest:
    """Everything the catalog knows about one committed step."""

    step: int
    files: List[FileEntry]
    format: str = "unknown"            # dsllm | snapshot | sync | unknown
    engine_mode: Optional[str] = None
    checksum_algo: Optional[str] = None
    created_unix: float = 0.0
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    version: int = MANIFEST_VERSION

    @property
    def total_bytes(self) -> int:
        return sum(f.nbytes for f in self.files)

    def file(self, name: str) -> Optional[FileEntry]:
        for f in self.files:
            if f.name == name:
                return f
        return None

    # -- serialization -------------------------------------------------------
    def to_json_bytes(self) -> bytes:
        d = dataclasses.asdict(self)
        d["files"] = [dataclasses.asdict(f) for f in self.files]
        return json.dumps(d, indent=1, sort_keys=True).encode()

    @classmethod
    def from_json_bytes(cls, data: bytes) -> "StepManifest":
        d = json.loads(data.decode())
        files = [FileEntry(**f) for f in d.pop("files", [])]
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(files=files, **{k: v for k, v in d.items() if k in known})

    # -- construction --------------------------------------------------------
    @classmethod
    def build(cls, sdir: str, step: int, *, engine_mode: Optional[str] = None,
              checksum: bool = True,
              meta: Optional[Dict[str, Any]] = None,
              expect_ranks: Optional[int] = None,
              writers: Optional[Any] = None,
              nodes: Optional[Dict[int, Any]] = None) -> "StepManifest":
        """Scan a fully-persisted step directory into a manifest.

        With ``expect_ranks=N`` (a multi-rank save), the phase-1 votes are
        validated first: a rank manifest must be present for exactly the
        expected writer set (``writers`` — defaults to all N ranks; a
        coordinator that reassigned a dead rank's shard slice passes the
        surviving subset) and claim ``world == N``, every file a vote
        declares must be on disk at the declared size, and no undeclared
        shard file may exist. With ``nodes`` (``{node_id: [writer
        ranks]}``, the hierarchical commit tree), the node-aggregator
        votes are audited too: every node with writers must have written
        its ``nodeNNNNN.manifest.json`` covering exactly its member
        ranks' votes at the recorded sizes/checksums. Any violation
        raises :class:`ManifestError` — the commit fails and the step
        stays an invisible orphan. Checksums declared by the votes are
        reused, so the global commit never re-hashes payload bytes the
        rank lanes already hashed in parallel.
        """
        names = sorted(
            n for n in os.listdir(sdir)
            if os.path.isfile(os.path.join(sdir, n))
            and not any(s in n for s in _CONTROL_SUFFIXES))
        declared: Dict[str, FileEntry] = {}
        if expect_ranks is not None:
            writer_set = set(range(expect_ranks)) if writers is None \
                else {int(w) for w in writers}
            votes = read_rank_manifests(sdir)
            missing = sorted(writer_set - set(votes))
            if missing:
                raise ManifestError(
                    f"step {step}: rank manifests missing for ranks "
                    f"{missing} of writers {sorted(writer_set)} — not "
                    f"every writer prepared; refusing to commit")
            foreign = sorted(set(votes) - writer_set)
            if foreign:
                raise ManifestError(
                    f"step {step}: rank manifests from unexpected ranks "
                    f"{foreign} (writers: {sorted(writer_set)}) — a "
                    f"foreign or supposedly-dead writer voted; refusing "
                    f"to commit")
            for rank, rm in votes.items():
                if rm.world != expect_ranks:
                    raise ManifestError(
                        f"step {step}: rank manifest {rank} claims world "
                        f"{rm.world}, coordinator expects {expect_ranks}")
                for fe in rm.files:
                    path = os.path.join(sdir, fe.name)
                    if not os.path.isfile(path):
                        raise ManifestError(
                            f"step {step}: rank {rank} declared "
                            f"{fe.name!r} but it is not on disk")
                    if os.path.getsize(path) != fe.nbytes:
                        raise ManifestError(
                            f"step {step}: {fe.name!r} is "
                            f"{os.path.getsize(path)} B on disk, rank "
                            f"{rank} declared {fe.nbytes} B")
                    if fe.name in declared:
                        raise ManifestError(
                            f"step {step}: {fe.name!r} declared by two "
                            f"ranks — writer assignment broke the dedup "
                            f"invariant")
                    declared[fe.name] = fe
            undeclared = [n for n in names
                          if n not in declared
                          and not _RANK_MANIFEST_RE.match(n)
                          and not _NODE_MANIFEST_RE.match(n)]
            if undeclared:
                raise ManifestError(
                    f"step {step}: files {undeclared} present but not "
                    f"declared by any rank manifest — stale shards or a "
                    f"foreign writer; refusing to bless them")
            if nodes is not None:
                _validate_node_votes(sdir, step, expect_ranks, nodes,
                                     checksum=checksum)
        files = []
        # Per-file domain maps normally arrive from the engine's plan
        # (meta["file_domains"], popped below — never stored: the per-file
        # info lives on the FileEntry). Footer probes are the fallback
        # only, gated on the committer's meta: per-file codec matters only
        # for differential saves, per-file domain routing only for files
        # the engine map misses — re-parsing every footer on every commit
        # would tax the plain path for nothing.
        meta = dict(meta or {})
        file_domains: Dict[str, Any] = meta.pop("file_domains", None) or {}
        # writer-streamed per-file checksums (single-writer saves hand them
        # straight to the committer; multi-rank saves route them through
        # the rank votes instead) — popped, never stored: the per-file
        # value lives on the FileEntry
        file_checksums: Dict[str, int] = \
            meta.pop("file_checksums", None) or {}
        probe_codec = meta.get("delta") is not None
        probe_domains = meta.get("domains") is not None
        for n in names:
            path = os.path.join(sdir, n)
            fe = declared.get(n)
            if fe is not None and (fe.checksum is not None or not checksum):
                pass  # reuse the rank lane's hash
            elif checksum and n in file_checksums:
                fe = FileEntry(name=n, nbytes=os.path.getsize(path),
                               checksum=int(file_checksums[n]))
            else:
                fe = FileEntry(
                    name=n, nbytes=os.path.getsize(path),
                    checksum=file_checksum(path) if checksum else None)
            if fe.domains is None and n in file_domains:
                fe = dataclasses.replace(fe, domains=file_domains[n])
            if (probe_codec or (probe_domains and fe.domains is None)) \
                    and n.endswith(".dsllm") \
                    and (fe.codec is None or fe.domains is None):
                fmeta = dsllm_file_meta(path)
                repl: Dict[str, Any] = {}
                if probe_codec and fe.codec is None:
                    d = (fmeta or {}).get("delta") or {}
                    if d:
                        repl["codec"] = "raw" if d.get("keyframe", True) \
                            else d.get("codec", "raw")
                if probe_domains and fe.domains is None:
                    doms = (fmeta or {}).get("domains")
                    if doms:
                        repl["domains"] = doms
                if repl:
                    fe = dataclasses.replace(fe, **repl)
            files.append(fe)
        if expect_ranks is not None:
            meta = dict(meta or {})
            meta.setdefault("world", expect_ranks)
            if writers is not None and \
                    sorted(int(w) for w in writers) != \
                    list(range(expect_ranks)):
                # a partial writer set (dead ranks reassigned) is worth
                # recording: fleet tooling can see which saves ran degraded
                meta.setdefault("writers", sorted(int(w) for w in writers))
            if nodes is not None:
                meta.setdefault("nodes", {
                    str(nid): sorted(int(r) for r in ranks)
                    for nid, ranks in nodes.items()})
        return cls(step=step, files=files, format=detect_format(names),
                   engine_mode=engine_mode,
                   checksum_algo=CHECKSUM_ALGO if checksum else None,
                   created_unix=time.time(), meta=dict(meta or {}))


def detect_format(names) -> str:
    names = list(names)
    if any(n.endswith(".dsllm") for n in names):
        return "dsllm"
    if any(n.startswith("manifest_rank") and n.endswith(".pkl")
           for n in names):
        return "snapshot"
    if any(n.endswith(".pkl") for n in names):
        return "sync"
    return "unknown"


# ---------------------------------------------------------------------------
# Legacy completeness probe (pre-repository step directories).

_TRAILER = struct.Struct("<Q8s")


def _dsllm_trailer_ok(path: str) -> bool:
    from repro.core.layout import MAGIC
    try:
        size = os.path.getsize(path)
        if size < _TRAILER.size:
            return False
        with open(path, "rb") as f:
            f.seek(size - _TRAILER.size)
            footer_len, magic = _TRAILER.unpack(f.read(_TRAILER.size))
        return magic == MAGIC and footer_len <= size - _TRAILER.size
    except OSError:
        return False


# Probe results keyed by the directory's stat fingerprint (per-file name,
# size, mtime): the probe only ever runs on legacy pre-repository
# directories and crash victims, both effectively immutable — anything
# written through the repository carries a marker or a manifest and is
# classified without probing. A stat sweep is metadata-only, so the cache
# removes the expensive part (parsing multi-GB legacy pickles) from the
# committer thread, which re-scans the catalog after every commit.
# Bounded: one entry per step directory.
_probe_cache: Dict[str, Tuple[tuple, bool]] = {}
_probe_lock = threading.Lock()


def _dir_fingerprint(sdir: str) -> tuple:
    entries = []
    with os.scandir(sdir) as it:
        for e in it:
            try:
                st = e.stat()
            except OSError:
                continue
            entries.append((e.name, st.st_size, st.st_mtime_ns))
    return tuple(sorted(entries))


def probe_step_complete(sdir: str) -> bool:
    """Best-effort completeness check for a manifest-less step directory.

    * native: every ``*.dsllm`` file must end in a valid footer trailer
      (the engine writes footers last, so a crash victim fails this);
    * snapshot: every chunk referenced by every rank manifest must exist
      with the advertised size;
    * sync: every pickle must parse.

    Results are cached per directory stat fingerprint — ``committed_steps``
    runs after every commit, and re-parsing multi-GB legacy pickles each
    time would put the whole legacy directory's I/O on the committer
    thread.
    """
    if not os.path.isdir(sdir):
        return False
    path = os.path.abspath(sdir)
    try:
        fp = _dir_fingerprint(path)
    except OSError:
        return False
    with _probe_lock:
        cached = _probe_cache.get(path)
    if cached is not None and cached[0] == fp:
        return cached[1]
    result = _probe_step_complete_uncached(sdir)
    with _probe_lock:
        _probe_cache[path] = (fp, result)
    return result


def _probe_step_complete_uncached(sdir: str) -> bool:
    dsllm = glob.glob(os.path.join(sdir, "*.dsllm"))
    if dsllm:
        return all(_dsllm_trailer_ok(p) for p in dsllm)
    manifests = glob.glob(os.path.join(sdir, "manifest_rank*.pkl"))
    if manifests:
        try:
            for mpath in manifests:
                with open(mpath, "rb") as f:
                    manifest = pickle.load(f)
                for t in manifest["tensors"]:
                    for cpath, lo, hi in t["chunks"]:
                        if not os.path.exists(cpath):
                            cpath = os.path.join(
                                sdir, os.path.basename(cpath))
                        if not os.path.isfile(cpath) \
                                or os.path.getsize(cpath) != hi - lo:
                            return False
            return True
        except Exception:
            return False
    pkls = glob.glob(os.path.join(sdir, "*.pkl"))
    if pkls:
        for p in pkls:
            try:
                with open(p, "rb") as f:
                    pickle.load(f)
            except Exception:
                return False
        return True
    return False
