"""Repository operations CLI.

    python -m repro.storage.cli --root CKPT_DIR ls
    python -m repro.storage.cli --root CKPT_DIR verify [--step N] [--fast]
    python -m repro.storage.cli --root CKPT_DIR stats [--step N] [--fleet]
    python -m repro.storage.cli --root CKPT_DIR pin 1200
    python -m repro.storage.cli --root CKPT_DIR unpin 1200
    python -m repro.storage.cli --root CKPT_DIR gc --keep-last 3 \\
        [--keep-every K] [--orphans] [--dry-run]

Operates on the local tier's catalog (remote tiers are process-local
objects owned by the training job). ``verify`` re-audits committed steps
against their manifests and flags orphaned crash victims for GC; exit
status is non-zero when anything is wrong, so it can gate an automated
resume.
"""

from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from .repository import (CheckpointRepository, RetentionPolicy, orphan_steps,
                         _dir_size)


def _fmt_bytes(n: int) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if n < 1024 or unit == "GiB":
            return f"{n:.1f} {unit}" if unit != "B" else f"{n} B"
        n /= 1024
    return f"{n:.1f} GiB"


def _repo(args) -> CheckpointRepository:
    # Read/admin access only: no cascade thread, no auto-GC side effects.
    return CheckpointRepository(args.root, auto_cascade=False, auto_gc=False)


def cmd_ls(args) -> int:
    repo = _repo(args)
    pins = repo.pins()
    steps = repo.steps()
    if not steps:
        print(f"(no committed steps in {args.root})")
    for step in steps:
        if repo.has_manifest(step):
            m = repo.manifest(step)
            desc = (f"{len(m.files):3d} files  "
                    f"{_fmt_bytes(m.total_bytes):>10}  "
                    f"format={m.format}  engine={m.engine_mode or '-'}")
        else:
            desc = (f"{'?':>3} files  "
                    f"{_fmt_bytes(_dir_size(repo.step_dir(step))):>10}  "
                    f"legacy (no manifest)")
        pin = "  [pinned]" if step in pins else ""
        print(f"step {step:>10}  {desc}{pin}")
    orphans = repo.orphans()
    for step in orphans:
        print(f"step {step:>10}  ORPHAN (incomplete save — eligible for "
              f"`gc --orphans`)")
    return 0


def _chain_ancestors(repo: CheckpointRepository, step: int) -> List[int]:
    """Chain ancestors of a differential step (nearest base first), empty
    for keyframes / full snapshots. Lenient walk (the repository's
    shared one): an unreadable ancestor truncates the list — its direct
    dependent still gets flagged, via the not-committed check."""
    return list(reversed(repo.chain_steps(step)[:-1]))


def cmd_verify(args) -> int:
    repo = _repo(args)
    bad_steps = set()
    all_orphans = repo.orphans()
    committed = repo.steps()
    if args.step is not None:
        if args.step not in committed and args.step not in all_orphans:
            print(f"step {args.step}: NOT FOUND — no such step on any tier")
            return 1
        steps = [args.step] if args.step not in all_orphans else []
        # a differential step is only as trustworthy as its chain: pull
        # every ancestor into this audit too
        for b in _chain_ancestors(repo, args.step):
            if b in committed and b not in steps:
                steps.append(b)
        steps.sort()
    else:
        steps = committed
    for step in steps:
        if not repo.has_manifest(step):
            print(f"step {step}: legacy directory (no manifest) — "
                  f"probe only, no checksums")
            continue
        res = repo.verify_step(step, check_checksums=not args.fast)
        if res.ok:
            print(f"step {step}: OK ({len(repo.manifest(step).files)} files"
                  f"{', sizes only' if args.fast else ', checksums verified'})")
        else:
            bad_steps.add(step)
            print(f"step {step}: CORRUPT — {', '.join(res.problems)}")
    # Chain propagation: a delta step whose keyframe or any intermediate
    # delta is damaged/missing cannot be replayed — fail it too, even
    # though its own files are byte-perfect.
    for step in steps:
        if step in bad_steps:
            continue
        for b in _chain_ancestors(repo, step):
            if b in bad_steps or b in all_orphans or b not in committed:
                bad_steps.add(step)
                print(f"step {step}: CHAIN-BROKEN — delta depends on "
                      f"damaged or missing step {b}")
                break
    bad = len(bad_steps)
    orphans = 0
    for step in all_orphans:
        if args.step is not None and step != args.step:
            continue  # --step N audits N alone; unrelated orphans
                      # must not flip its exit status
        # Young orphans may be another process's live in-flight save
        # (in-flight protection is process-local); with a grace window
        # they are reported without failing the exit status.
        if args.orphan_grace and \
                repo._orphan_age_s(step) < args.orphan_grace:
            print(f"step {step}: in-flight or fresh orphan "
                  f"(younger than --orphan-grace; not counted)")
            continue
        orphans += 1
        print(f"step {step}: ORPHAN — incomplete save (no manifest); "
              f"flagged for GC (`gc --orphans`)")
    return 1 if bad or orphans else 0


def _cmd_stats_fleet(repo: CheckpointRepository, args) -> int:
    """Fleet warm-start ledger: per-step remote bytes served vs. bytes
    peer-exchanged between replicas, from ``.catalog/fleet-stats.json``
    (persisted by ``repro.fleet.FleetFabric``)."""
    import json
    import os
    path = os.path.join(repo.catalog_dir, "fleet-stats.json")
    try:
        with open(path) as f:
            ledger = json.load(f)
    except (OSError, ValueError):
        print(f"(no fleet transfer ledger in {args.root} — attach a "
              f"repro.fleet.FleetFabric and warm-start some replicas)")
        return 0
    steps = ledger.get("steps", {})
    if args.step is not None:
        steps = {k: v for k, v in steps.items() if int(k) == args.step}
        if not steps:
            print(f"step {args.step}: NOT FOUND — no fleet transfers "
                  f"recorded")
            return 1
    for k in sorted(steps, key=int):
        st = steps[k]
        remote = int(st.get("remote_bytes", 0))
        peer = int(st.get("peer_bytes", 0))
        total = remote + peer
        print(f"step {int(k):>10}  replicas={st.get('replicas', 0):<4} "
              f"remote={_fmt_bytes(remote):>10}  "
              f"peer={_fmt_bytes(peer):>10}  "
              f"peer_share={peer / total if total else 0.0:.2f}  "
              f"cache_hits={st.get('cache_hits', 0)}"
              f"{'  [delta]' if st.get('delta') else ''}")
    cache = ledger.get("cache") or {}
    if cache:
        print(f"cache: hits={cache.get('hits', 0)} "
              f"misses={cache.get('misses', 0)} "
              f"evictions={cache.get('evictions', 0)} "
              f"remote={_fmt_bytes(int(cache.get('remote_bytes', 0)))}")
    return 0


def cmd_stats(args) -> int:
    """Per-step save/commit timings, bytes by codec and domain, and delta
    chain depth — read back from ``StepManifest`` metadata only, so it
    works on any existing repository with no training process around."""
    repo = _repo(args)
    if getattr(args, "fleet", False):
        return _cmd_stats_fleet(repo, args)
    steps = repo.steps()
    if args.step is not None:
        if args.step not in steps:
            print(f"step {args.step}: NOT FOUND — no such committed step")
            return 1
        steps = [args.step]
    if not steps:
        print(f"(no committed steps in {args.root})")
        return 0
    for step in steps:
        if not repo.has_manifest(step):
            print(f"step {step:>10}  legacy directory (no manifest — "
                  f"no recorded stats)")
            continue
        m = repo.manifest(step)
        meta = m.meta or {}
        save = meta.get("save") or {}
        commit = meta.get("commit") or {}
        delta = meta.get("delta") or {}

        def _ms(key, src):
            v = src.get(key)
            return f"{v * 1e3:.1f}ms" if v is not None else "-"

        by_codec: dict = {}
        by_domain: dict = {}
        for fe in m.files:
            codec = fe.codec or "raw"
            by_codec[codec] = by_codec.get(codec, 0) + fe.nbytes
            doms = sorted(fe.domains) if fe.domains else []
            dkey = "+".join(doms) if doms else "-"
            by_domain[dkey] = by_domain.get(dkey, 0) + fe.nbytes
        chain = delta.get("chain_depth", 0) if delta else 0
        kind = "keyframe" if delta.get("keyframe", True) else \
            f"delta(base={delta.get('base_step')})"
        print(f"step {step:>10}  "
              f"persist={_ms('persist_s', save)}  "
              f"commit={_ms('persist_to_commit_s', save)}"
              f"+{_ms('build_s', commit)}  "
              f"blocking={_ms('blocking_s', save)}  "
              f"chain_depth={chain}"
              f"{'' if not delta else '  [' + kind + ']'}")
        for codec in sorted(by_codec):
            print(f"    codec  {codec:<12} {_fmt_bytes(by_codec[codec]):>10}")
        for dkey in sorted(by_domain):
            print(f"    domain {dkey:<12} {_fmt_bytes(by_domain[dkey]):>10}")
    return 0


def cmd_pin(args) -> int:
    _repo(args).pin(args.step)
    print(f"pinned step {args.step}")
    return 0


def cmd_unpin(args) -> int:
    _repo(args).unpin(args.step)
    print(f"unpinned step {args.step}")
    return 0


def cmd_gc(args) -> int:
    repo = _repo(args)
    policy = None
    if args.keep_last is not None or args.keep_every is not None:
        policy = RetentionPolicy(keep_last_n=args.keep_last,
                                 keep_every_k=args.keep_every)
    report = repo.gc(include_orphans=args.orphans, dry_run=args.dry_run,
                     retention=policy, orphan_grace_s=args.orphan_grace)
    verb = "would delete" if args.dry_run else "deleted"
    print(f"{verb} steps: {report.deleted_steps or '[]'}  "
          f"orphans: {report.deleted_orphans or '[]'}  "
          f"freed: {_fmt_bytes(report.bytes_freed)}  "
          f"({report.seconds * 1e3:.1f} ms)")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.storage.cli",
        description="Tiered checkpoint repository admin commands.")
    ap.add_argument("--root", required=True,
                    help="checkpoint directory (the repository's local tier)")
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("ls", help="list committed steps and orphans")
    p = sub.add_parser("verify",
                       help="audit steps against their manifests")
    p.add_argument("--step", type=int, default=None)
    p.add_argument("--fast", action="store_true",
                   help="sizes only, skip checksum recompute")
    p.add_argument("--orphan-grace", type=float, default=0.0,
                   metavar="SECONDS",
                   help="don't fail the exit status for orphans younger "
                        "than this (monitoring a live job: its in-flight "
                        "save looks like an orphan from outside; "
                        "default: 0 = strict, for post-crash audits)")
    p = sub.add_parser("stats",
                       help="per-step commit latency, bytes by codec/"
                            "domain, chain depth (from manifest metadata)")
    p.add_argument("--step", type=int, default=None)
    p.add_argument("--fleet", action="store_true",
                   help="fleet warm-start view: per-step remote bytes "
                        "served vs. peer-exchanged bytes (from the "
                        "fabric's .catalog/fleet-stats.json ledger)")
    p = sub.add_parser("pin", help="protect a step from GC")
    p.add_argument("step", type=int)
    p = sub.add_parser("unpin", help="remove a GC pin")
    p.add_argument("step", type=int)
    p = sub.add_parser("gc", help="apply retention / clean orphans")
    p.add_argument("--keep-last", type=int, default=None)
    p.add_argument("--keep-every", type=int, default=None)
    p.add_argument("--orphans", action="store_true",
                   help="also delete orphaned incomplete saves")
    p.add_argument("--orphan-grace", type=float, default=900.0,
                   metavar="SECONDS",
                   help="leave orphans younger than this alone — from "
                        "outside the training process an *in-flight* save "
                        "is indistinguishable from a crash victim "
                        "(default: 900)")
    p.add_argument("--dry-run", action="store_true")
    args = ap.parse_args(argv)
    return {"ls": cmd_ls, "verify": cmd_verify, "stats": cmd_stats,
            "pin": cmd_pin, "unpin": cmd_unpin, "gc": cmd_gc}[args.cmd](args)


if __name__ == "__main__":
    sys.exit(main())
