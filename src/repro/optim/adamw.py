"""AdamW with fp32 master weights — mixed-precision faithful to the paper.

The training state mirrors the paper's DeepSpeed/ZeRO-1 composition
(Table I): bf16 working params (the "model state") + fp32 master copies,
momentum and variance (the "optimizer state", ~4x the model bytes — the
checkpoint-volume-dominating part). Sharding of the optimizer state is
decided by :mod:`repro.sharding.partition` (ZeRO-1 over the ``data`` axis in
``tp_zero1`` mode; fully 2D-sharded in ``2d`` mode).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init_opt_state(params) -> Dict[str, Any]:
    """master: fp32 copy; m/v: fp32 zeros; step counter."""
    f32 = lambda t: jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32), t)
    zeros = lambda t: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return {"master": f32(params), "m": zeros(params), "v": zeros(params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_updates(params, opt_state, grads, hp: AdamWConfig
                  ) -> Tuple[Any, Dict[str, Any]]:
    """One AdamW step; returns (new bf16 params, new opt state)."""
    count = opt_state["count"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, hp.grad_clip / (gn + 1e-9))

    b1c = 1.0 - hp.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - hp.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = hp.b1 * m + (1 - hp.b1) * g
        v = hp.b2 * v + (1 - hp.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        new_master = master - hp.lr * (
            mhat / (jnp.sqrt(vhat) + hp.eps) + hp.weight_decay * master)
        return m, v, new_master

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_w = treedef.flatten_up_to(opt_state["master"])
    out = [upd(g, m, v, w) for g, m, v, w in
           zip(flat_g, flat_m, flat_v, flat_w)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_master = treedef.unflatten([o[2] for o in out])
    param_dtypes = jax.tree_util.tree_map(lambda x: x.dtype, params)
    new_params = jax.tree_util.tree_map(
        lambda w, dt: w.astype(dt), new_master, param_dtypes)
    return new_params, {"master": new_master, "m": new_m, "v": new_v,
                        "count": count}
