"""Production mesh construction.

Defined as functions (not module-level constants) so importing this module
never touches JAX device state. The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any JAX
import; everything else sees the real device count.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU training)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data)
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


# TPU v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
