"""Production mesh construction.

Defined as functions (not module-level constants) so importing this module
never touches JAX device state. The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any JAX
import; everything else sees the real device count.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax


def make_mesh(dims: Sequence[int], axes: Sequence[str]):
    """Version-compat ``jax.make_mesh``: only pass ``axis_types`` where it
    exists (``jax.sharding.AxisType`` appeared after 0.4.x; on older JAX
    the raw keyword raises ``AttributeError`` at call time)."""
    dims = tuple(dims)
    axes = tuple(axes)
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(dims, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(dims, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU training)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data)
    return make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
