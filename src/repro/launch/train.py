"""Training launcher.

Runs a (reduced or full) config with the two-phase lazy-checkpoint loop.
On this CPU container it is used with ``--smoke`` (reduced configs) — the
end-to-end driver for examples and the checkpointing benchmarks. On a real
TPU cluster the same entrypoint runs the full configs under
``make_production_mesh()``.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
        --steps 20 --ckpt-interval 5 --engine datastates --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family variant (CPU-sized)")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-interval", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--engine", default="datastates",
                    choices=["datastates", "datastates-old", "snapshot",
                             "sync"])
    ap.add_argument("--host-cache-mb", type=int, default=512)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--json", default=None, help="write iteration records")
    args = ap.parse_args(argv)

    from repro.configs import get_config, smoke_variant
    from repro.core import CheckpointManager
    from repro.training.loop import Trainer

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)

    manager = None
    if args.ckpt_interval:
        from repro.core import CheckpointPolicy, EnginePolicy
        manager = CheckpointManager.from_policy(
            args.ckpt_dir, CheckpointPolicy(engine=EnginePolicy(
                mode=args.engine,
                host_cache_bytes=args.host_cache_mb << 20)))
    trainer = Trainer(cfg, batch=args.batch, seq_len=args.seq_len,
                      manager=manager)
    if args.resume and manager is not None and manager.latest_step() is not None:
        step = trainer.resume()
        print(f"resumed from step {step}")

    t0 = time.perf_counter()
    records = trainer.run(args.steps, ckpt_interval=args.ckpt_interval)
    wall = time.perf_counter() - t0
    losses = [r.loss for r in records]
    stalls = [r.ckpt_stall_s for r in records]
    print(f"arch={cfg.name} steps={len(records)} wall={wall:.2f}s "
          f"final_loss={losses[-1]:.4f} "
          f"ckpt_stall_total={sum(stalls)*1e3:.1f}ms")
    assert all(np.isfinite(l) for l in losses), "NaN loss"
    if args.json:
        with open(args.json, "w") as f:
            json.dump([r.__dict__ for r in records], f, indent=2)
    if manager is not None:
        manager.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
