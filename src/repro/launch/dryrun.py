import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (device count locks on
# first backend init). Only the dry-run sees 512 placeholder devices.
if os.environ.get("REPRO_DRYRUN_DEVICES"):  # debugging escape hatch
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh)
combination against the production mesh, print memory/cost analysis, and
emit the roofline record consumed by EXPERIMENTS.md.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch llama3.2-1b --shape train_4k [--multi-pod] \
        [--mode 2d|tp_zero1] [--out experiments/dryrun/...json]
"""

import argparse
import dataclasses
import json
import sys
import time
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config
from repro.launch import analysis
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.models import model as M
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.serving.engine import cache_template, make_decode_step, \
    make_prefill_step
from repro.sharding import context as shctx
from repro.sharding.partition import (batch_pspecs, cache_pspecs, opt_pspecs,
                                      param_pspecs, shardings_for)
from repro.training.loop import make_train_step


def batch_template(cfg, shape) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input — no allocation."""
    B = shape.global_batch
    if shape.kind == "decode":
        S = 1
    else:
        S = shape.seq_len
    tshape = (B, S) + ((cfg.n_codebooks,) if cfg.n_codebooks else ())
    t: Dict[str, jax.ShapeDtypeStruct] = {
        "tokens": jax.ShapeDtypeStruct(tshape, jnp.int32)}
    if cfg.n_prefix_embeds and shape.kind != "decode":
        t["prefix_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_prefix_embeds, cfg.d_model), jnp.float32)
    if cfg.n_memory_embeds and shape.kind != "decode":
        t["memory_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_memory_embeds, cfg.d_model), jnp.float32)
    return t


def input_specs(cfg, shape, mesh) -> Tuple[Tuple, Tuple, Dict[str, Any]]:
    """(args, in_shardings, meta) for the step this shape lowers."""
    params_shape = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    pspec = param_pspecs(cfg, params_shape, mesh)
    pshard = shardings_for(pspec, mesh)
    params_sds = jax.tree_util.tree_map(
        lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sh),
        params_shape, pshard)
    bt = batch_template(cfg, shape)
    bspec = batch_pspecs(cfg, shape.kind, bt, mesh)
    bshard = {k: NamedSharding(mesh, s) for k, s in bspec.items()}
    batch_sds = {k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=bshard[k])
                 for k, v in bt.items()}

    if shape.kind == "train":
        opt_shape = jax.eval_shape(lambda p: init_opt_state(p), params_shape)
        ospec = opt_pspecs(cfg, params_shape, mesh)
        oshard = shardings_for(ospec, mesh)
        opt_sds = jax.tree_util.tree_map(
            lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                                 sharding=sh),
            opt_shape, oshard)
        args = (params_sds, opt_sds, batch_sds)
        shardings = (pshard, oshard, bshard)
        return args, shardings, {"step": "train"}

    if shape.kind == "prefill":
        return (params_sds, batch_sds), (pshard, bshard), {"step": "prefill"}

    # decode: one new token against a seq_len-deep cache
    long_ctx = shape.seq_len > 100_000
    ct = cache_template(cfg, shape.global_batch, shape.seq_len)
    cspec = cache_pspecs(cfg, ct, mesh, long_context=long_ctx)
    cshard = shardings_for(cspec, mesh)
    cache_sds = jax.tree_util.tree_map(
        lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sh),
        ct, cshard)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    args = (params_sds, batch_sds["tokens"], cache_sds, pos_sds)
    shardings = (pshard, bshard["tokens"], cshard, None)
    return args, shardings, {"step": "decode", "long_context": long_ctx}


def model_flops_global(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params."""
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq


def run_dryrun(arch: str, shape_name: str, *, multi_pod: bool = False,
               mode: str = "2d", donate: bool = True,
               overrides: Dict[str, Any] = None,
               verbose: bool = True) -> Dict[str, Any]:
    shape = INPUT_SHAPES[shape_name]
    # Single-pod runs unroll scans so cost_analysis counts true FLOPs (the
    # roofline table is single-pod only). Multi-pod runs prove the "pod"
    # axis lowers/compiles — no roofline — so they keep rolled scans, which
    # compiles several times faster on this 1-core container.
    unroll = not multi_pod
    kvb = min(4096, max(1024, shape.seq_len // 8))
    kw = {"sharding_mode": mode, "analysis_unroll": unroll,
          "attn_kv_block": kvb}
    kw.update(overrides or {})
    cfg = get_config(arch, **kw)
    record_overrides = dict(overrides or {})
    if shape.kind == "decode" and shape.seq_len > 100_000 \
            and not cfg.long_context_ok:
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "pure full-attention architecture; long_500k "
                          "requires sub-quadratic attention (DESIGN.md §4)"}
    debug_mesh = os.environ.get("REPRO_DRYRUN_MESH")
    if debug_mesh:  # e.g. "4,4" or "2,4,4" — small-scale debugging only
        dims = tuple(int(x) for x in debug_mesh.split(","))
        axes = ("pod", "data", "model")[-len(dims):]
        mesh = make_mesh(dims, axes)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(mesh.devices.shape))
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mode": mode,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "axes": list(mesh.axis_names), "n_devices": n_dev,
        "overrides": record_overrides,
    }
    t0 = time.time()
    with shctx.activate(mesh):
        long_ctx = (shape.kind == "decode" and shape.seq_len > 100_000)
        shctx.set_seq_axis("data" if long_ctx else None)
        shctx.set_batch_axes(("data", "model") if mode == "fsdp" else None)
        try:
            args, in_shardings, meta = input_specs(cfg, shape, mesh)
            record.update(meta)
            if shape.kind == "train":
                step = make_train_step(cfg, AdamWConfig())
                donate_argnums = (0, 1) if donate else ()
            elif shape.kind == "prefill":
                step = make_prefill_step(cfg)
                donate_argnums = ()
            else:
                step = make_decode_step(cfg)
                donate_argnums = (2,) if donate else ()
            jitted = jax.jit(step, in_shardings=in_shardings,
                             donate_argnums=donate_argnums)
            lowered = jitted.lower(*args)
            record["lower_s"] = time.time() - t0
            t1 = time.time()
            compiled = lowered.compile()
            record["compile_s"] = time.time() - t1
            hlo = compiled.as_text()
            record["roofline"] = analysis.roofline(
                compiled, n_devices=n_dev,
                model_flops_global=model_flops_global(cfg, shape),
                hlo_text=hlo)
            record["n_params"] = cfg.n_params()
            record["n_active_params"] = cfg.n_active_params()
            if verbose:
                mem = record["roofline"]["memory"]
                print(f"[{arch} × {shape_name} × {record['mesh']}] "
                      f"compile={record['compile_s']:.1f}s")
                print("  memory_analysis:", json.dumps(mem))
                print("  cost_analysis terms:",
                      json.dumps(record["roofline"]["terms"]))
                print("  dominant:", record["roofline"]["dominant"],
                      f"useful_flops_ratio="
                      f"{record['roofline']['useful_flops_ratio']:.3f}")
        finally:
            shctx.set_seq_axis(None)
            shctx.set_batch_axes(None)
    return record


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=sorted(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", default="2d",
                    choices=["2d", "tp_zero1", "fsdp"])
    ap.add_argument("--no-donate", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--set", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="ModelConfig override, e.g. --set attn_kv_block=2048"
                         " --set remat=false (repeatable)")
    args = ap.parse_args(argv)
    overrides: Dict[str, Any] = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        if v.lower() in ("true", "false"):
            overrides[k] = v.lower() == "true"
        else:
            try:
                overrides[k] = int(v)
            except ValueError:
                try:
                    overrides[k] = float(v)
                except ValueError:
                    overrides[k] = v
    rec = run_dryrun(args.arch, args.shape, multi_pod=args.multi_pod,
                     mode=args.mode, donate=not args.no_donate,
                     overrides=overrides)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=2)
        print("wrote", args.out)
    if rec.get("skipped"):
        print(f"SKIPPED: {rec['reason']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
