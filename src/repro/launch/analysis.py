"""Compiled-artifact analysis: cost, memory, collective bytes, roofline.

The dry-run cannot time anything (CPU container, TPU target), so the perf
report is derived from the compiled HLO exactly as the brief specifies:

    compute term    = HLO_FLOPs(per device) / peak_FLOP/s
    memory term     = HLO_bytes(per device) / HBM_bw
    collective term = collective_bytes(per device) / link_bw

``cost_analysis()`` reports the per-device (SPMD) module. Collective bytes
are parsed from the optimized HLO text: we sum the *result* shape bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (including ``-start`` async forms, excluding ``-done``).
"""

from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def shape_bytes(type_str: str) -> int:
    """Bytes of one HLO result type (handles tuples by summing)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Per-device collective traffic from optimized HLO text."""
    per_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        # result types precede the op name: "%x = TYPE op-name(...)"
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(",
                     line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        base = op
        if base.endswith("-start"):
            base = base[:-6]
        if base in _COLLECTIVES:
            per_kind[base] += shape_bytes(type_str)
            counts[base] += 1
    total = sum(per_kind.values())
    return {"bytes_per_device": total, "by_kind": per_kind, "counts": counts}


def cost_dict(compiled) -> Dict[str, float]:
    c = compiled.cost_analysis()
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    return dict(c or {})


def memory_dict(compiled) -> Dict[str, int]:
    out = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                out[k] = int(v)
    except Exception as e:  # noqa: BLE001
        out["error"] = str(e)
    return out


def roofline(compiled, *, n_devices: int, model_flops_global: float,
             hlo_text: Optional[str] = None) -> Dict[str, Any]:
    cost = cost_dict(compiled)
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)

    t_compute = flops_dev / PEAK_FLOPS_BF16
    t_memory = bytes_dev / HBM_BW
    t_collective = coll["bytes_per_device"] / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    dominant = max(terms, key=terms.get)
    mem = memory_dict(compiled)
    # Lower-bound memory term: guaranteed HBM traffic = read live args once +
    # write outputs once (donated aliases counted once). The cost-analysis
    # term above is an upper bound — XLA:CPU emulates bf16 by materializing
    # f32 converts that a TPU build never emits, inflating "bytes accessed".
    lb_bytes = (mem.get("argument_size_in_bytes", 0)
                + mem.get("output_size_in_bytes", 0)
                - mem.get("alias_size_in_bytes", 0))
    terms["memory_lb_s"] = max(lb_bytes, 0) / HBM_BW
    hlo_flops_global = flops_dev * n_devices
    return {
        "per_device": {"flops": flops_dev, "bytes": bytes_dev,
                       "collective_bytes": coll["bytes_per_device"]},
        "collectives": coll,
        "terms": terms,
        "dominant": dominant,
        "bound_s": terms[dominant],
        "model_flops_global": model_flops_global,
        "hlo_flops_global": hlo_flops_global,
        "useful_flops_ratio": (model_flops_global / hlo_flops_global
                               if hlo_flops_global else 0.0),
        "memory": memory_dict(compiled),
        "hw": {"peak_flops": PEAK_FLOPS_BF16, "hbm_bw": HBM_BW,
               "ici_bw": ICI_BW, "n_devices": n_devices},
    }
