"""Quantize-pack kernels for compressed checkpoints (Pallas TPU).

Two codecs used by the differential/compressed checkpoint path (the paper's
stated future work, implemented here as a beyond-paper feature):

* fp32 → bf16 downcast (2× smaller optimizer-state snapshots);
* fp32 → int8 blockwise symmetric quantization: each (ROWS, COLS) tile gets a
  per-row scale = max|x|/127 and values round to int8 (4× smaller).

Tiles are (256, 256) fp32 = 256 KiB in / 64-128 KiB out per grid step —
VMEM-friendly, lane-dim 256 = 2×128 (hardware-aligned).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 256
COLS = 256


def _downcast_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].astype(jnp.bfloat16)


def downcast_bf16(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    """x: (R, C) fp32, R % ROWS == 0, C % COLS == 0 -> (R, C) bf16."""
    R, C = x.shape
    assert R % ROWS == 0 and C % COLS == 0
    grid = (R // ROWS, C // COLS)
    return pl.pallas_call(
        _downcast_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS, COLS), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((ROWS, COLS), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, C), jnp.bfloat16),
        interpret=interpret,
    )(x)


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...]
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    q_ref[...] = q
    s_ref[...] = scale


def quantize_int8(x: jax.Array, *, interpret: bool = True):
    """x: (R, C) fp32 -> (int8 (R, C), scales (R, 1) fp32), per-row symmetric."""
    R, C = x.shape
    assert R % ROWS == 0 and C % COLS == 0 and C == COLS, \
        "per-row scales require a single column tile"
    grid = (R // ROWS,)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS, COLS), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((ROWS, COLS), lambda i: (i, 0)),
                   pl.BlockSpec((ROWS, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((R, C), jnp.int8),
                   jax.ShapeDtypeStruct((R, 1), jnp.float32)],
        interpret=interpret,
    )(x)


def _dequant_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]


def dequantize_int8(q: jax.Array, scales: jax.Array, *,
                    interpret: bool = True) -> jax.Array:
    R, C = q.shape
    grid = (R // ROWS,)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS, COLS), lambda i: (i, 0)),
                  pl.BlockSpec((ROWS, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ROWS, COLS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), jnp.float32),
        interpret=interpret,
    )(q, scales)
