"""Pallas TPU kernels for checkpoint-path compute hot-spots.

The paper's core contribution is I/O-side, so the checkpoint engine itself is
pure host/JAX code. These kernels implement the *device-side* compute the
paper defers to future work (integrity verification, data reduction for
checkpoints) plus the attention hot-spot of the model zoo:

* ``flash_attention`` — TPU twin of the pure-XLA blocked attention in
  ``repro.models.layers`` (MXU-tiled, VMEM-resident blocks).
* ``checksum`` — blocked integrity checksum over tensor shards, computed on
  device before staging so corrupted transfers are detectable.
* ``quantize`` — fp32→bf16/int8 quantize-pack for compressed checkpoints.
* ``delta`` — differential checkpointing: subtract/XOR vs previous snapshot.
* ``fused`` — the one-pass encode/decode pipeline: each encoded route
  (XOR delta, int8 quantize) emits its payload *and* integrity digest in a
  single kernel invocation per chunk, reading the staged bytes exactly once.

Each has a jit'd wrapper in :mod:`repro.kernels.ops` (with
``interpret=True`` fallback on CPU) and a pure-NumPy/jnp oracle in
:mod:`repro.kernels.ref`; tests sweep shapes/dtypes against the oracle, and
``tests/test_fused_kernels.py`` proves the fused kernels bit-identical to
the legacy multi-pass composition before the engine trusts either.
"""

from . import fused, ops, ref

__all__ = ["fused", "ops", "ref"]
