"""Flash attention kernel (Pallas TPU) — the model zoo's compute hot-spot.

TPU-native adaptation of the FlashAttention blocking scheme: the grid is
(batch·heads, q-blocks, kv-blocks); the last grid dimension is sequential on
TPU, so the online-softmax state (row max m, row sum l, accumulator acc)
lives in VMEM scratch across kv steps. Block shapes are MXU-aligned
(q_block × head_dim and kv_block × head_dim tiles, lane dim = head_dim,
sublane = block rows; defaults 256×128 fp32 = 128 KiB per operand tile).

Supports causal "full", sliding-"window" and "chunked" (block-local) masks —
the three attention variants in the assigned architectures. GQA is handled
by the wrapper (`ops.flash_attention`) which folds the group dim into heads.

Validated in interpret mode against ``ref.flash_attention_ref`` (and against
``repro.models.layers.blocked_sdpa``, the pure-XLA production path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Q_BLOCK = 256
KV_BLOCK = 256
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  kind: str, window: int, chunk: int, scale: float,
                  kv_block: int, q_block: int, n_kv: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale            # (qb, hd)
    k = k_ref[0].astype(jnp.float32)                    # (kvb, hd)
    v = v_ref[0].astype(jnp.float32)
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)             # (qb, kvb)

    qpos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32,
                                                   logits.shape, 0)
    kpos = kj * kv_block + jax.lax.broadcasted_iota(jnp.int32,
                                                    logits.shape, 1)
    mask = kpos <= qpos
    if kind == "window":
        mask &= kpos > qpos - window
    elif kind == "chunked":
        mask &= (qpos // chunk) == (kpos // chunk)
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)
    p = jnp.where(mask, p, 0.0)
    l_new = l_prev * alpha + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kj == n_kv - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / (l_scr[...] + 1e-30)).astype(o_ref.dtype)


def flash_attention_bh(q, k, v, *, kind: str = "full", window: int = 0,
                       chunk: int = 0, q_block: int = Q_BLOCK,
                       kv_block: int = KV_BLOCK,
                       interpret: bool = True) -> jax.Array:
    """q: (BH, S, hd); k/v: (BH, T, hd) — batch and heads pre-folded."""
    BH, S, hd = q.shape
    T = k.shape[1]
    qb = min(q_block, S)
    kvb = min(kv_block, T)
    assert S % qb == 0 and T % kvb == 0
    n_q = S // qb
    n_kv = T // kvb
    grid = (BH, n_q, n_kv)
    kernel = functools.partial(
        _flash_kernel, kind=kind, window=window, chunk=chunk,
        scale=1.0 / (hd ** 0.5), kv_block=kvb, q_block=qb, n_kv=n_kv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, qb, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kvb, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kvb, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, 1), jnp.float32),
            pltpu.VMEM((qb, 1), jnp.float32),
            pltpu.VMEM((qb, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
