"""Blocked integrity checksum kernel (Pallas TPU).

Computes a position-weighted modular checksum over a flat u32 view of a
tensor shard: ``sum_i (x_i * (a + i mod M)) mod 2^32``. Position weighting
catches reordered blocks, which a plain sum would miss. The grid walks
VMEM-sized blocks of the flattened input; each step accumulates into a (1,1)
SMEM-resident partial in the output ref (grid iterations on TPU are
sequential, so the accumulation is race-free).

VMEM budget: BLOCK u32 elements (default 64k = 256 KiB) — comfortably inside
the ~16 MiB/core VMEM with room for double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 65_536          # u32 elements per grid step (256 KiB VMEM)
WEIGHT_MOD = 65_521     # largest prime < 2^16 (adler-style)
WEIGHT_BASE = 65_599


def _checksum_kernel(x_ref, out_ref):
    step = pl.program_id(0)
    x = x_ref[...].astype(jnp.uint32)
    n = x.shape[0]
    idx = (jax.lax.iota(jnp.uint32, n)
           + jnp.uint32(step) * jnp.uint32(n))
    w = jnp.uint32(WEIGHT_BASE) + (idx % jnp.uint32(WEIGHT_MOD))
    partial = jnp.sum(x * w, dtype=jnp.uint32)

    @pl.when(step == 0)
    def _init():
        out_ref[0, 0] = jnp.uint32(0)

    out_ref[0, 0] = out_ref[0, 0] + partial


def checksum_u32(x_flat_u32: jax.Array, *, block: int = BLOCK,
                 interpret: bool = True) -> jax.Array:
    """x_flat_u32: 1-D uint32 (pre-padded to a multiple of ``block``)."""
    n = x_flat_u32.shape[0]
    assert n % block == 0, f"pad input to a multiple of {block}"
    grid = (n // block,)
    return pl.pallas_call(
        _checksum_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.uint32),
        interpret=interpret,
    )(x_flat_u32)[0, 0]
