"""One-pass fused encode/decode kernels (Pallas TPU).

The encode lane used to walk every staged chunk once per codec stage:
``delta.py`` XORed, ``quantize.py`` quantized, ``checksum.py`` hashed — three
kernel launches, three reads of bytes that are immutable for the whole save.
These kernels collapse each encoded route into a single pallas_call per chunk
that reads the staged bytes exactly once and emits the encoded payload *and*
its integrity digest together:

* ``xor_checksum_u32``       — delta = cur ^ prev, plus the position-weighted
  checksum of the delta words (the stored payload), in one pass over cur.
* ``xor_fold_checksum_u32``  — the symmetric decode: folded = base ^ delta,
  plus the checksum of the *incoming* delta words, so chain replay verifies
  each payload while applying it (one read of the delta).
* ``quantize_checksum_int8`` — per-row int8 quantization (same math as
  ``quantize.py``) plus the checksum of the packed ``int8q`` payload area the
  kernel produces (scale words + little-endian-packed q words at their final
  payload word positions); the 8-byte header's contribution is two scalar
  terms added host-side.
* ``dequantize_checksum_int8`` — the symmetric decode: dequantize and digest
  the payload in one read of the q words.

Digest convention: every digest is the ``checksum.py`` position-weighted
modular sum over the uncompressed payload's little-endian u32 words —
``sum_i payload_u32[i] * (BASE + i mod M) mod 2^32`` — so a fused digest is
bit-identical to ``checksum_u32`` over the packed payload bytes. Zero words
contribute zero, which makes block padding (and the zero-padded q rows of a
partial tile) digest-neutral; padded *scale* rows are not in the payload and
are masked out explicitly.

Grid iterations on TPU are sequential, so the (1,1) digest accumulator in the
output ref is race-free (same idiom as ``checksum.py``). VMEM budget per grid
step: one 256 KiB u32 slab per input for the XOR kernels; a (256, 256) fp32
tile + int8/scale outputs for the quantize kernels.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .checksum import BLOCK, WEIGHT_BASE, WEIGHT_MOD
from .quantize import COLS, ROWS

# int8q payload layout (see core/codecs.py): u32 n_rows | u32 raw_nbytes |
# f32 scales[n_rows] | i8 q[n_rows * 256]. Word indices below are positions
# within that payload's u32 view.
PAYLOAD_HEADER_WORDS = 2


def _weights(idx_u32):
    return jnp.uint32(WEIGHT_BASE) + (idx_u32 % jnp.uint32(WEIGHT_MOD))


def _accumulate(dig_ref, partial):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        dig_ref[0, 0] = jnp.uint32(0)

    dig_ref[0, 0] = dig_ref[0, 0] + partial


# --------------------------------------------------------------- delta route
def _xor_checksum_kernel(a_ref, b_ref, o_ref, dig_ref):
    step = pl.program_id(0)
    delta = jax.lax.bitwise_xor(a_ref[...], b_ref[...])
    o_ref[...] = delta
    n = delta.shape[0]
    idx = jax.lax.iota(jnp.uint32, n) + jnp.uint32(step) * jnp.uint32(n)
    _accumulate(dig_ref, jnp.sum(delta * _weights(idx), dtype=jnp.uint32))


def xor_checksum_u32(cur_u32: jax.Array, prev_u32: jax.Array, *,
                     block: int = BLOCK, interpret: bool = True):
    """(delta, digest-of-delta) in one read of ``cur``/``prev``."""
    n = cur_u32.shape[0]
    assert n % block == 0 and cur_u32.shape == prev_u32.shape
    grid = (n // block,)
    return pl.pallas_call(
        _xor_checksum_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                   pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.uint32),
                   jax.ShapeDtypeStruct((1, 1), jnp.uint32)],
        interpret=interpret,
    )(cur_u32, prev_u32)


def _xor_fold_checksum_kernel(base_ref, d_ref, o_ref, dig_ref):
    step = pl.program_id(0)
    delta = d_ref[...]
    o_ref[...] = jax.lax.bitwise_xor(base_ref[...], delta)
    n = delta.shape[0]
    idx = jax.lax.iota(jnp.uint32, n) + jnp.uint32(step) * jnp.uint32(n)
    _accumulate(dig_ref, jnp.sum(delta * _weights(idx), dtype=jnp.uint32))


def xor_fold_checksum_u32(base_u32: jax.Array, delta_u32: jax.Array, *,
                          block: int = BLOCK, interpret: bool = True):
    """(base ^ delta, digest-of-delta): verify the payload while applying."""
    n = base_u32.shape[0]
    assert n % block == 0 and base_u32.shape == delta_u32.shape
    grid = (n // block,)
    return pl.pallas_call(
        _xor_fold_checksum_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                   pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.uint32),
                   jax.ShapeDtypeStruct((1, 1), jnp.uint32)],
        interpret=interpret,
    )(base_u32, delta_u32)


# ------------------------------------------------------------ int8q route
def _payload_digest_tile(q, scale, n_rows: int):
    """Digest contribution of one (ROWS, COLS) tile's payload words.

    q words: 4 consecutive int8 lanes pack little-endian into the u32 word
    at payload index ``2 + n_rows + row * COLS//4 + word``; zero-padded rows
    quantize to q == 0 and contribute nothing. Scale words sit at payload
    index ``2 + row`` and exist only for live rows (padding is masked).
    """
    step = pl.program_id(0)
    rows, cols = q.shape
    words_per_row = cols // 4
    row0 = jnp.uint32(step) * jnp.uint32(rows)
    qu = jax.lax.bitcast_convert_type(q, jnp.uint8).astype(jnp.uint32)
    qw = qu.reshape(rows, words_per_row, 4)
    lane = jax.lax.broadcasted_iota(jnp.uint32, (rows, words_per_row, 4), 2)
    words = jnp.sum(jnp.left_shift(qw, lane * jnp.uint32(8)), axis=-1,
                    dtype=jnp.uint32)
    r_iota = jax.lax.broadcasted_iota(jnp.uint32, (rows, words_per_row), 0)
    c_iota = jax.lax.broadcasted_iota(jnp.uint32, (rows, words_per_row), 1)
    q_idx = (jnp.uint32(PAYLOAD_HEADER_WORDS + n_rows)
             + (row0 + r_iota) * jnp.uint32(words_per_row) + c_iota)
    partial = jnp.sum(words * _weights(q_idx), dtype=jnp.uint32)

    sbits = jax.lax.bitcast_convert_type(scale, jnp.uint32)       # (rows, 1)
    s_rows = row0 + jax.lax.broadcasted_iota(jnp.uint32, (rows, 1), 0)
    live = s_rows < jnp.uint32(n_rows)
    s_term = jnp.where(live,
                       sbits * _weights(jnp.uint32(PAYLOAD_HEADER_WORDS)
                                        + s_rows),
                       jnp.uint32(0))
    return partial + jnp.sum(s_term, dtype=jnp.uint32)


def _quant_checksum_kernel(x_ref, q_ref, s_ref, dig_ref, *, n_rows: int):
    x = x_ref[...]
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    q_ref[...] = q
    s_ref[...] = scale
    _accumulate(dig_ref, _payload_digest_tile(q, scale, n_rows))


def quantize_checksum_int8(x: jax.Array, n_rows: int, *,
                           interpret: bool = True):
    """x: (R, COLS) fp32, R % ROWS == 0 -> (q, scales, payload digest).

    ``n_rows`` is the live (un-padded) row count; the digest covers exactly
    the scale + q payload words of those rows (header words are host-side).
    """
    R, C = x.shape
    assert R % ROWS == 0 and C == COLS and 0 < n_rows <= R
    grid = (R // ROWS,)
    kern = functools.partial(_quant_checksum_kernel, n_rows=n_rows)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS, COLS), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((ROWS, COLS), lambda i: (i, 0)),
                   pl.BlockSpec((ROWS, 1), lambda i: (i, 0)),
                   pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((R, C), jnp.int8),
                   jax.ShapeDtypeStruct((R, 1), jnp.float32),
                   jax.ShapeDtypeStruct((1, 1), jnp.uint32)],
        interpret=interpret,
    )(x)


def _dequant_checksum_kernel(q_ref, s_ref, o_ref, dig_ref, *, n_rows: int):
    q = q_ref[...]
    scale = s_ref[...]
    o_ref[...] = q.astype(jnp.float32) * scale
    _accumulate(dig_ref, _payload_digest_tile(q, scale, n_rows))


def dequantize_checksum_int8(q: jax.Array, scales: jax.Array, n_rows: int, *,
                             interpret: bool = True):
    """Symmetric decode: (fp32, payload digest) in one read of q/scales."""
    R, C = q.shape
    assert R % ROWS == 0 and C == COLS and 0 < n_rows <= R
    grid = (R // ROWS,)
    kern = functools.partial(_dequant_checksum_kernel, n_rows=n_rows)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS, COLS), lambda i: (i, 0)),
                  pl.BlockSpec((ROWS, 1), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((ROWS, COLS), lambda i: (i, 0)),
                   pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((R, C), jnp.float32),
                   jax.ShapeDtypeStruct((1, 1), jnp.uint32)],
        interpret=interpret,
    )(q, scales)
