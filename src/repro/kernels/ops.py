"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on real TPU
backends; the kernels themselves are written for the TPU target (BlockSpec
VMEM tiling, MXU-shaped dots).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import checksum as _checksum
from . import delta as _delta
from . import flash_attention as _fa
from . import fused as _fused
from . import quantize as _quant
from . import ref as _ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def host_fastpath() -> bool:
    """True when there is no real TPU backend.

    Interpret-mode Pallas is a correctness harness, not a data path (it
    moves tens of MB/s); without a TPU the encode/verify hot paths dispatch
    to the pure-NumPy oracles in ``ref.py``, which the differential suite
    (``tests/test_fused_kernels.py``) proves bit-identical to the kernels.
    """
    return _default_interpret()


def tensor_checksum_fast(x) -> int:
    """``tensor_checksum`` as a Python int, via the fastest bit-exact path."""
    if host_fastpath():
        return _ref.checksum_np_bytes(np.asarray(x))
    return int(tensor_checksum(x))


def _pad_to(x: jax.Array, multiple: int) -> jax.Array:
    n = x.shape[0]
    pad = (-n) % multiple
    if pad:
        x = jnp.pad(x, (0, pad))
    return x


def as_u32(x) -> jax.Array:
    """Flat uint32 view (zero-padding the byte tail)."""
    b = jnp.asarray(x).reshape(-1).view(jnp.uint8)
    b = _pad_to(b, 4)
    return b.view(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def tensor_checksum(x, block: int = _checksum.BLOCK,
                    interpret: bool | None = None) -> jax.Array:
    """Position-weighted u32 checksum of any array's bytes."""
    interp = _default_interpret() if interpret is None else interpret
    u = _pad_to(as_u32(x), block)
    return _checksum.checksum_u32(u, block=block, interpret=interp)


@functools.partial(jax.jit, static_argnames=("interpret",))
def downcast_bf16(x, interpret: bool | None = None) -> jax.Array:
    interp = _default_interpret() if interpret is None else interpret
    return _quant.downcast_bf16(x, interpret=interp)


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_int8(x, interpret: bool | None = None):
    interp = _default_interpret() if interpret is None else interpret
    return _quant.quantize_int8(x, interpret=interp)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequantize_int8(q, scales, interpret: bool | None = None) -> jax.Array:
    interp = _default_interpret() if interpret is None else interpret
    return _quant.dequantize_int8(q, scales, interpret=interp)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def delta_xor(cur, prev, block: int = _delta.BLOCK,
              interpret: bool | None = None) -> jax.Array:
    interp = _default_interpret() if interpret is None else interpret
    c = _pad_to(as_u32(cur), block)
    p = _pad_to(as_u32(prev), block)
    return _delta.delta_xor(c, p, block=block, interpret=interp)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def delta_f32(cur, prev, block: int = _delta.BLOCK,
              interpret: bool | None = None) -> jax.Array:
    interp = _default_interpret() if interpret is None else interpret
    c = _pad_to(jnp.asarray(cur, jnp.float32).reshape(-1), block)
    p = _pad_to(jnp.asarray(prev, jnp.float32).reshape(-1), block)
    return _delta.delta_f32(c, p, block=block, interpret=interp)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fused_xor_checksum(cur, prev, block: int = _delta.BLOCK,
                       interpret: bool | None = None):
    """One-pass (delta, digest-of-delta) over any two same-size arrays."""
    interp = _default_interpret() if interpret is None else interpret
    c = _pad_to(as_u32(cur), block)
    p = _pad_to(as_u32(prev), block)
    delta, dig = _fused.xor_checksum_u32(c, p, block=block, interpret=interp)
    return delta, dig[0, 0]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fused_xor_fold(base, delta, block: int = _delta.BLOCK,
                   interpret: bool | None = None):
    """One-pass (base ^ delta, digest-of-delta): fused chain-replay decode."""
    interp = _default_interpret() if interpret is None else interpret
    b = _pad_to(as_u32(base), block)
    d = _pad_to(as_u32(delta), block)
    folded, dig = _fused.xor_fold_checksum_u32(b, d, block=block,
                                               interpret=interp)
    return folded, dig[0, 0]


@functools.partial(jax.jit, static_argnames=("n_rows", "interpret"))
def fused_quantize_int8(x, n_rows: int, interpret: bool | None = None):
    """One-pass (q, scales, int8q payload digest); x: (R, 256) fp32."""
    interp = _default_interpret() if interpret is None else interpret
    q, scales, dig = _fused.quantize_checksum_int8(x, n_rows,
                                                   interpret=interp)
    return q, scales, dig[0, 0]


@functools.partial(jax.jit, static_argnames=("n_rows", "interpret"))
def fused_dequantize_int8(q, scales, n_rows: int,
                          interpret: bool | None = None):
    """One-pass (fp32, int8q payload digest): fused int8 decode + verify."""
    interp = _default_interpret() if interpret is None else interpret
    out, dig = _fused.dequantize_checksum_int8(q, scales, n_rows,
                                               interpret=interp)
    return out, dig[0, 0]


@functools.partial(jax.jit, static_argnames=(
    "kind", "window", "chunk", "q_block", "kv_block", "interpret"))
def flash_attention(q, k, v, kind: str = "full", window: int = 0,
                    chunk: int = 0, q_block: int = _fa.Q_BLOCK,
                    kv_block: int = _fa.KV_BLOCK,
                    interpret: bool | None = None) -> jax.Array:
    """q: (B, S, H, hd); k/v: (B, T, KV, hd). GQA folded into heads."""
    interp = _default_interpret() if interpret is None else interpret
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    rep = H // KV
    kr = jnp.repeat(k, rep, axis=2) if rep > 1 else k
    vr = jnp.repeat(v, rep, axis=2) if rep > 1 else v
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = kr.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    vf = vr.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    out = _fa.flash_attention_bh(qf, kf, vf, kind=kind, window=window,
                                 chunk=chunk, q_block=q_block,
                                 kv_block=kv_block, interpret=interp)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
