"""Differential-checkpoint kernels (Pallas TPU).

Differential checkpointing (paper §VII future work): instead of persisting a
full snapshot every interval, persist ``delta = current - previous`` (exact
for integer/bit views via XOR) plus an occasional full keyframe. Deltas of
slowly-moving optimizer state are highly compressible downstream (zstd in the
host pipeline).

* ``delta_xor`` — bit-exact XOR of two u32 views (lossless, order-insensitive
  reconstruction: ``prev ^ delta = cur``).
* ``delta_f32`` — arithmetic difference of fp32 views (feeds the int8
  quantizer for lossy-but-bounded delta compression).

Tiles are 1-D BLOCK-element slabs (256 KiB VMEM per input).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 65_536


def _xor_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jax.lax.bitwise_xor(a_ref[...], b_ref[...])


def delta_xor(cur_u32: jax.Array, prev_u32: jax.Array, *,
              block: int = BLOCK, interpret: bool = True) -> jax.Array:
    n = cur_u32.shape[0]
    assert n % block == 0 and cur_u32.shape == prev_u32.shape
    grid = (n // block,)
    return pl.pallas_call(
        _xor_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        interpret=interpret,
    )(cur_u32, prev_u32)


def _sub_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] - b_ref[...]


def delta_f32(cur: jax.Array, prev: jax.Array, *, block: int = BLOCK,
              interpret: bool = True) -> jax.Array:
    n = cur.shape[0]
    assert n % block == 0 and cur.shape == prev.shape
    grid = (n // block,)
    return pl.pallas_call(
        _sub_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(cur, prev)
