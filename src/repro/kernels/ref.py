"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .checksum import WEIGHT_BASE, WEIGHT_MOD


def checksum_ref(x_flat_u32) -> np.uint32:
    x = np.asarray(x_flat_u32, dtype=np.uint64)
    idx = np.arange(x.shape[0], dtype=np.uint64)
    w = (np.uint64(WEIGHT_BASE) + (idx % np.uint64(WEIGHT_MOD)))
    return np.uint32((x * w).sum() & np.uint64(0xFFFFFFFF))


def downcast_bf16_ref(x):
    return jnp.asarray(x).astype(jnp.bfloat16)


def quantize_int8_ref(x):
    x = jnp.asarray(x, jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8_ref(q, scale):
    return q.astype(jnp.float32) * scale


def delta_xor_ref(cur_u32, prev_u32):
    return jnp.bitwise_xor(jnp.asarray(cur_u32), jnp.asarray(prev_u32))


def delta_f32_ref(cur, prev):
    return jnp.asarray(cur) - jnp.asarray(prev)


def flash_attention_ref(q, k, v, *, kind: str = "full", window: int = 0,
                        chunk: int = 0):
    """q/k/v: (BH, S|T, hd). Masked softmax attention, fp32 math."""
    BH, S, hd = q.shape
    T = k.shape[1]
    logits = jnp.einsum("bsh,bth->bst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / (hd ** 0.5)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(T)[None, :]
    mask = j <= i
    if kind == "window":
        mask &= j > i - window
    elif kind == "chunked":
        mask &= (i // chunk) == (j // chunk)
    logits = jnp.where(mask[None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bst,bth->bsh", p, v.astype(jnp.float32)).astype(q.dtype)
