"""Pure-NumPy / pure-jnp oracles for every Pallas kernel.

Two roles: the allclose/bit-exact targets of the differential kernel suite,
and the host fallback the fused encode path dispatches to when there is no
real TPU (interpret-mode Pallas is a correctness harness, not a data path —
it moves tens of MB/s; the NumPy oracles move GB/s and are proven
bit-identical by ``tests/test_fused_kernels.py``).
"""

from __future__ import annotations

import threading

import jax
import jax.numpy as jnp
import numpy as np

from .checksum import WEIGHT_BASE, WEIGHT_MOD

_W_LOCK = threading.Lock()
_W_CACHE: dict = {}


def _weights_at(word_offset: int, n: int) -> np.ndarray:
    """uint64 weight vector for payload words [offset, offset + n)."""
    key = (word_offset % WEIGHT_MOD, n)
    with _W_LOCK:
        w = _W_CACHE.get(key)
    if w is None:
        idx = np.arange(word_offset, word_offset + n, dtype=np.uint64)
        w = np.uint64(WEIGHT_BASE) + (idx % np.uint64(WEIGHT_MOD))
        with _W_LOCK:
            if len(_W_CACHE) > 16:
                _W_CACHE.clear()
            _W_CACHE[key] = w
    return w


def checksum_np(x_flat_u32, word_offset: int = 0) -> int:
    """Position-weighted u32 digest, vectorized NumPy (weights cached).

    ``word_offset`` shifts the position weights, giving the digest
    contribution of a word run starting mid-payload — the additive building
    block for streaming whole-file checksums. Products stay < 2^49, and the
    uint64 accumulator wraps mod 2^64, which is exact mod 2^32.
    """
    x = np.asarray(x_flat_u32)
    assert x.dtype == np.uint32
    if x.size == 0:
        return 0
    w = _weights_at(word_offset, x.size)
    return int((x.astype(np.uint64) * w).sum() & np.uint64(0xFFFFFFFF))


def checksum_np_bytes(data, word_offset: int = 0) -> int:
    """``checksum_np`` over a byte buffer (zero-padding the u32 tail)."""
    b = np.frombuffer(data, np.uint8) if not isinstance(data, np.ndarray) \
        else data.reshape(-1).view(np.uint8)
    pad = (-b.size) % 4
    if pad:
        b = np.concatenate([b, np.zeros(pad, np.uint8)])
    if not b.flags["C_CONTIGUOUS"] or b.ctypes.data % 4:
        b = b.copy()
    return checksum_np(b.view(np.uint32), word_offset)


def checksum_ref(x_flat_u32) -> np.uint32:
    x = np.asarray(x_flat_u32, dtype=np.uint64)
    idx = np.arange(x.shape[0], dtype=np.uint64)
    w = (np.uint64(WEIGHT_BASE) + (idx % np.uint64(WEIGHT_MOD)))
    return np.uint32((x * w).sum() & np.uint64(0xFFFFFFFF))


def downcast_bf16_ref(x):
    return jnp.asarray(x).astype(jnp.bfloat16)


def quantize_int8_ref(x):
    x = jnp.asarray(x, jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8_ref(q, scale):
    return q.astype(jnp.float32) * scale


def delta_xor_ref(cur_u32, prev_u32):
    return jnp.bitwise_xor(jnp.asarray(cur_u32), jnp.asarray(prev_u32))


# ------------------------------------------------- fused-kernel oracles
# Payload word layout of the int8q codec (core/codecs.py): 2 header words,
# n_rows scale words, then n_rows * 64 little-endian-packed q words. The
# fused kernels digest the scale + q areas; the header is host-side.
_PAYLOAD_HEADER_WORDS = 2


def fused_xor_checksum_ref(cur_u32, prev_u32):
    """(delta, digest-of-delta) — oracle for ``fused.xor_checksum_u32``."""
    delta = np.bitwise_xor(np.asarray(cur_u32), np.asarray(prev_u32))
    return delta, checksum_np(delta)


def fused_xor_fold_checksum_ref(base_u32, delta_u32):
    """(base ^ delta, digest-of-delta) — oracle for the fused decode."""
    delta = np.asarray(delta_u32)
    return np.bitwise_xor(np.asarray(base_u32), delta), checksum_np(delta)


def int8_payload_digest_ref(q, scales, n_rows: int) -> int:
    """Digest of the scale + q payload areas (header words excluded)."""
    q = np.asarray(q, np.int8)[:n_rows]
    sbits = np.asarray(scales, np.float32)[:n_rows].reshape(-1) \
        .view(np.uint32)
    dig = checksum_np(sbits, word_offset=_PAYLOAD_HEADER_WORDS)
    qwords = q.reshape(-1).view(np.uint8).copy().view(np.uint32)
    dig += checksum_np(qwords,
                       word_offset=_PAYLOAD_HEADER_WORDS + n_rows)
    return dig & 0xFFFFFFFF


def fused_quantize_checksum_ref(x, n_rows: int):
    """(q, scales, payload digest) — oracle for the fused int8 encode."""
    q, scales = quantize_int8_ref(x)
    return q, scales, int8_payload_digest_ref(np.asarray(q),
                                              np.asarray(scales), n_rows)


def fused_dequantize_checksum_ref(q, scales, n_rows: int):
    """(fp32, payload digest) — oracle for the fused int8 decode."""
    out = np.asarray(q, np.int8).astype(np.float32) \
        * np.asarray(scales, np.float32)
    return out, int8_payload_digest_ref(q, scales, n_rows)


def delta_f32_ref(cur, prev):
    return jnp.asarray(cur) - jnp.asarray(prev)


def flash_attention_ref(q, k, v, *, kind: str = "full", window: int = 0,
                        chunk: int = 0):
    """q/k/v: (BH, S|T, hd). Masked softmax attention, fp32 math."""
    BH, S, hd = q.shape
    T = k.shape[1]
    logits = jnp.einsum("bsh,bth->bst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / (hd ** 0.5)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(T)[None, :]
    mask = j <= i
    if kind == "window":
        mask &= j > i - window
    elif kind == "chunked":
        mask &= (i // chunk) == (j // chunk)
    logits = jnp.where(mask[None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bst,bth->bsh", p, v.astype(jnp.float32)).astype(q.dtype)
