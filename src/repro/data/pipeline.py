"""Deterministic synthetic token pipeline.

Stands in for the paper's OSCAR-en/Llama-2-tokenizer dataset: a seeded,
restartable stream of token batches with the exact shapes the configs
request. The iterator state (seed + step) is part of the checkpoint's
host-object state — restoring a checkpoint resumes the stream exactly, which
the restart tests verify (the paper's "globally consistent checkpoint
includes all objects needed to successfully restart").
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class DataState:
    seed: int
    step: int

    def as_dict(self) -> Dict[str, int]:
        return {"seed": self.seed, "step": self.step}


class SyntheticTokenPipeline:
    """Seeded batch stream; ``state``/``restore`` give exact resumability."""

    def __init__(self, cfg, batch: int, seq_len: int, seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self._state = DataState(seed=seed, step=0)

    # -- checkpointable state ------------------------------------------------
    @property
    def state(self) -> Dict[str, int]:
        return self._state.as_dict()

    def restore(self, state: Dict[str, int]) -> None:
        self._state = DataState(**state)

    # -- iteration -------------------------------------------------------
    def next_batch(self) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([self._state.seed, self._state.step]))
        self._state.step += 1
        shape = (self.batch, self.seq_len)
        if cfg.n_codebooks:
            shape = shape + (cfg.n_codebooks,)
        batch: Dict[str, np.ndarray] = {
            "tokens": rng.integers(0, cfg.vocab, size=shape, dtype=np.int32)}
        if cfg.n_prefix_embeds:
            batch["prefix_embeds"] = rng.standard_normal(
                (self.batch, cfg.n_prefix_embeds, cfg.d_model),
                dtype=np.float32)
        if cfg.n_memory_embeds:
            batch["memory_embeds"] = rng.standard_normal(
                (self.batch, cfg.n_memory_embeds, cfg.d_model),
                dtype=np.float32)
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()
