"""Process-per-rank runtime: real SIGKILLs against the hierarchical commit.

The thread runtime's "dead rank" is a raised exception; here every rank
is a spawned OS process and the fault matrix kills it with an actual
``SIGKILL`` at each protocol window (``tests/faults.ProcessFaultSpec``,
fired child-side). The invariants under test (ISSUE 8 acceptance):

* a rank killed mid-save leaves **no visible step** — the orphan never
  enters the catalog, resume falls back to the previous commit;
* the failure is **isolated at the victim's aggregator**: the surviving
  node's aggregator still casts its ``NodeManifest`` vote (its subtree
  drained cleanly) while the victim's node poisons with the rank named;
* the coordinator evicts the corpse and the **next save commits with
  every shard present** — the dead rank's slice is re-spread over the
  survivors by byte balance — and a delta chain **re-keyframes**;
* per-process trace spans merge into the parent tracer so one Perfetto
  export covers every rank's lanes.

These run in the fast lane: spawn cost is ~1s/rank and the payloads are
tiny; the suite-wide slow-marker audit at the bottom pins that placement.
"""

from __future__ import annotations

import os
import re
import signal
import time

import numpy as np
import pytest

from repro.core import (CheckpointError, CheckpointManager,
                        CheckpointPolicy, DeltaPolicy, DistPolicy,
                        StoragePolicy)
from repro.dist import Coordinator, node_topology, partition_records
from repro.dist.coordinator import _SaveJob
from repro.obs import trace as obs
from repro.storage.manifest import read_node_manifests

from faults import ProcessDied, ProcessFaultSpec

WORLD = 4
NODE_SIZE = 2  # two nodes of two ranks: a real tree, still cheap


def _state(n_arrays: int = 8, per: int = 3000) -> dict:
    rng = np.random.default_rng(7)
    return {"model": {f"w{i:02d}": rng.standard_normal(per + i)
                      .astype(np.float32) for i in range(n_arrays)},
            "meta": {"note": "proc-runtime"}}


def _coordinator(fault=None, ack_timeout_s=60.0) -> Coordinator:
    return Coordinator(WORLD, runtime="process", node_size=NODE_SIZE,
                       host_cache_bytes=16 << 20, flush_threads=1,
                       checksum_files=False, ack_timeout_s=ack_timeout_s,
                       fault=fault)


def _manager(root: str, coordinator: Coordinator, **policy_kw
             ) -> CheckpointManager:
    return CheckpointManager.from_policy(root, CheckpointPolicy(
        storage=StoragePolicy(manifest_checksums=False),
        dist=DistPolicy(coordinator=coordinator), **policy_kw))


def _restore_template() -> dict:
    s = _state()
    return {"model": {k: np.zeros_like(v)
                      for k, v in s["model"].items()},
            "meta": {"note": ""}}


class TestHealthyHierarchicalCommit:
    def test_save_commits_with_node_manifests_and_topology_meta(
            self, tmp_path):
        state = _state()
        mgr = _manager(str(tmp_path), _coordinator())
        fut = mgr.save(1, state)
        fut.wait_persisted()
        mgr.wait_for_commit(1)
        assert mgr.commit_errors == []
        assert mgr.latest_step() == 1
        sdir = os.path.join(str(tmp_path), "global_step1")
        nodes = read_node_manifests(sdir)
        assert sorted(nodes) == [0, 1]
        assert nodes[0].ranks == [0, 1] and nodes[1].ranks == [2, 3]
        man = mgr.repository.manifest(1)
        assert man.meta["nodes"] == {"0": [0, 1], "1": [2, 3]}
        # full writer set → no degraded-writers record
        assert "writers" not in man.meta
        restored = mgr.restore(_restore_template())
        for k, v in state["model"].items():
            assert np.array_equal(restored["model"][k], v)
        mgr.close()

    def test_process_traces_merge_into_parent_export(self, tmp_path):
        mgr = _manager(str(tmp_path), _coordinator())
        with obs.tracing() as t:
            fut = mgr.save(1, _state())
            fut.wait_persisted()
            mgr.wait_for_commit(1)
        lanes = {e["lane"] for e in t.events()}
        # child-side engine/vote spans shipped back, rank-labeled
        assert any(lane.startswith("rank000") for lane in lanes), lanes
        names = {e["name"] for e in t.events()}
        assert "vote" in names            # child-side phase-1 vote
        assert "node.vote" in names       # parent-side aggregator vote
        assert "rank.ship" in names       # payload crossing the pipe
        mgr.close()


class TestSigkillFaultMatrix:
    @pytest.mark.parametrize("point",
                             ["mid_file", "after_vote", "before_ack"])
    def test_killed_rank_leaves_no_visible_step_and_next_save_commits(
            self, tmp_path, point):
        state = _state()
        coord = _coordinator(
            fault=ProcessFaultSpec(point, rank=2, step=2))
        mgr = _manager(str(tmp_path), coord)
        f1 = mgr.save(1, state)
        f1.wait_persisted()
        mgr.wait_for_commit(1)

        f2 = mgr.save(2, state)
        with pytest.raises(CheckpointError) as ei:
            f2.wait_persisted()
        assert isinstance(ei.value.__cause__, ProcessDied)
        assert ei.value.__cause__.rank == 2
        mgr.wait_for_commit(2)
        # no visible step: the orphan never entered the catalog, resume
        # falls back to the previous commit
        assert mgr.latest_step() == 1
        restored = mgr.restore(_restore_template())
        for k, v in state["model"].items():
            assert np.array_equal(restored["model"][k], v)

        # the corpse is evicted; the next save commits with every shard
        # present on the surviving writers
        assert 2 in coord.dead_ranks
        f3 = mgr.save(3, state)
        f3.wait_persisted()
        mgr.wait_for_commit(3)
        assert mgr.commit_errors == []
        assert mgr.latest_step() == 3
        man = mgr.repository.manifest(3)
        assert man.meta["writers"] == [0, 1, 3]
        assert man.meta["nodes"] == {"0": [0, 1], "1": [3]}
        sdir = os.path.join(str(tmp_path), "global_step3")
        assert not os.path.exists(
            os.path.join(sdir, "rank00002.dsllm"))
        restored3 = mgr.restore(_restore_template())
        for k, v in state["model"].items():
            assert np.array_equal(restored3["model"][k], v)
        mgr.close()

    def test_failure_is_isolated_at_the_victims_aggregator(self,
                                                           tmp_path):
        """Rank 2 dies before its ack: its node (ranks 2-3) poisons with
        the victim named, while the *other* node's aggregator still
        drains its subtree and casts the node-0 vote into the (orphaned)
        step directory."""
        coord = _coordinator(
            fault=ProcessFaultSpec("after_upload", rank=2, step=1))
        mgr = _manager(str(tmp_path), coord)
        fut = mgr.save(1, _state())
        with pytest.raises(CheckpointError):
            fut.wait_persisted()
        mgr.wait_for_commit(1)
        assert mgr.latest_step() is None
        # surviving subtree completed phase 1 and its aggregator voted
        sdir = os.path.join(str(tmp_path), "global_step1")
        deadline = time.monotonic() + 10
        while time.monotonic() < deadline:
            nodes = read_node_manifests(sdir)
            if 0 in nodes:
                break
            time.sleep(0.05)
        assert sorted(nodes) == [0], \
            "only the surviving node's aggregator should have voted"
        assert nodes[0].ranks == [0, 1]
        mgr.close()

    def test_stalled_rank_trips_watchdog_not_the_survivors(self,
                                                           tmp_path):
        coord = _coordinator(
            fault=ProcessFaultSpec("before_ack", rank=1, step=1,
                                   action="stall", stall_s=3.0),
            ack_timeout_s=1.0)
        mgr = _manager(str(tmp_path), coord)
        fut = mgr.save(1, _state())
        with pytest.raises(CheckpointError) as ei:
            fut.wait_persisted()
        assert "not all ranks acked" in repr(ei.value.__cause__)
        mgr.wait_for_commit(1)
        assert mgr.latest_step() is None
        mgr.close()

    def test_idle_rank_death_is_pruned_before_the_next_save(self,
                                                            tmp_path):
        """A rank that dies *between* saves (no failed save to flag it)
        is still evicted by the liveness probe at submit time."""
        state = _state()
        coord = _coordinator()
        mgr = _manager(str(tmp_path), coord)
        f1 = mgr.save(1, state)
        f1.wait_persisted()
        mgr.wait_for_commit(1)
        victim = coord.ranks[3]
        os.kill(victim._proc.pid, signal.SIGKILL)
        victim._proc.join(timeout=10)
        f2 = mgr.save(2, state)
        f2.wait_persisted()
        mgr.wait_for_commit(2)
        assert mgr.commit_errors == []
        assert mgr.repository.manifest(2).meta["writers"] == [0, 1, 2]
        restored = mgr.restore(_restore_template())
        for k, v in state["model"].items():
            assert np.array_equal(restored["model"][k], v)
        mgr.close()


class TestDeltaRekeyframeAfterDeath:
    def test_writer_loss_forces_a_keyframe(self, tmp_path):
        """Save 3 kills rank 1 mid-chain; the reassigned slice has no
        delta base on its new writer, so save 4 must re-keyframe (and
        commit)."""
        state = _state()
        coord = _coordinator(
            fault=ProcessFaultSpec("after_upload", rank=1, step=3))
        mgr = _manager(str(tmp_path), coord,
                       delta=DeltaPolicy(keyframe_every=100))
        f1 = mgr.save(1, state)
        f1.wait_persisted()
        mgr.wait_for_commit(1)
        assert mgr.repository.manifest(1).meta["delta"]["keyframe"]
        f2 = mgr.save(2, state)
        f2.wait_persisted()
        mgr.wait_for_commit(2)
        assert not mgr.repository.manifest(2).meta["delta"]["keyframe"]
        f3 = mgr.save(3, state)
        with pytest.raises(CheckpointError):
            f3.wait_persisted()
        mgr.wait_for_commit(3)
        assert mgr.latest_step() == 2
        f4 = mgr.save(4, state)
        f4.wait_persisted()
        mgr.wait_for_commit(4)
        assert mgr.commit_errors == []
        man4 = mgr.repository.manifest(4)
        assert man4.meta["delta"]["keyframe"]
        assert man4.meta["writers"] == [0, 2, 3]
        restored = mgr.restore(_restore_template())
        for k, v in state["model"].items():
            assert np.array_equal(restored["model"][k], v)
        mgr.close()


class TestDeadRankPartition:
    def test_orphan_slice_respreads_by_byte_balance(self):
        from repro.core.distributed import ShardRecord

        def rec(i, nbytes):
            return ShardRecord(
                leaf_path=f"t{i}", tensor_name=f"t{i:03d}", rank=0,
                index=((0, 1),), global_shape=(1,), shape=(1,),
                dtype="float32", nbytes=nbytes, data=None,
                device_resident=False)

        recs = [rec(i, 1000 + i) for i in range(16)]
        base = partition_records(recs, 4)
        degraded = partition_records(recs, 4, dead={2})
        assert sorted(degraded) == [0, 1, 3]
        # surviving ranks keep their base slice (delta bases stay valid)
        for r in (0, 1, 3):
            base_names = {x.tensor_name for x in base[r]}
            assert base_names <= {x.tensor_name for x in degraded[r]}
        # every orphaned record lands somewhere, exactly once
        all_names = sorted(x.tensor_name for p in degraded.values()
                           for x in p)
        assert all_names == sorted(x.tensor_name for x in recs)
        # byte balance: 4 orphans over 3 near-equally loaded survivors
        # (greedy, largest-first onto least-loaded) spreads them — every
        # survivor picks up work instead of one lane absorbing the slice
        added = {r: {x.tensor_name for x in degraded[r]} -
                 {x.tensor_name for x in base[r]} for r in (0, 1, 3)}
        assert all(added.values()), added

    def test_all_dead_raises(self):
        with pytest.raises(RuntimeError):
            partition_records([], 2, dead={0, 1})


class TestTopologyHelpers:
    def test_node_topology_blocks(self):
        assert node_topology(4, 2) == {0: [0, 1], 1: [2, 3]}
        assert node_topology(5, 2) == {0: [0, 1], 1: [2, 3], 2: [4]}
        # default: small worlds are single-node (flat-protocol behavior)
        assert node_topology(4) == {0: [0, 1, 2, 3]}

    def test_save_job_rejects_topology_not_covering_writers(self,
                                                            tmp_path):
        from repro.core.engine import CheckpointFuture
        with pytest.raises(ValueError):
            _SaveJob(1, str(tmp_path), 4, writers=[0, 1, 2],
                     nodes={0: [0, 1]},
                     future=CheckpointFuture(1, str(tmp_path)),
                     ack_timeout_s=None)


# Fast-lane placement audit: the process fault matrix must ride the fast
# lane (spawns are ~1s/rank), while the genuinely multi-minute suites
# stay behind the `slow` marker. This pins both sides so a stray
# pytestmark (or a missing one) shows up as a test failure, not as CI
# drift.
SLOW_MARKED_MODULES = {
    "test_distributed.py", "test_models.py", "test_perf_features.py",
    "test_system.py", "test_training.py",
}


def test_slow_marker_audit():
    tests_dir = os.path.dirname(__file__)
    for name in sorted(os.listdir(tests_dir)):
        if not (name.startswith("test_") and name.endswith(".py")):
            continue
        with open(os.path.join(tests_dir, name)) as f:
            src = f.read()
        module_slow = re.search(
            r"^pytestmark\s*=\s*pytest\.mark\.slow", src,
            re.MULTILINE) is not None
        assert module_slow == (name in SLOW_MARKED_MODULES), (
            f"{name}: module-level slow marker "
            f"{'present' if module_slow else 'missing'} but the audit "
            f"expects the opposite — update SLOW_MARKED_MODULES "
            f"deliberately if the lane placement really changed")
