"""CheckpointPolicy / from_policy API + the legacy-kwarg deprecation shim.

The back-compat contract (ISSUE 5): every pre-policy constructor kwarg
keeps working — mapped onto exactly one CheckpointPolicy field — while
emitting a DeprecationWarning; the policy path emits nothing; mixing both
is an error.
"""

import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CheckpointManager, CheckpointPolicy, DeltaPolicy,
                        DistPolicy, EnginePolicy, StoragePolicy)
from repro.core.policy import LEGACY_KWARG_MAP
from repro.storage import MemoryBackend, RetentionPolicy, Tier


def tiny_state(v=1.0):
    return {"model": {"w": jnp.full((64,), v, jnp.float32)},
            "meta": {"step": 1}}


# ----------------------------------------------------------- legacy shim
def test_legacy_kwargs_warn_and_still_work(tmp_path):
    state = tiny_state(3.0)
    with pytest.warns(DeprecationWarning, match="from_policy"):
        mgr = CheckpointManager(str(tmp_path), mode="datastates",
                                host_cache_bytes=1 << 22,
                                delta=DeltaPolicy(keyframe_every=2))
    with mgr:
        assert mgr.mode == "datastates"
        assert mgr.delta_policy.keyframe_every == 2
        mgr.save(1, state, blocking=True)
        out = mgr.restore(state, step=1)
        np.testing.assert_array_equal(np.asarray(out["model"]["w"]),
                                      np.asarray(state["model"]["w"]))


def test_legacy_save_of_raw_pytree_still_works(tmp_path):
    """The pre-domain surface — an arbitrary (non-mapping-rooted) pytree
    — still saves and restores through the default routing."""
    state = [jnp.arange(32, dtype=jnp.float32),
             np.arange(8, dtype=np.int16)]
    with pytest.warns(DeprecationWarning):
        mgr = CheckpointManager(str(tmp_path), mode="datastates")
    with mgr:
        mgr.save(1, state, blocking=True)
        out = mgr.restore(state, step=1)
        np.testing.assert_array_equal(np.asarray(out[0]),
                                      np.asarray(state[0]))
        np.testing.assert_array_equal(out[1], state[1])


def test_bare_directory_constructor_does_not_warn(tmp_path):
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        with CheckpointManager(str(tmp_path)) as mgr:
            assert mgr.policy == CheckpointPolicy()


def test_from_policy_does_not_warn(tmp_path):
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        with CheckpointManager.from_policy(
                str(tmp_path),
                CheckpointPolicy(engine=EnginePolicy(mode="sync"))) as mgr:
            assert mgr.mode == "sync"


def test_policy_plus_legacy_kwargs_is_an_error(tmp_path):
    with pytest.raises(ValueError, match="not both"):
        CheckpointManager(str(tmp_path), mode="sync",
                          policy=CheckpointPolicy())


# ------------------------------------------------------- kwarg → field map
def test_every_legacy_kwarg_maps_onto_one_policy_field(tmp_path):
    tier = Tier("peer", MemoryBackend())
    ret = RetentionPolicy(keep_last_n=2)
    delta = DeltaPolicy(keyframe_every=3)
    pol = CheckpointPolicy.from_legacy_kwargs(
        mode="datastates-old", host_cache_bytes=1 << 22, flush_threads=2,
        chunk_bytes=1 << 20, throttle_mbps=100.0, restore_threads=3,
        tiers=[tier], retention=ret, manifest_checksums=False,
        world=None, ack_timeout_s=5.0, delta=delta)
    assert pol.engine == EnginePolicy(
        mode="datastates-old", host_cache_bytes=1 << 22, flush_threads=2,
        chunk_bytes=1 << 20, throttle_mbps=100.0, restore_threads=3)
    assert pol.storage == StoragePolicy(tiers=(tier,), retention=ret,
                                        manifest_checksums=False)
    assert pol.dist == DistPolicy(world=None, ack_timeout_s=5.0)
    assert pol.delta == delta
    assert pol.providers is None


def test_unknown_legacy_kwarg_raises_type_error():
    with pytest.raises(TypeError, match="unknown"):
        CheckpointPolicy.from_legacy_kwargs(fsync_mode="never")


def test_legacy_map_is_total_over_the_old_signature():
    """Guards the migration table: the shim must cover the entire
    pre-policy constructor surface."""
    assert set(LEGACY_KWARG_MAP) == {
        "mode", "host_cache_bytes", "flush_threads", "chunk_bytes",
        "throttle_mbps", "restore_threads", "tiers", "retention",
        "manifest_checksums", "world", "coordinator", "ack_timeout_s",
        "delta"}


# ------------------------------------------------------------- validation
def test_policy_validates_engine_mode(tmp_path):
    with pytest.raises(ValueError, match="unknown engine mode"):
        CheckpointManager.from_policy(
            str(tmp_path), CheckpointPolicy(engine=EnginePolicy(mode="x")))


def test_policy_delta_requires_data_movement_engine(tmp_path):
    with pytest.raises(ValueError, match="DataMovementEngine"):
        CheckpointManager.from_policy(
            str(tmp_path), CheckpointPolicy(engine=EnginePolicy(mode="sync"),
                                            delta=DeltaPolicy()))


def test_delta_policy_validates_keyframe_every():
    with pytest.raises(ValueError):
        DeltaPolicy(keyframe_every=0)


def test_dist_policy_validates_world():
    with pytest.raises(ValueError):
        DistPolicy(world=0)


def test_policy_equivalent_to_legacy_kwargs(tmp_path):
    """Same save through both constructor surfaces → identical bytes
    visible to restore."""
    state = tiny_state(7.0)
    d1, d2 = str(tmp_path / "legacy"), str(tmp_path / "policy")
    with pytest.warns(DeprecationWarning):
        m1 = CheckpointManager(d1, mode="datastates",
                               host_cache_bytes=1 << 22)
    with m1:
        m1.save(1, state, blocking=True)
        a = m1.restore(state, step=1)
    with CheckpointManager.from_policy(
            d2, CheckpointPolicy(
                engine=EnginePolicy(host_cache_bytes=1 << 22))) as m2:
        m2.save(1, state, blocking=True)
        b = m2.restore(state, step=1)
    np.testing.assert_array_equal(np.asarray(a["model"]["w"]),
                                  np.asarray(b["model"]["w"]))
