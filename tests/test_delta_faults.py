"""Fault matrix for differential checkpoint chains (ISSUE 4).

Acceptance: no delta chain is ever selected for restore unless its
keyframe and every intermediate delta are present, checksum-clean, and
committed — under rank kills mid-delta-save, post-commit tampering of any
chain member, and retention GC racing pinned chains. Plus the
ObjectStateProvider exact-resume gap: resuming *from a delta step* with
data-pipeline + RNG state checkpointed reproduces the uninterrupted loss
trajectory bit-identically.
"""

import glob
import os
import time

import jax.numpy as jnp
import numpy as np
import pytest

from faults import FaultInjector, InjectedFault, tamper_file

from repro.analysis import witness as lock_witness
from repro.core import (CheckpointError, CheckpointManager, DeltaPolicy,
                        RestoreError, latest_step, step_dir)
from repro.dist import BarrierBroken, Coordinator
from repro.storage import cli as storage_cli

WORLD = 2
KEYFRAME_EVERY = 4


@pytest.fixture(autouse=True)
def _lock_order_witness():
    """Delta-chain fault scenarios also validate the declared lock
    hierarchy at runtime (zero recorded violations is an acceptance
    criterion, same as test_fault_injection)."""
    with lock_witness.recording() as w:
        yield w
    w.assert_clean()


def tiny_state(tag: float = 0.0):
    return {"model": {f"w{i}": jnp.arange(256, dtype=jnp.float32) + tag + i
                      for i in range(2 * WORLD)},
            "meta": {"step": int(tag)}}


def delta_manager(tmp_path, injector=None, **kw):
    coord = Coordinator(WORLD, fault_hook=injector, ack_timeout_s=30.0)
    return CheckpointManager(str(tmp_path), coordinator=coord,
                             delta=DeltaPolicy(keyframe_every=KEYFRAME_EVERY),
                             **kw)


@pytest.mark.parametrize("point", ["mid_file", "after_upload", "before_ack"])
def test_rank_killed_mid_delta_save_chain_restorable(tmp_path, point):
    """Kill a rank at every protocol window of a *delta* save: the chain
    stays restorable at the previous committed (delta) step, the victim
    is an invisible orphan, and the next save re-arms with a keyframe."""
    injector = FaultInjector(point, rank=1, step=3)
    with delta_manager(tmp_path, injector) as mgr:
        mgr.save(1, tiny_state(1.0), blocking=True)   # keyframe
        mgr.save(2, tiny_state(2.0), blocking=True)   # delta on 1
        assert not mgr.repository.manifest(2).meta["delta"]["keyframe"]
        with pytest.raises(CheckpointError) as ei:
            mgr.save(3, tiny_state(3.0), blocking=True)  # delta, killed
        assert isinstance(ei.value.__cause__, (InjectedFault, BarrierBroken))
        mgr.wait_for_commit()
        assert not mgr.repository.has_manifest(3)
        assert mgr.latest_step() == 2
        # restore lands on the last committed delta step, replaying 1⊕2
        out = mgr.restore(tiny_state())
        assert mgr.last_restored_step == 2
        np.testing.assert_array_equal(np.asarray(out["model"]["w0"]),
                                      np.asarray(tiny_state(2.0)["model"]["w0"]))
        # chain invalidated by the failure: the next save is a keyframe
        mgr.save(4, tiny_state(4.0), blocking=True)
        assert mgr.repository.manifest(4).meta["delta"]["keyframe"] is True
    root = str(tmp_path)
    assert storage_cli.main(["--root", root, "verify"]) == 1  # orphan 3
    assert storage_cli.main(["--root", root, "gc", "--orphans",
                             "--orphan-grace", "0"]) == 0
    assert not os.path.isdir(step_dir(root, 3))
    assert storage_cli.main(["--root", root, "verify"]) == 0
    assert latest_step(root) == 4


@pytest.mark.parametrize("victim", ["keyframe", "mid_delta"])
def test_tampered_chain_member_fails_every_dependent(tmp_path, victim):
    """Post-commit bitrot on a keyframe (or an intermediate delta) must
    fail `storage.cli verify` for the victim AND every dependent delta
    step, and chain restore must refuse the damaged chain."""
    states = {}
    with delta_manager(tmp_path) as mgr:
        for s in range(1, 5):  # k1 d2 d3 d4
            states[s] = tiny_state(float(s))
            mgr.save(s, states[s], blocking=True)
    root = str(tmp_path)
    victim_step = 1 if victim == "keyframe" else 2
    f = sorted(glob.glob(os.path.join(step_dir(root, victim_step),
                                      "*.dsllm")))[0]
    tamper_file(f, offset=200)
    assert storage_cli.main(["--root", root, "verify"]) == 1
    # explicit-step audits of dependents fail too (chain pulled in)
    assert storage_cli.main(["--root", root, "verify", "--step", "4"]) == 1
    with CheckpointManager(root) as mgr2:
        # explicit restore of any dependent delta step refuses the chain
        with pytest.raises((RestoreError, CheckpointError)):
            mgr2.restore(tiny_state(), step=4)
        if victim == "mid_delta":
            # fallback restore walks past 4,3,2 to the clean keyframe 1
            out = mgr2.restore(tiny_state())
            assert mgr2.last_restored_step == 1
            np.testing.assert_array_equal(
                np.asarray(out["model"]["w0"]),
                np.asarray(states[1]["model"]["w0"]))


def test_gc_orphans_never_break_a_pinned_chain(tmp_path):
    """Chain-aware GC acceptance: with aggressive retention plus orphan
    collection after a killed delta save, a pinned delta step keeps its
    whole chain and stays restorable."""
    injector = FaultInjector("after_upload", rank=0, step=5)
    states = {}
    with delta_manager(tmp_path, injector) as mgr:
        for s in range(1, 5):  # k1 d2 d3 d4
            states[s] = tiny_state(float(s))
            mgr.save(s, states[s], blocking=True)
        mgr.repository.pin(3)
        with pytest.raises(CheckpointError):
            mgr.save(5, tiny_state(5.0), blocking=True)  # killed → orphan
        mgr.wait_for_commit()
    root = str(tmp_path)
    assert storage_cli.main(["--root", root, "gc", "--keep-last", "1",
                             "--orphans", "--orphan-grace", "0"]) == 0
    # orphan 5 reclaimed; keep-last-1 retains 4 → chain 1..4 all pinned
    # (4's chain covers 1-3 anyway; pin on 3 is belt-and-braces)
    assert not os.path.isdir(step_dir(root, 5))
    for s in range(1, 5):
        assert os.path.isdir(step_dir(root, s)), f"chain member {s} GC'd"
    with CheckpointManager(root) as mgr2:
        out = mgr2.restore(tiny_state(), step=3)
        np.testing.assert_array_equal(np.asarray(out["model"]["w1"]),
                                      np.asarray(states[3]["model"]["w1"]))


@pytest.mark.parametrize("same_process", [True, False])
def test_rewind_resave_retracts_delta_dependents(tmp_path, same_process):
    """Re-saving a step that committed delta dependents (rewind after a
    loss spike) must retract those dependents: their XOR payloads were
    encoded against the bytes being replaced, so replaying them over the
    new base would restore checksum-clean garbage."""
    policy = DeltaPolicy(keyframe_every=4)
    with CheckpointManager(str(tmp_path), delta=policy) as mgr:
        for s in range(1, 4):  # k1 d2 d3
            mgr.save(s, tiny_state(float(s)), blocking=True)
        assert mgr.latest_step() == 3
        if same_process:
            mgr.save(2, tiny_state(20.0), blocking=True)  # rewind-resave
            # the tracker re-armed: no chain onto a later step (cycle)
            d = mgr.repository.manifest(2).meta["delta"]
            assert d["keyframe"] is True
            assert mgr.latest_step() == 2  # dependent 3 retracted
            out = mgr.restore(tiny_state())
            assert mgr.last_restored_step == 2
            np.testing.assert_array_equal(
                np.asarray(out["model"]["w0"]),
                np.asarray(tiny_state(20.0)["model"]["w0"]))
    if not same_process:
        # restart (fresh tracker) then rewind-resave step 2
        with CheckpointManager(str(tmp_path), delta=policy) as mgr2:
            mgr2.save(2, tiny_state(20.0), blocking=True)
            assert mgr2.latest_step() == 2
            out = mgr2.restore(tiny_state())
            assert mgr2.last_restored_step == 2
            np.testing.assert_array_equal(
                np.asarray(out["model"]["w0"]),
                np.asarray(tiny_state(20.0)["model"]["w0"]))
    # the retracted dependent is an orphan: flagged, then reclaimable
    root = str(tmp_path)
    assert storage_cli.main(["--root", root, "verify"]) == 1
    assert storage_cli.main(["--root", root, "gc", "--orphans",
                             "--orphan-grace", "0"]) == 0
    assert storage_cli.main(["--root", root, "verify"]) == 0


# ----------------------------------------------- mid-fused-encode faults
class _EncodeBomb:
    """Wrap a fused codec encoder; raise on the N-th call once armed."""

    def __init__(self, real, explode_on=2):
        self.real = real
        self.explode_on = explode_on
        self.calls = 0
        self.armed = False

    def __call__(self, *args, **kw):
        if self.armed:
            self.calls += 1
            if self.calls >= self.explode_on:
                raise InjectedFault("fused encode exploded mid-chunk")
        return self.real(*args, **kw)


def _mixed_policy(world: int):
    """Delta-routed model domain + quantized fp32 optimizer domain, small
    chunks so every tensor crosses several fused-encode calls."""
    from repro.core import (CheckpointPolicy, DistPolicy, EnginePolicy,
                            StateProviderRegistry)
    return CheckpointPolicy(
        engine=EnginePolicy(host_cache_bytes=1 << 26, chunk_bytes=1 << 16),
        dist=DistPolicy(world=world) if world > 1 else DistPolicy(),
        delta=DeltaPolicy(keyframe_every=4),
        providers=(StateProviderRegistry()
                   .add_rule(provider="quantized", domain="optimizer",
                             dtype="float32")
                   .add_rule(provider="auto")))


def _mixed_state(tag: float):
    rng = np.random.default_rng(int(tag))
    return {"model": {f"w{i}": jnp.asarray(
                rng.standard_normal(65_536).astype(np.float32)) + tag
                for i in range(4)},
            "optimizer": {"m": jnp.asarray(
                rng.standard_normal(131_072).astype(np.float32))},
            "meta": {"step": int(tag)}}


@pytest.mark.parametrize("world", [1, 4])
@pytest.mark.parametrize("route", ["delta", "quantized"])
def test_provider_raising_mid_fused_encode(tmp_path, world, route,
                                           monkeypatch):
    """A fused encoder blowing up mid-chunk (kernel error, corrupt staged
    view) must behave like any producer death: the partial file is
    aborted and unlinked, nothing commits, the encode budget drains, and
    the *same* engine saves the next step cleanly — at world=1 and on the
    world=4 thread runtime."""
    import repro.core.state_provider as sp_mod
    from repro.core import CheckpointManager as CM

    target = ("encode_delta_chunk" if route == "delta"
              else "encode_int8_block")
    bomb = _EncodeBomb(getattr(sp_mod, target), explode_on=2)
    monkeypatch.setattr(sp_mod, target, bomb)

    with CM.from_policy(str(tmp_path), _mixed_policy(world)) as mgr:
        mgr.save(1, _mixed_state(1.0), blocking=True)   # keyframe
        bomb.armed = True
        with pytest.raises(CheckpointError):
            mgr.save(2, _mixed_state(2.0), blocking=True)
        assert bomb.calls >= bomb.explode_on   # it really fired mid-save
        bomb.armed = False
        mgr.wait_for_commit()
        assert not mgr.repository.has_manifest(2)
        assert mgr.latest_step() == 1
        # the aborted writers unlink their footer-less partials once the
        # in-flight ops drain (async w.r.t. the failed save by design —
        # closing the fd inline would race queued pwrites). Ranks whose
        # save completed before a peer failed may leave *complete*
        # (footer-carrying) shards behind — those are orphans for GC, not
        # partials; what must never survive is a footer-less file.
        from repro.core.layout import FileReader
        sdir = step_dir(str(tmp_path), 2)
        deadline = time.monotonic() + 10.0
        partials = []
        while time.monotonic() < deadline:
            partials = []
            for f in glob.glob(os.path.join(sdir, "*.dsllm")):
                try:
                    FileReader(f)
                except (ValueError, OSError):
                    partials.append(f)
            if not partials:
                break
            time.sleep(0.05)
        assert not partials, f"footer-less partial(s) survived: {partials}"
        # same engine, next save: healthy (budget credited back on the
        # error path), chain re-armed with a keyframe
        mgr.save(3, _mixed_state(3.0), blocking=True)
        assert mgr.repository.manifest(3).meta["delta"]["keyframe"] is True
        out = mgr.restore(_mixed_state(0.0))
        assert mgr.last_restored_step == 3
        np.testing.assert_array_equal(
            np.asarray(out["model"]["w0"]),
            np.asarray(_mixed_state(3.0)["model"]["w0"]))
    root = str(tmp_path)
    assert storage_cli.main(["--root", root, "gc", "--orphans",
                             "--orphan-grace", "0"]) == 0
    assert storage_cli.main(["--root", root, "verify"]) == 0


@pytest.mark.slow
def test_exact_resume_from_delta_step(tmp_path):
    """Close the ObjectStateProvider gap end to end: train with
    data-pipeline + RNG state checkpointed through the delta path, kill,
    resume from a *delta* step, and the loss trajectory is bit-identical
    to an uninterrupted run."""
    from repro.configs import get_config, smoke_variant
    from repro.training.loop import Trainer

    cfg = smoke_variant(get_config("llama2-7b"))
    # reference: uninterrupted 6 steps
    ref = Trainer(cfg, batch=2, seq_len=32)
    ref_losses = [r.loss for r in ref.run(6)]

    mgr = CheckpointManager(str(tmp_path),
                            delta=DeltaPolicy(keyframe_every=2))
    tr = Trainer(cfg, batch=2, seq_len=32, manager=mgr)
    tr.run(4, ckpt_interval=2)  # saves: step 2 (keyframe), step 4 (delta)
    mgr.wait_for_commit()
    assert mgr.repository.manifest(4).meta["delta"]["keyframe"] is False
    mgr.close()  # "kill" the first process

    with CheckpointManager(str(tmp_path)) as mgr2:  # fresh, no delta policy
        tr2 = Trainer(cfg, batch=2, seq_len=32, manager=mgr2)
        assert tr2.resume() == 4          # resumes from the delta step
        recs = tr2.run(2)
        resumed_losses = [r.loss for r in recs]
    np.testing.assert_array_equal(np.asarray(resumed_losses, np.float64),
                                  np.asarray(ref_losses[4:], np.float64))
