"""End-to-end behaviour tests for the paper's system.

These exercise the *whole* stack at once — training runtime, checkpoint
manager, state providers, file layout, restore — rather than one layer:

* cross-engine equivalence: every engine (DeepSpeed-default, TorchSnapshot,
  DataStates-old, DataStates) persists a state that restores bit-identically;
* heterogeneous-state fidelity: the full "3D heterogeneity" pytree (device
  tensors of mixed dtype, host numpy, nested Python objects) round-trips;
* crash consistency: a truncated/partial checkpoint is rejected cleanly and
  an earlier intact checkpoint remains restorable;
* serve-after-restore: a checkpoint taken during training serves greedy
  decoding identically to the live params.
"""

import glob
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core import ENGINES, CheckpointManager, step_dir
from repro.serving.engine import greedy_generate
from repro.training.loop import Trainer

# Whole-module slow marker: multi-second jit compiles per case; the
# fast lane (scripts/run_tests.sh --fast) deselects these.
pytestmark = pytest.mark.slow


def tiny_cfg():
    return smoke_variant(get_config("llama3.2-1b"))


def hetero_state():
    """The paper's Table-I composition in miniature: GPU tensors (mixed
    precision), host numpy, and nested non-tensor Python state."""
    key = jax.random.PRNGKey(0)
    return {
        "model": {
            "w_bf16": jax.random.normal(key, (64, 48)).astype(jnp.bfloat16),
            "w_f32": jax.random.normal(key, (33, 7), dtype=jnp.float32),
            "b_i8": jnp.arange(17, dtype=jnp.int8),
        },
        "optimizer": {"m": np.random.default_rng(1).normal(size=(64, 48))
                      .astype(np.float32)},
        "meta": {
            "step": 12,
            "rng": {"seed": 1234, "algo": "threefry"},
            "schedule": [0.1, 0.01, ("warmup", 100)],
            "note": "πβγ unicode survives",
            "none_field": None,
        },
    }


def assert_state_equal(a, b):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb
    for x, y in zip(la, lb):
        if hasattr(x, "shape"):
            np.testing.assert_array_equal(
                np.asarray(x, dtype=np.float32) if str(getattr(x, "dtype", "")) == "bfloat16" else np.asarray(x),
                np.asarray(y, dtype=np.float32) if str(getattr(y, "dtype", "")) == "bfloat16" else np.asarray(y))
        else:
            assert x == y


def test_every_engine_restores_identical_state(tmp_path):
    state = hetero_state()
    restored = {}
    for mode in ENGINES:
        mgr = CheckpointManager(str(tmp_path / mode), mode=mode)
        mgr.save(1, state, blocking=True)
        restored[mode] = mgr.restore(state, step=1)
        mgr.close()
    for mode, r in restored.items():
        assert_state_equal(state, r)
    # all engines agree with each other, not just with the source
    modes = sorted(restored)
    for m in modes[1:]:
        assert_state_equal(restored[modes[0]], restored[m])


def test_partial_checkpoint_rejected_earlier_survives(tmp_path):
    """Crash-mid-flush: the damaged step is rejected (footer/magic check),
    while the previous intact checkpoint stays restorable."""
    state = hetero_state()
    mgr = CheckpointManager(str(tmp_path), mode="datastates")
    mgr.save(1, state, blocking=True)
    mgr.save(2, state, blocking=True)
    # simulate a crash mid-flush of step 2: truncate every file
    for p in glob.glob(os.path.join(step_dir(str(tmp_path), 2), "*.dsllm")):
        with open(p, "r+b") as f:
            f.truncate(max(os.path.getsize(p) // 2, 1))
    with pytest.raises(Exception):
        mgr.restore(state, step=2)
    assert_state_equal(state, mgr.restore(state, step=1))
    mgr.close()


def test_train_checkpoint_serve_pipeline(tmp_path):
    """Full lifecycle: train → per-iteration lazy checkpoints → restore into
    a fresh process-level state → greedy decode matches the live params."""
    cfg = tiny_cfg()
    mgr = CheckpointManager(str(tmp_path), mode="datastates")
    tr = Trainer(cfg, batch=2, seq_len=32, manager=mgr)
    tr.run(3, ckpt_interval=1)
    mgr.wait_for_persist()

    tr2 = Trainer(cfg, batch=2, seq_len=32, manager=mgr)
    tr2.resume()
    assert tr2.step == 3

    prompt = {"tokens": jnp.array([[1, 5, 9, 2]], dtype=jnp.int32)}
    out_live = greedy_generate(cfg, tr.params, prompt, n_new=6)
    out_rest = greedy_generate(cfg, tr2.params, prompt, n_new=6)
    np.testing.assert_array_equal(np.asarray(out_live), np.asarray(out_rest))
    mgr.close()


def test_many_checkpoints_bounded_host_cache(tmp_path):
    """Per-iteration checkpointing with a host cache far smaller than the
    sum of all checkpoints: backpressure (paper §V-A2 'wait for eviction')
    must keep every version intact."""
    state = hetero_state()
    total = sum(np.asarray(x).nbytes
                for x in jax.tree_util.tree_leaves(state)
                if hasattr(x, "shape"))
    mgr = CheckpointManager(str(tmp_path), mode="datastates",
                            host_cache_bytes=max(total + 4096, 1 << 16),
                            chunk_bytes=1 << 12)
    for step in range(1, 6):
        state["meta"]["step"] = step
        mgr.save(step, state)
        mgr.wait_for_capture()
    mgr.wait_for_persist()
    for step in range(1, 6):
        r = mgr.restore(state, step=step)
        assert r["meta"]["step"] == step
    mgr.close()
