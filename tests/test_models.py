"""Per-architecture smoke tests + decode/forward consistency + recurrences."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs, smoke_variant
from repro.data.pipeline import SyntheticTokenPipeline
from repro.models import model as M
from repro.models import layers, rglru, rwkv6
from repro.serving.engine import make_decode_step, make_prefill_step

# Whole-module slow marker: multi-second jit compiles per case; the
# fast lane (scripts/run_tests.sh --fast) deselects these.
pytestmark = pytest.mark.slow

ALL_ARCHS = sorted(list_configs())


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    """Reduced same-family variant: one forward + one train step on CPU,
    asserting output shapes and finiteness (the brief's per-arch smoke)."""
    cfg = smoke_variant(get_config(arch))
    pipe = SyntheticTokenPipeline(cfg, 2, 32)
    batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    logits, aux, _ = M.forward(cfg, params, batch)
    S_out = 32 + cfg.n_prefix_embeds
    if cfg.n_codebooks:
        assert logits.shape == (2, S_out, cfg.n_codebooks, cfg.vocab)
    else:
        assert logits.shape == (2, S_out, cfg.vocab)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()

    from repro.optim.adamw import AdamWConfig, apply_updates, init_opt_state
    opt = init_opt_state(params)
    loss, grads = jax.value_and_grad(lambda p: M.loss_fn(cfg, p, batch))(params)
    assert np.isfinite(float(loss))
    new_params, new_opt = apply_updates(params, opt, grads, AdamWConfig())
    # params actually moved
    moved = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l.astype(jnp.float32)))),
        jax.tree_util.tree_map(
            lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
            new_params, params), 0.0)
    assert moved > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_matches_forward(arch):
    """logits(prefill+decode) == logits(full forward) — the KV-cache /
    recurrent-state decode path is consistent with the parallel path."""
    cfg = smoke_variant(get_config(arch))
    cfg = dataclasses.replace(cfg, max_decode_len=4)
    if cfg.n_experts:
        # capacity dropping is batch-size-dependent (a known MoE artifact):
        # full-forward may drop tokens the 1-token decode never drops. Use a
        # no-drop capacity so the test isolates path equivalence.
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    S = 24
    pipe = SyntheticTokenPipeline(cfg, 2, S)
    batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
    params = M.init_params(cfg, jax.random.PRNGKey(1))

    # full forward over S tokens
    full_logits, _, _ = M.forward(cfg, params, batch)

    # prefill on the first S-1 tokens, then decode token S-1
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :S - 1]
    prefill = make_prefill_step(cfg)
    decode = make_decode_step(cfg)
    plogits, caches = prefill(params, pre_batch)
    np.testing.assert_allclose(
        np.asarray(plogits[:, -1], dtype=np.float32),
        np.asarray(full_logits[:, S - 2 + cfg.n_prefix_embeds],
                   dtype=np.float32),
        atol=5e-2, rtol=5e-2)

    last_tok = batch["tokens"][:, S - 1:S]
    dlogits, _ = decode(params, last_tok, caches,
                        S - 1 + cfg.n_prefix_embeds)
    np.testing.assert_allclose(
        np.asarray(dlogits[:, -1], dtype=np.float32),
        np.asarray(full_logits[:, S - 1 + cfg.n_prefix_embeds],
                   dtype=np.float32),
        atol=5e-2, rtol=5e-2)


def test_rwkv_chunked_matches_stepwise():
    B, T, H, hs = 2, 64, 3, 8
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (B, T, H, hs))
    k = jax.random.normal(ks[1], (B, T, H, hs))
    v = jax.random.normal(ks[2], (B, T, H, hs))
    lw = -jnp.exp(jax.random.normal(ks[3], (B, T, H, hs)) * 0.5)
    u = jax.random.normal(ks[4], (H, hs)) * 0.1
    out_c, S_c = rwkv6.chunked_wkv6(r, k, v, lw, u, chunk=16)
    out_s, S_s = rwkv6.reference_wkv6(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_s),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(S_c), np.asarray(S_s),
                               atol=1e-4, rtol=1e-4)


def test_rwkv_stepwise_state_continuity():
    """Running T steps == running T/2 then T/2 with carried state."""
    B, T, H, hs = 1, 32, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    r = jax.random.normal(ks[0], (B, T, H, hs))
    k = jax.random.normal(ks[1], (B, T, H, hs))
    v = jax.random.normal(ks[2], (B, T, H, hs))
    lw = -jnp.exp(jax.random.normal(ks[3], (B, T, H, hs)) * 0.3)
    u = jax.random.normal(ks[4], (H, hs)) * 0.1
    out_full, S_full = rwkv6.reference_wkv6(r, k, v, lw, u)
    h = T // 2
    out1, S1 = rwkv6.reference_wkv6(r[:, :h], k[:, :h], v[:, :h],
                                    lw[:, :h], u)
    out2, S2 = rwkv6.reference_wkv6(r[:, h:], k[:, h:], v[:, h:],
                                    lw[:, h:], u, initial_state=S1)
    np.testing.assert_allclose(np.asarray(out_full),
                               np.asarray(jnp.concatenate([out1, out2], 1)),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(S_full), np.asarray(S2),
                               atol=1e-5, rtol=1e-5)


def test_rglru_scan_matches_loop():
    B, T, dr = 2, 16, 8
    cfg = smoke_variant(get_config("recurrentgemma-2b"))
    p = rglru.init_rglru_block(
        dataclasses.replace(cfg, d_model=dr, lru_width=dr),
        jax.random.PRNGKey(0))
    u = jax.random.normal(jax.random.PRNGKey(1), (B, T, dr))
    y, h_last = rglru.rg_lru(p, u)
    # manual stepwise recurrence
    uf = np.asarray(u, dtype=np.float32)
    r = np.asarray(jax.nn.sigmoid(u.astype(jnp.float32)
                                  @ p["w_a"].astype(jnp.float32) + p["b_a"]))
    i = np.asarray(jax.nn.sigmoid(u.astype(jnp.float32)
                                  @ p["w_x"].astype(jnp.float32) + p["b_x"]))
    log_a = -rglru.C_FACTOR * np.asarray(jax.nn.softplus(p["lam"])) * r
    a = np.exp(log_a)
    b = np.sqrt(np.clip(1 - np.exp(2 * log_a), 1e-12, None)) * (i * uf)
    h = np.zeros((B, dr), np.float32)
    outs = []
    for t in range(T):
        h = a[:, t] * h + b[:, t]
        outs.append(h.copy())
    want = np.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y, dtype=np.float32), want,
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), want[:, -1],
                               atol=1e-5, rtol=1e-5)


def test_rglru_decode_continuity():
    cfg = smoke_variant(get_config("recurrentgemma-2b"))
    d = cfg.d_model
    p = rglru.init_rglru_block(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 12, d), jnp.float32)
    full, _ = rglru.apply_rglru_block(cfg, p, x)
    out1, st = rglru.apply_rglru_block(cfg, p, x[:, :8])
    outs = [out1]
    for t in range(8, 12):
        o, st = rglru.apply_rglru_block(cfg, p, x[:, t:t + 1], state=st)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got, dtype=np.float32),
                               np.asarray(full, dtype=np.float32),
                               atol=1e-4, rtol=1e-4)


def test_moe_capacity_and_balance():
    from repro.models import moe as moe_mod
    cfg = smoke_variant(get_config("dbrx-132b"))
    p = moe_mod.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.bfloat16)
    out, aux = moe_mod.apply_moe(cfg, p, x)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out, dtype=np.float32)).all()
    assert float(aux) > 0  # load-balance loss active


def test_attention_mask_kinds():
    S = 32
    full = np.asarray(layers.make_mask(S, "full"))
    win = np.asarray(layers.make_mask(S, "window", window=8))
    chk = np.asarray(layers.make_mask(S, "chunked", chunk=8))
    pre = np.asarray(layers.make_mask(S, "full", n_prefix=5))
    assert full[10, :11].all() and not full[10, 11:].any()
    assert win[20, 13:21].all() and not win[20, :13].any()
    assert chk[20, 16:21].all() and not chk[20, :16].any()
    assert pre[2, 4] and pre[0, 4] and not pre[2, 6]


def test_param_count_analytic_close_to_actual():
    for arch in ("llama3.2-1b", "rwkv6-7b", "dbrx-132b"):
        cfg = smoke_variant(get_config(arch))
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(int(np.prod(l.shape))
                     for l in jax.tree_util.tree_leaves(params))
        analytic = M.count_params_analytic(cfg)
        assert abs(actual - analytic) / actual < 0.05, \
            f"{arch}: actual={actual} analytic={analytic}"


def test_full_config_param_counts_match_citations():
    """Assigned configs land near their nameplate parameter counts."""
    expect = {"dbrx-132b": 132e9, "rwkv6-7b": 7.5e9, "starcoder2-7b": 7.2e9,
              "llama3.2-1b": 1.24e9, "command-r-35b": 35e9,
              "gemma3-27b": 27e9, "llama4-maverick-400b-a17b": 400e9}
    for arch, want in expect.items():
        n = get_config(arch).n_params()
        assert 0.7 * want < n < 1.35 * want, f"{arch}: {n/1e9:.1f}B vs {want/1e9:.0f}B"
