"""Tiered checkpoint repository: backends, catalog crash consistency,
cascade flush, tier-by-tier restore, retention GC, and the admin CLI."""

import glob
import os
import shutil
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CheckpointManager, latest_step, step_dir
from repro.serving.engine import load_params_for_serving
from repro.storage import (BackendError, CheckpointRepository, LocalBackend,
                           MemoryBackend, ObjectStoreBackend, RetentionPolicy,
                           StepManifest, Tier, committed_steps, file_checksum,
                           probe_step_complete)
from repro.storage import cli as storage_cli


def tiny_state(tag: float = 0.0):
    return {"model": {"w": jnp.arange(256, dtype=jnp.float32) + tag},
            "meta": {"step": int(tag)}}


# ---------------------------------------------------------------- backends
@pytest.mark.parametrize("make", [
    lambda tmp: LocalBackend(str(tmp / "be")),
    lambda tmp: MemoryBackend(),
    lambda tmp: ObjectStoreBackend(),
], ids=["local", "memory", "object"])
def test_backend_roundtrip(tmp_path, make):
    be = make(tmp_path)
    be.put("a/b/one.bin", b"hello")
    be.put("a/two.bin", b"world!")
    assert be.get("a/b/one.bin") == b"hello"
    assert be.exists("a/two.bin") and not be.exists("a/three.bin")
    assert be.size("a/two.bin") == 6
    assert be.list("a/") == ["a/b/one.bin", "a/two.bin"]
    assert be.list("a/b/") == ["a/b/one.bin"]
    be.delete("a/b/one.bin")
    be.delete("a/b/one.bin")  # idempotent
    assert be.list("") == ["a/two.bin"]
    with pytest.raises(BackendError):
        be.get("a/b/one.bin")


def test_backend_file_helpers(tmp_path):
    src = tmp_path / "payload.bin"
    src.write_bytes(os.urandom(100_000))
    for be in (LocalBackend(str(tmp_path / "l")), MemoryBackend(),
               ObjectStoreBackend(part_bytes=1 << 14)):
        n = be.put_file("k/payload", str(src))
        assert n == 100_000
        dst = str(tmp_path / f"out_{be.name}.bin")
        be.get_file("k/payload", dst)
        assert open(dst, "rb").read() == src.read_bytes()


def test_local_backend_key_escape_rejected(tmp_path):
    be = LocalBackend(str(tmp_path / "root"))
    with pytest.raises(BackendError, match="escapes"):
        be.put("../evil", b"x")


def test_memory_backend_capacity(tmp_path):
    be = MemoryBackend(capacity_bytes=10)
    be.put("a", b"12345")
    with pytest.raises(BackendError, match="full"):
        be.put("b", b"1234567")
    be.put("a", b"1234567890")  # replacing the key is not an overflow
    assert be.used_bytes() == 10


def test_object_store_multipart_visibility():
    be = ObjectStoreBackend()
    uid = be.initiate_multipart("big")
    be.upload_part(uid, 1, b"world")
    be.upload_part(uid, 0, b"hello ")  # out-of-order parts are fine
    assert not be.exists("big"), "partial upload must be invisible"
    be.complete_multipart(uid)
    assert be.get("big") == b"hello world"
    uid2 = be.initiate_multipart("gone")
    be.upload_part(uid2, 0, b"x")
    be.abort_multipart(uid2)
    assert not be.exists("gone")
    with pytest.raises(BackendError):
        be.complete_multipart(uid2)


def test_object_store_put_file_multipart(tmp_path):
    src = tmp_path / "big.bin"
    src.write_bytes(os.urandom(5 << 14))
    be = ObjectStoreBackend(part_bytes=1 << 14)
    be.put_file("big", str(src))
    assert be.stats["n_multipart"] == 1
    assert be.get("big") == src.read_bytes()


def test_object_store_latency_bandwidth_model():
    be = ObjectStoreBackend(latency_s=0.02, bandwidth_mbps=1.0)
    payload = b"x" * 100_000  # 0.1 s at 1 MB/s
    t0 = time.perf_counter()
    be.put("k", payload)
    assert time.perf_counter() - t0 >= 0.1
    t0 = time.perf_counter()
    be.get("k")
    assert time.perf_counter() - t0 >= 0.1


# ---------------------------------------------------------------- manifest
def test_manifest_roundtrip_and_checksum(tmp_path):
    sdir = tmp_path / "global_step5"
    sdir.mkdir()
    (sdir / "rank00000.dsllm").write_bytes(os.urandom(10_000))
    (sdir / "rank00001.dsllm").write_bytes(os.urandom(777))
    m = StepManifest.build(str(sdir), 5, engine_mode="datastates",
                           meta={"note": "hi"})
    m2 = StepManifest.from_json_bytes(m.to_json_bytes())
    assert m2.step == 5 and m2.engine_mode == "datastates"
    assert m2.total_bytes == 10_777 and len(m2.files) == 2
    assert m2.file("rank00001.dsllm").checksum == \
        file_checksum(str(sdir / "rank00001.dsllm"))
    assert m2.meta == {"note": "hi"}


def test_file_checksum_sensitive_to_content(tmp_path):
    p = tmp_path / "f.bin"
    data = bytearray(os.urandom(50_000))
    p.write_bytes(data)
    c0 = file_checksum(str(p))
    data[12_345] ^= 0xFF
    p.write_bytes(data)
    assert file_checksum(str(p)) != c0


def test_probe_step_complete_dsllm(tmp_path):
    state = tiny_state()
    with CheckpointManager(str(tmp_path)) as mgr:
        mgr.save(1, state, blocking=True)
    sdir = step_dir(str(tmp_path), 1)
    assert probe_step_complete(sdir)
    [f] = glob.glob(os.path.join(sdir, "*.dsllm"))
    with open(f, "r+b") as fh:  # chop the footer: probe must reject
        fh.truncate(os.path.getsize(f) // 2)
    assert not probe_step_complete(sdir)


def test_legacy_directory_without_catalog_still_eligible(tmp_path):
    """Pre-repository checkpoints (no catalog at all) resume via the
    completeness probe."""
    state = tiny_state(3.0)
    with CheckpointManager(str(tmp_path)) as mgr:
        mgr.save(3, state, blocking=True)
    shutil.rmtree(str(tmp_path / ".catalog"))  # simulate a legacy layout
    assert latest_step(str(tmp_path)) == 3
    with CheckpointManager(str(tmp_path)) as mgr:
        out = mgr.restore(tiny_state())
        assert float(out["model"]["w"][3]) == 6.0


# ------------------------------------------------- catalog crash consistency
def test_killed_save_is_never_resume_eligible(tmp_path, monkeypatch):
    """Acceptance: kill a save after data files exist but before the
    manifest commit — latest_step skips it, restore falls back to the
    previous complete step, and `cli verify` flags the orphan for GC."""
    state1, state2 = tiny_state(1.0), tiny_state(2.0)
    with CheckpointManager(str(tmp_path)) as mgr:
        mgr.save(1, state1, blocking=True)
        # "kill" the process inside the commit window: data files fully
        # persisted, manifest never written.
        monkeypatch.setattr(CheckpointRepository, "commit_step",
                            lambda self, step, **kw: None)
        mgr.save(2, state2, blocking=True)
    monkeypatch.undo()
    assert os.path.isdir(step_dir(str(tmp_path), 2))  # data landed...
    assert latest_step(str(tmp_path)) == 1            # ...but not eligible
    with CheckpointManager(str(tmp_path)) as mgr:
        assert mgr.latest_step() == 1
        out = mgr.restore(tiny_state())               # falls back to step 1
        assert mgr.last_restored_step == 1
        assert float(out["model"]["w"][0]) == 1.0
    # the CLI flags the orphan and a non-zero exit gates automated resume
    assert storage_cli.main(["--root", str(tmp_path), "verify"]) == 1
    # the default grace window protects what *might* be a live save from
    # another process...
    assert storage_cli.main(["--root", str(tmp_path), "gc",
                             "--orphans"]) == 0
    assert os.path.isdir(step_dir(str(tmp_path), 2))
    # ...but this one is known dead: GC cleans it (and only it)
    assert storage_cli.main(["--root", str(tmp_path), "gc", "--orphans",
                             "--orphan-grace", "0"]) == 0
    assert not os.path.isdir(step_dir(str(tmp_path), 2))
    assert os.path.isdir(step_dir(str(tmp_path), 1))
    assert storage_cli.main(["--root", str(tmp_path), "verify"]) == 0


def test_restore_falls_back_past_damaged_committed_step(tmp_path):
    """Damage *after* commit: the newest step indexes but fails integrity;
    step=None restore walks back to the previous complete step."""
    with CheckpointManager(str(tmp_path)) as mgr:
        mgr.save(1, tiny_state(1.0), blocking=True)
        mgr.save(2, tiny_state(2.0), blocking=True)
    for p in glob.glob(os.path.join(step_dir(str(tmp_path), 2), "*.dsllm")):
        with open(p, "r+b") as f:
            f.truncate(max(os.path.getsize(p) // 2, 1))
    with CheckpointManager(str(tmp_path)) as mgr:
        out = mgr.restore(tiny_state())
        assert mgr.last_restored_step == 1
        assert float(out["model"]["w"][0]) == 1.0
        # an explicit step request still surfaces the corruption
        with pytest.raises(Exception):
            mgr.restore(tiny_state(), step=2)


def test_verify_detects_bitrot(tmp_path):
    with CheckpointManager(str(tmp_path)) as mgr:
        mgr.save(1, tiny_state(1.0), blocking=True)
        [f] = glob.glob(os.path.join(step_dir(str(tmp_path), 1), "*.dsllm"))
        with open(f, "r+b") as fh:  # flip one payload byte, size unchanged
            fh.seek(100)
            b = fh.read(1)
            fh.seek(100)
            fh.write(bytes([b[0] ^ 0xFF]))
        res = mgr.repository.verify_step(1)
        assert not res.ok and res.checksum_mismatch
    assert storage_cli.main(["--root", str(tmp_path), "verify"]) == 1
    assert storage_cli.main(["--root", str(tmp_path), "verify",
                             "--fast"]) == 0  # sizes alone can't see it


def test_verify_localizes_tampered_raw_keyframe_chunk(tmp_path):
    """Raw keyframes carry fused per-chunk digests too: when the
    whole-file checksum fails, verify names the flipped chunk instead of
    leaving a multi-GB haystack."""
    from repro.core.layout import FileReader
    with CheckpointManager(str(tmp_path)) as mgr:
        mgr.save(1, tiny_state(1.0), blocking=True)
        [f] = glob.glob(os.path.join(step_dir(str(tmp_path), 1), "*.dsllm"))
        fr = FileReader(f)
        name, e = sorted(fr.tensors.items())[0]
        assert e.raw_chunks and all(d is not None
                                    for _, _, d in e.raw_chunks)
        lo, hi, _dig = e.raw_chunks[0]
        with open(f, "r+b") as fh:  # flip a byte inside that chunk
            fh.seek(e.offset + lo + (hi - lo) // 2)
            b = fh.read(1)
            fh.seek(e.offset + lo + (hi - lo) // 2)
            fh.write(bytes([b[0] ^ 0xFF]))
        res = mgr.repository.verify_step(1)
        assert not res.ok and res.checksum_mismatch
        assert any(f"{name} raw chunk [{lo}:{hi})" in m
                   for m in res.chunk_mismatch)
        assert any("(chunk)" in p for p in res.problems)


def test_streamed_checksums_commit_clean_and_catch_fused_tamper(tmp_path):
    """The fused-encode pipeline streams the whole-file checksum at write
    time and the commit lane reuses it instead of re-reading the shard:
    the committed manifest hash must equal an independent read-back hash
    for delta- and quantized-encoded shards alike, `verify` must pass
    clean, and a post-commit byte flip inside a fused payload must fail
    it — proving the reused (never re-read) hash still audits the disk."""
    from faults import tamper_file
    from repro.core import (CheckpointPolicy, DeltaPolicy,
                            StateProviderRegistry)
    from repro.core.layout import FileReader

    rng = np.random.default_rng(0)
    def state(i):
        return {"model": {"w": jnp.asarray(
                    rng.standard_normal(65_536).astype(np.float32)) + i},
                "optimizer": {"m": jnp.asarray(
                    rng.standard_normal(65_536).astype(np.float32))},
                "meta": {"step": i}}

    pol = CheckpointPolicy(
        delta=DeltaPolicy(keyframe_every=2),
        providers=(StateProviderRegistry()
                   .add_rule(provider="quantized", domain="optimizer",
                             dtype="float32")
                   .add_rule(provider="auto")))
    with CheckpointManager.from_policy(str(tmp_path), pol) as mgr:
        mgr.save(1, state(1), blocking=True)   # keyframe + quantized
        mgr.save(2, state(2), blocking=True)   # delta + quantized
        mgr.wait_for_commit(2)
        for s in (1, 2):
            man = mgr.repository.manifest(s)
            for fe in man.files:
                path = os.path.join(step_dir(str(tmp_path), s), fe.name)
                assert fe.checksum == file_checksum(path), (s, fe.name)
    assert storage_cli.main(["--root", str(tmp_path), "verify"]) == 0
    # flip a byte inside a fused-encoded chunk of the delta step's shard
    sdir = step_dir(str(tmp_path), 2)
    [f] = glob.glob(os.path.join(sdir, "*.dsllm"))
    enc = [c for t in FileReader(f).tensors.values()
           for c in (t.enc_chunks or ())]
    assert enc and all(c[4] is not None for c in enc), \
        "fused per-chunk digests missing from the footer"
    tamper_file(f, offset=enc[0][0] + 3, nbytes=1)
    assert storage_cli.main(["--root", str(tmp_path), "verify"]) == 1
    assert storage_cli.main(["--root", str(tmp_path), "verify",
                             "--step", "2"]) == 1


# ------------------------------------------------------- cascade + restore
def test_cascade_replicates_and_rehydrates(tmp_path):
    remote = Tier("peer", MemoryBackend())
    with CheckpointManager(str(tmp_path), tiers=[remote]) as mgr:
        mgr.save(1, tiny_state(1.0), blocking=True)
        mgr.save(2, tiny_state(2.0), blocking=True)
        mgr.repository.wait_cascaded()
        assert mgr.repository.tier_steps(remote) == [1, 2]
        assert not mgr.repository.cascade_errors
        assert len(mgr.repository.cascade_log) == 2
        # blow away the local copy of step 1 entirely
        mgr.repository._delete_local_step(1)
        assert mgr.repository.local_steps() == [2]
        assert mgr.repository.steps() == [1, 2]  # still resumable
        out = mgr.restore(tiny_state(), step=1)  # tier-by-tier fallback
        assert float(out["model"]["w"][0]) == 1.0
        assert mgr.repository.local_steps() == [1, 2]  # re-hydrated


def test_cascade_manifest_uploaded_last_makes_step_atomic(tmp_path):
    """A step is complete-on-tier iff its manifest object exists; data
    objects alone must not count."""
    repo = CheckpointRepository(str(tmp_path), auto_cascade=False)
    sdir = repo.begin_step(7)
    with open(os.path.join(sdir, "rank00000.dsllm"), "wb") as f:
        f.write(os.urandom(4096))
    repo.commit_step(7)
    tier = Tier("s3", ObjectStoreBackend())
    repo.remote_tiers = [tier]
    tier.backend.put("global_step7/rank00000.dsllm", b"partial junk")
    assert not repo.tier_has_step(tier, 7)
    repo.cascade_step(7)
    assert repo.tier_has_step(tier, 7)
    assert tier.backend.get("global_step7/rank00000.dsllm") != b"partial junk"
    repo.close()


def test_serving_from_remote_tier(tmp_path):
    """GC evicts the local copy; serving re-hydrates from the object tier."""
    remote = Tier("s3", ObjectStoreBackend())
    state = tiny_state(5.0)
    with CheckpointManager(str(tmp_path), tiers=[remote]) as mgr:
        mgr.save(5, state, blocking=True)
        mgr.repository.wait_cascaded()
        mgr.repository._delete_local_step(5)
        params, stats = load_params_for_serving(
            str(tmp_path), {"w": jnp.zeros(256, jnp.float32)},
            repository=mgr.repository)
        np.testing.assert_array_equal(np.asarray(params["w"]),
                                      np.asarray(state["model"]["w"]))
        assert stats.bytes_read > 0


def test_restore_falls_back_past_damaged_remote_copy(tmp_path):
    """Remote bitrot on the newest step: its re-hydration fails the
    checksum audit and the step=None walk falls back to the previous
    complete step instead of aborting."""
    remote = Tier("peer", MemoryBackend())
    with CheckpointManager(str(tmp_path), tiers=[remote]) as mgr:
        mgr.save(1, tiny_state(1.0), blocking=True)
        mgr.save(2, tiny_state(2.0), blocking=True)
        mgr.repository.wait_cascaded()
        mgr.repository._delete_local_step(2)
        # flip a byte of step 2's remote data object, size unchanged
        [key] = [k for k in remote.backend.list("global_step2/")]
        blob = bytearray(remote.backend.get(key))
        blob[100] ^= 0xFF
        remote.backend.put(key, bytes(blob))
        out = mgr.restore(tiny_state())
        assert mgr.last_restored_step == 1
        assert float(out["model"]["w"][0]) == 1.0


def test_fetch_tries_next_tier_when_first_is_damaged(tmp_path):
    """Tier-by-tier really means per *tier*: a damaged copy on the fast
    remote tier falls through to a good copy on the slower one."""
    fast, slow = Tier("peer", MemoryBackend()), Tier("s3", MemoryBackend())
    with CheckpointManager(str(tmp_path), tiers=[fast, slow]) as mgr:
        mgr.save(1, tiny_state(1.0), blocking=True)
        mgr.repository.wait_cascaded()
        mgr.repository._delete_local_step(1)
        [key] = [k for k in fast.backend.list("global_step1/")]
        fast.backend.delete(key)  # manifest present, data object gone
        out = mgr.restore(tiny_state(), step=1)
        assert float(out["model"]["w"][0]) == 1.0


def test_resave_clears_stale_shards(tmp_path):
    """Re-saving a step must not let old extra files survive into the new
    manifest (elastic rewind to fewer shards)."""
    repo = CheckpointRepository(str(tmp_path), checksum=False)
    sdir = repo.begin_step(9)
    for n in ("rank00000.dsllm", "rank00001.dsllm"):
        with open(os.path.join(sdir, n), "wb") as f:
            f.write(os.urandom(512))
    repo.commit_step(9)
    assert len(repo.manifest(9).files) == 2
    sdir = repo.begin_step(9)  # rewind onto a 1-shard layout
    assert os.listdir(sdir) == []
    with open(os.path.join(sdir, "rank00000.dsllm"), "wb") as f:
        f.write(os.urandom(256))
    m = repo.commit_step(9)
    assert [fe.name for fe in m.files] == ["rank00000.dsllm"]
    repo.close()


def test_cli_verify_missing_step_fails(tmp_path):
    with CheckpointManager(str(tmp_path)) as mgr:
        mgr.save(1, tiny_state(1.0), blocking=True)
    assert storage_cli.main(["--root", str(tmp_path), "verify",
                             "--step", "999"]) == 1
    assert storage_cli.main(["--root", str(tmp_path), "verify",
                             "--step", "1"]) == 0


def test_cli_verify_orphan_grace_spares_fresh_inflight(tmp_path):
    with CheckpointManager(str(tmp_path)) as mgr:
        mgr.save(1, tiny_state(1.0), blocking=True)
    repo = CheckpointRepository(str(tmp_path), auto_cascade=False)
    sdir = repo.begin_step(2)  # looks in-flight from any other process
    with open(os.path.join(sdir, "rank00000.dsllm"), "wb") as f:
        f.write(os.urandom(128))
    root = str(tmp_path)
    assert storage_cli.main(["--root", root, "verify"]) == 1  # strict
    assert storage_cli.main(["--root", root, "verify",
                             "--orphan-grace", "3600"]) == 0  # monitoring
    repo.close()


def test_resave_after_cascade_reuploads_fresh_bytes(tmp_path):
    """Rewind-and-resave of an already-cascaded step must replace the
    remote copy — otherwise a later local eviction would re-hydrate the
    stale bytes."""
    remote = Tier("peer", MemoryBackend())
    with CheckpointManager(str(tmp_path), tiers=[remote]) as mgr:
        mgr.save(4, tiny_state(4.0), blocking=True)
        mgr.repository.wait_cascaded()
        mgr.save(4, tiny_state(40.0), blocking=True)  # rewind, new content
        mgr.repository.wait_cascaded()
        assert not mgr.repository.cascade_errors
        mgr.repository._delete_local_step(4)
        out = mgr.restore(tiny_state(), step=4)       # re-hydrate
        assert float(out["model"]["w"][0]) == 40.0, "stale remote bytes"


# ------------------------------------------------------------ retention GC
def test_retention_policy_math():
    p = RetentionPolicy(keep_last_n=2, keep_every_k=10)
    assert p.retained([1, 5, 10, 11, 12]) == {10, 11, 12}
    assert RetentionPolicy().retained([1, 2, 3]) == {1, 2, 3}
    assert RetentionPolicy(keep_every_k=4).retained([2, 4, 7, 8]) == {4, 8}


def test_gc_keeps_last_n_pins_and_newest(tmp_path):
    with CheckpointManager(
            str(tmp_path),
            retention=RetentionPolicy(keep_last_n=2)) as mgr:
        mgr.save(1, tiny_state(1.0), blocking=True)
        mgr.repository.pin(1)
        for s in (2, 3, 4, 5, 6):
            mgr.save(s, tiny_state(float(s)), blocking=True)
        mgr.drain()
        kept = mgr.repository.local_steps()
        assert kept == [1, 5, 6]  # pinned + last 2 (newest included)
        assert mgr.repository.gc_log  # auto-GC ran on commit
        # pinned step still restores bit-exact
        out = mgr.restore(tiny_state(), step=1)
        assert float(out["model"]["w"][0]) == 1.0


def test_gc_never_deletes_mid_cascade_step(tmp_path):
    """A step being cascaded is protected even when retention would drop
    it; once the cascade lands it becomes collectible."""
    slow = Tier("slow-s3", ObjectStoreBackend(latency_s=0.15))
    repo = CheckpointRepository(str(tmp_path), remote_tiers=[slow],
                                retention=RetentionPolicy(keep_last_n=1),
                                auto_gc=False, checksum=False)
    for s in (1, 2):
        sdir = repo.begin_step(s)
        with open(os.path.join(sdir, "rank00000.dsllm"), "wb") as f:
            f.write(os.urandom(2048))
        repo.commit_step(s)
    report = repo.gc()  # both steps still queued/cascading: keep-last-1
    assert 1 not in report.deleted_steps, "mid-cascade step deleted"
    assert repo.local_steps() == [1, 2]
    repo.wait_cascaded()
    report = repo.gc()
    assert report.deleted_steps == [1]
    assert repo.local_steps() == [2]
    assert repo.tier_steps(slow) == [1, 2]  # the cascade still landed
    repo.close()


def test_gc_dry_run_and_remote_retention(tmp_path):
    remote = Tier("s3", ObjectStoreBackend(),
                  retention=RetentionPolicy(keep_last_n=2))
    repo = CheckpointRepository(str(tmp_path), remote_tiers=[remote],
                                checksum=False)
    for s in (1, 2, 3):
        sdir = repo.begin_step(s)
        with open(os.path.join(sdir, "rank00000.dsllm"), "wb") as f:
            f.write(os.urandom(1024))
        repo.commit_step(s)
    repo.wait_cascaded()
    dry = repo.gc(retention=RetentionPolicy(keep_last_n=1), dry_run=True)
    assert dry.deleted_steps == [1, 2] and dry.bytes_freed > 0
    assert repo.local_steps() == [1, 2, 3]  # dry run touched nothing
    real = repo.gc(retention=RetentionPolicy(keep_last_n=1))
    assert repo.local_steps() == [3]
    assert real.remote_deleted == {"s3": [1]}
    assert repo.tier_steps(remote) == [2, 3]
    repo.close()


# -------------------------------------------------------------------- CLI
def test_cli_ls_pin_unpin_gc(tmp_path, capsys):
    with CheckpointManager(str(tmp_path)) as mgr:
        for s in (1, 2, 3):
            mgr.save(s, tiny_state(float(s)), blocking=True)
    root = str(tmp_path)
    assert storage_cli.main(["--root", root, "pin", "2"]) == 0
    assert storage_cli.main(["--root", root, "ls"]) == 0
    out = capsys.readouterr().out
    assert "step          2" in out and "[pinned]" in out
    assert "format=dsllm" in out
    assert storage_cli.main(["--root", root, "gc", "--keep-last", "1"]) == 0
    assert committed_steps(root) == [2, 3]  # pinned + newest survive
    assert storage_cli.main(["--root", root, "unpin", "2"]) == 0
    assert storage_cli.main(["--root", root, "gc", "--keep-last", "1"]) == 0
    assert committed_steps(root) == [3]
    assert storage_cli.main(["--root", root, "verify"]) == 0
