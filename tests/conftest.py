import os
import sys

# Tests must see the real device count (1 CPU) — never the dry-run's 512.
os.environ.pop("XLA_FLAGS", None)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def run_in_subprocess(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Run ``code`` in a fresh interpreter with N virtual CPU devices."""
    import subprocess
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"subprocess failed:\n{out.stdout}\n{out.stderr}"
    return out.stdout
