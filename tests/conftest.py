import faulthandler
import os
import sys

# Tests must see the real device count (1 CPU) — never the dry-run's 512.
os.environ.pop("XLA_FLAGS", None)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


def pytest_configure(config):
    # Hung-test watchdog: the concurrency suites exercise real lock/barrier
    # interleavings, so a regression can deadlock rather than fail. Dump
    # every thread's stack if the run wedges — CI then shows the deadlock
    # instead of a silent job kill. REPRO_TEST_DUMP_AFTER_S=0 disables.
    timeout = float(os.environ.get("REPRO_TEST_DUMP_AFTER_S", "900"))
    if timeout > 0:
        faulthandler.enable()
        faulthandler.dump_traceback_later(timeout, repeat=True, exit=False)


def pytest_unconfigure(config):
    faulthandler.cancel_dump_traceback_later()

# ---------------------------------------------------------------------------
# `hypothesis` is an optional dev dependency (see requirements-dev.txt).
# Property-based tests import `given`/`settings`/`strategies` from here: when
# hypothesis is missing they collect fine and skip individually, while the
# plain tests in the same modules keep running.
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    HAS_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for `st.<anything>(...)` at decoration time."""

        def __getattr__(self, name):
            return lambda *a, **k: self

        def __call__(self, *a, **k):
            return self

    st = _AnyStrategy()
    HealthCheck = _AnyStrategy()

    def given(*a, **k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)

    def settings(*a, **k):
        return lambda f: f


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def run_in_subprocess(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Run ``code`` in a fresh interpreter with N virtual CPU devices."""
    import subprocess
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"subprocess failed:\n{out.stdout}\n{out.stderr}"
    return out.stdout
