"""Differential/compressed checkpointing (beyond-paper, kernel-backed)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import HealthCheck, given, settings, st

from repro.core.reduction import (DifferentialCheckpointer, decode_tensor,
                                  encode_tensor)


def test_encode_decode_raw_lossless():
    x = jax.random.normal(jax.random.PRNGKey(0), (100, 37), jnp.float32)
    enc, work = encode_tensor(x)
    out = decode_tensor(enc)
    np.testing.assert_array_equal(out, np.asarray(x))


def test_encode_decode_delta_lossless():
    x0 = jax.random.normal(jax.random.PRNGKey(0), (1000,), jnp.float32)
    x1 = x0.at[::7].add(0.001)  # small sparse change
    enc0, w0 = encode_tensor(x0)
    enc1, _w1 = encode_tensor(x1, prev=w0)
    assert enc1.codec == "delta-xor"
    out = decode_tensor(enc1, prev=np.asarray(x0))
    np.testing.assert_array_equal(out, np.asarray(x1))


def test_delta_compresses_identical_state_massively():
    x = jax.random.normal(jax.random.PRNGKey(1), (1 << 16,), jnp.float32)
    _enc0, w0 = encode_tensor(x)
    enc1, _ = encode_tensor(x, prev=w0)          # unchanged -> all-zero XOR
    assert len(enc1.payload) < x.nbytes / 100    # >100x on the delta


def test_quantized_encode_bounded_error():
    x = jax.random.normal(jax.random.PRNGKey(2), (256, 256), jnp.float32)
    enc, _ = encode_tensor(x, quant="int8")
    assert enc.quant == "int8"
    out = decode_tensor(enc).astype(np.float32)
    # reconstruct with scales (same codec the encoder used — zstd or zlib)
    from repro.core.reduction import _decompress
    scales = np.frombuffer(_decompress(enc.scales),
                           np.float32).reshape(256, 1)
    err = np.abs(out * scales - np.asarray(x))
    assert (err <= scales + 1e-6).all()


def test_differential_checkpointer_roundtrip(tmp_path):
    tree0 = {"a": jnp.arange(4096, dtype=jnp.float32),
             "b": {"c": jnp.ones((64, 64), jnp.float32)}}
    ck = DifferentialCheckpointer(str(tmp_path), keyframe_every=3)
    ck.save(0, tree0)
    tree1 = {"a": tree0["a"] + 1, "b": {"c": tree0["b"]["c"] * 2}}
    info1 = ck.save(1, tree1)
    assert not info1["keyframe"]
    tree2 = {"a": tree1["a"] * 0.5, "b": {"c": tree1["b"]["c"] - 3}}
    ck.save(2, tree2)

    for step, tree in ((0, tree0), (1, tree1), (2, tree2)):
        state = ck.restore(step)
        np.testing.assert_array_equal(state["['a']"], np.asarray(tree["a"]))
        np.testing.assert_array_equal(state["['b']['c']"],
                                      np.asarray(tree["b"]["c"]))


def test_differential_checkpointer_restart_continues_chain(tmp_path):
    """ISSUE-4 satellite bugfix: a restarted process must derive its
    keyframe/chain state from disk. Pre-fix, a restart reset _n_saves
    with an empty _prev, so a cadence-said-delta save was written
    ``keyframe=False`` while actually raw-encoded and restore() died on
    its ``chain[0]["keyframe"]`` assertion."""
    t0 = {"a": jnp.arange(1000, dtype=jnp.float32)}
    t1 = {"a": t0["a"].at[::9].add(1.0)}
    t2 = {"a": t1["a"].at[::9].add(1.0)}
    t3 = {"a": t2["a"].at[::9].add(1.0)}
    ck = DifferentialCheckpointer(str(tmp_path), keyframe_every=4)
    ck.save(0, t0)
    ck.save(1, t1)
    # process restart
    ck2 = DifferentialCheckpointer(str(tmp_path), keyframe_every=4)
    assert ck2._n_saves == 2  # cadence derived from disk
    info = ck2.save(2, t2)
    # the chain *continues* as deltas (bases re-armed from disk)...
    assert not info["keyframe"]
    import pickle
    with open(os.path.join(tmp_path, "diff_00000002.pkl"), "rb") as fh:
        rec = pickle.load(fh)
    assert all(e.codec == "delta-xor" for e in rec["tensors"].values())
    ck2.save(3, t3)
    # ...and every step restores across the restart boundary
    for step, tree in ((0, t0), (1, t1), (2, t2), (3, t3)):
        state = DifferentialCheckpointer(str(tmp_path)).restore(step)
        np.testing.assert_array_equal(state["['a']"], np.asarray(tree["a"]))


def test_differential_checkpointer_restart_with_damaged_tail(tmp_path):
    """If the on-disk chain tail is unreadable at restart, the next save
    must fall back to a keyframe (never a delta against nothing)."""
    t0 = {"a": jnp.arange(512, dtype=jnp.float32)}
    ck = DifferentialCheckpointer(str(tmp_path), keyframe_every=4)
    ck.save(0, t0)
    ck.save(1, {"a": t0["a"] + 1})
    for f in sorted(os.listdir(tmp_path)):  # corrupt every record
        with open(os.path.join(tmp_path, f), "r+b") as fh:
            fh.truncate(8)
    ck2 = DifferentialCheckpointer(str(tmp_path), keyframe_every=4)
    t2 = {"a": t0["a"] + 2}
    info = ck2.save(2, t2)
    assert info["keyframe"]  # forced: no usable bases on disk
    state = ck2.restore(2)
    np.testing.assert_array_equal(state["['a']"], np.asarray(t2["a"]))


# ----------------------------------------------- property-based round-trips
_PROP_DTYPES = ("float32", "float16", "int32", "uint8", "int8")


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       dtype=st.sampled_from(_PROP_DTYPES),
       shape=st.lists(st.integers(1, 17), min_size=0, max_size=3),
       n_deltas=st.integers(0, 3))
def test_property_encode_decode_roundtrip(seed, dtype, shape, n_deltas):
    """encode/decode is bit-exact for arbitrary dtypes/shapes (odd sizes
    exercise the u32-padding path) through raw and delta-chain codecs."""
    rng = np.random.default_rng(seed)
    dt = np.dtype(dtype)
    shape = tuple(shape)
    if dt.kind == "f":
        arr = rng.standard_normal(shape).astype(dt)
    else:
        arr = rng.integers(0, 100, size=shape).astype(dt)
    enc, work = encode_tensor(jnp.asarray(arr))
    assert enc.codec == "raw"
    np.testing.assert_array_equal(decode_tensor(enc), arr)
    cur, prev_work, prev_dec = arr, work, arr
    for _ in range(n_deltas):
        nxt = np.array(cur, copy=True)
        flat = nxt.reshape(-1)
        if flat.size:
            idx = rng.integers(0, flat.size, size=max(1, flat.size // 7))
            flat[idx] += np.asarray(1, dt) if dt.kind != "f" \
                else np.asarray(0.5, dt)
        enc, work = encode_tensor(jnp.asarray(nxt), prev=prev_work)
        if cur.size:
            assert enc.codec == "delta-xor"
        dec = decode_tensor(enc, prev=np.asarray(prev_dec))
        np.testing.assert_array_equal(dec, nxt)
        cur, prev_work, prev_dec = nxt, work, dec


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       quant=st.sampled_from(("none", "bf16", "int8")),
       then_delta=st.booleans())
def test_property_quant_delta_codec_mixes(seed, quant, then_delta):
    """raw↔delta↔quant mixes: quantized encodes chain with deltas in the
    quantized working domain and decode returns that domain bit-exactly."""
    rng = np.random.default_rng(seed)
    x0 = rng.standard_normal((256, 256)).astype(np.float32)
    enc0, w0 = encode_tensor(jnp.asarray(x0), quant=quant)
    assert enc0.quant == quant
    dec0 = decode_tensor(enc0)
    np.testing.assert_array_equal(dec0,
                                  np.asarray(w0).reshape(dec0.shape))
    if not then_delta:
        return
    x1 = np.array(x0, copy=True)
    x1[::5] += 0.25
    enc1, _w1 = encode_tensor(jnp.asarray(x1), quant=quant, prev=w0)
    assert enc1.codec == "delta-xor"
    dec1 = decode_tensor(enc1, prev=dec0)
    _enc_ref, w1_ref = encode_tensor(jnp.asarray(x1), quant=quant)
    np.testing.assert_array_equal(dec1,
                                  np.asarray(w1_ref).reshape(dec1.shape))


def test_differential_smaller_than_full_for_slow_state(tmp_path):
    """Adam moments move slowly -> deltas ≪ keyframes."""
    base = jax.random.normal(jax.random.PRNGKey(3), (1 << 15,), jnp.float32)
    ck = DifferentialCheckpointer(str(tmp_path), keyframe_every=10)
    i0 = ck.save(0, {"m": base})
    i1 = ck.save(1, {"m": base})                 # unchanged
    assert i1["compressed_bytes"] < i0["compressed_bytes"] / 50


# ------------------------------------------------- zstd→zlib fallback path
# (PR-1 made `zstandard` optional; these tests keep that path honest by
# roundtripping both codecs and cross-decoding via zstd-frame sniffing.)

from repro.core import reduction as R


def test_zlib_fallback_roundtrip(monkeypatch):
    """With zstandard absent, encode/decode must roundtrip via zlib."""
    monkeypatch.setattr(R, "zstandard", None)
    x = jax.random.normal(jax.random.PRNGKey(7), (333,), jnp.float32)
    enc, _work = encode_tensor(x)
    assert enc.payload[:4] != R._ZSTD_MAGIC  # really a zlib frame
    np.testing.assert_array_equal(decode_tensor(enc), np.asarray(x))


def test_zlib_payload_decodes_under_either_install(monkeypatch):
    """A checkpoint written on a zlib-only box must read back on a box
    with zstandard installed: _decompress sniffs the frame, it does not
    trust the local default codec."""
    monkeypatch.setattr(R, "zstandard", None)
    payload = R._compress(b"cross-install bytes" * 100)
    monkeypatch.undo()  # whatever this box actually has
    assert R._decompress(payload) == b"cross-install bytes" * 100


@pytest.mark.skipif(R.zstandard is None, reason="zstandard not installed")
def test_zstd_payload_roundtrip_and_rejection_without_zstd(monkeypatch):
    """zstd frames decode when the module is present and fail with an
    actionable error (not silent corruption) when it is not."""
    x = jax.random.normal(jax.random.PRNGKey(8), (222,), jnp.float32)
    enc, _ = encode_tensor(x)
    assert enc.payload[:4] == R._ZSTD_MAGIC
    np.testing.assert_array_equal(decode_tensor(enc), np.asarray(x))
    monkeypatch.setattr(R, "zstandard", None)
    with pytest.raises(RuntimeError, match="zstandard"):
        R._decompress(enc.payload)


def test_zstd_frame_sniffing_rejects_with_clear_error(monkeypatch):
    """Even on a zlib-only install, a zstd frame is *recognized* (magic
    sniff) and refused with install guidance — never fed to zlib."""
    monkeypatch.setattr(R, "zstandard", None)
    fake_zstd_frame = R._ZSTD_MAGIC + b"\x00" * 32
    with pytest.raises(RuntimeError, match="pip install zstandard"):
        R._decompress(fake_zstd_frame)


def test_differential_checkpointer_cross_codec_restore(tmp_path, monkeypatch):
    """Saves written with the fallback codec restore identically — the
    whole differential chain (keyframe ⊕ deltas) survives a codec switch
    between save and restore."""
    tree0 = {"a": jnp.arange(512, dtype=jnp.float32)}
    tree1 = {"a": tree0["a"].at[::5].add(1.0)}
    monkeypatch.setattr(R, "zstandard", None)  # write zlib
    ck = DifferentialCheckpointer(str(tmp_path), keyframe_every=4)
    ck.save(0, tree0)
    ck.save(1, tree1)
    monkeypatch.undo()  # read with the real install (zstd if present)
    ck2 = DifferentialCheckpointer(str(tmp_path))
    state = ck2.restore(1)
    np.testing.assert_array_equal(state["['a']"], np.asarray(tree1["a"]))
