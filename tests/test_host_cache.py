"""Pinned host-cache allocator: blocking back-pressure + interval invariants."""

import threading
import time

import numpy as np
import pytest
from conftest import given, settings, st  # hypothesis, optional

from repro.core.host_cache import CacheFullError, HostCache


def test_reserve_release_reuse():
    c = HostCache(1000)
    r1 = c.reserve(400)
    r2 = c.reserve(400)
    assert c.used_bytes() == 800
    with pytest.raises(CacheFullError):
        c.reserve(400, timeout=0.05)
    r1.release()
    r3 = c.reserve(400)  # reuses r1's interval
    assert r3.start == r1.start
    r2.release(); r3.release()
    assert c.used_bytes() == 0


def test_zero_copy_view():
    c = HostCache(1 << 16)
    r = c.reserve(256)
    arr = r.array(np.float32, (64,))
    arr[:] = np.arange(64, dtype=np.float32)
    # the same bytes are visible through a second view of the reservation
    again = np.frombuffer(r.view, dtype=np.float32)
    np.testing.assert_array_equal(again, np.arange(64, dtype=np.float32))


def test_oversized_request_raises():
    c = HostCache(100)
    with pytest.raises(CacheFullError, match="exceeds"):
        c.reserve(101)


def test_blocking_backpressure_unblocks():
    """A reserve that must wait is released when space frees (paper §V-A2:
    'the next checkpoint request needs to wait for previous tensors to get
    evicted')."""
    c = HostCache(100)
    r1 = c.reserve(80)
    got = {}

    def waiter():
        got["r"] = c.reserve(50, timeout=5)

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.05)
    assert "r" not in got          # still blocked
    r1.release()
    t.join(timeout=5)
    assert "r" in got
    got["r"].release()


def test_peak_usage_tracking():
    c = HostCache(1000)
    rs = [c.reserve(200) for _ in range(4)]
    assert c.peak_usage == 800
    for r in rs:
        r.release()
    c.reserve(100).release()
    assert c.peak_usage == 800  # historical peak


def test_parallel_reserve_release_stress():
    """Many threads hammering reserve/hold/release concurrently: no
    overlap, no lost frees, no deadlock (the flush pool + stage lane +
    producer lanes all hit the allocator at once in the real engine)."""
    c = HostCache(1 << 16)
    errors = []
    barrier = threading.Barrier(8)

    def worker(seed: int) -> None:
        rng = np.random.default_rng(seed)
        barrier.wait()
        try:
            for _ in range(200):
                r = c.reserve(int(rng.integers(1, 2048)), timeout=10)
                arr = r.array(np.uint8, (r.nbytes,))
                arr[:] = seed % 251  # touch the memory through the view
                if int(arr[0]) != seed % 251:
                    raise AssertionError("reservation bytes not visible")
                r.release()
        except BaseException as exc:  # noqa: BLE001
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not errors, errors
    assert c.used_bytes() == 0
    assert c.peak_usage <= c.capacity


def test_fragmentation_after_interleaved_frees():
    """Interleaved frees leave non-adjacent gaps: a request larger than
    every gap must block even though the *total* free space would fit it,
    and succeed once the middle allocation frees (gaps coalesce because
    the free list is derived from the live intervals)."""
    c = HostCache(950)
    r1 = c.reserve(300)   # [0, 300)
    r2 = c.reserve(300)   # [300, 600)
    r3 = c.reserve(300)   # [600, 900)
    r1.release()
    r3.release()
    # free = [0,300) + [600,950): 650 B total, largest gap 350 B
    assert c.used_bytes() == 300
    with pytest.raises(CacheFullError):
        c.reserve(380, timeout=0.05)
    small = c.reserve(350)            # fits the tail gap exactly
    assert small.start == 600
    small.release()
    r2.release()                      # now one contiguous 950 B gap
    big = c.reserve(380)
    assert big.start == 0
    big.release()
    assert c.used_bytes() == 0


def test_backpressure_wakeup_ordering():
    """When space frees, exactly the waiters that fit proceed; the rest
    keep waiting until more space frees (notify_all + re-check loop)."""
    c = HostCache(100)
    r = c.reserve(100)
    satisfied = []
    lock = threading.Lock()

    def waiter(idx: int) -> None:
        got = c.reserve(60, timeout=10)
        with lock:
            satisfied.append((idx, got))

    threads = [threading.Thread(target=waiter, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    time.sleep(0.1)
    assert not satisfied                  # both blocked behind r
    r.release()
    time.sleep(0.3)
    with lock:
        assert len(satisfied) == 1        # only one 60 B request fits
        _idx, first = satisfied[0]
    first.release()
    for t in threads:
        t.join(timeout=10)
    assert len(satisfied) == 2            # the second woke after the free
    satisfied[1][1].release()
    assert c.used_bytes() == 0


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 300), st.booleans()),
                min_size=1, max_size=40))
def test_property_intervals_never_overlap(ops):
    """Random reserve/release sequences keep allocated intervals disjoint."""
    c = HostCache(2048)
    live = []
    for size, release_one in ops:
        if release_one and live:
            live.pop(np.random.default_rng(size).integers(len(live))).release()
        else:
            try:
                live.append(c.reserve(size, timeout=0.01))
            except CacheFullError:
                if live:
                    live.pop(0).release()
        spans = sorted((r.start, r.start + r.nbytes) for r in live)
        for (s1, e1), (s2, _e2) in zip(spans, spans[1:]):
            assert e1 <= s2
        assert all(0 <= s and e <= 2048 for s, e in spans)
