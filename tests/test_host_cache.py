"""Pinned host-cache allocator: blocking back-pressure + interval invariants."""

import threading
import time

import numpy as np
import pytest
from conftest import given, settings, st  # hypothesis, optional

from repro.core.host_cache import CacheFullError, HostCache


def test_reserve_release_reuse():
    c = HostCache(1000)
    r1 = c.reserve(400)
    r2 = c.reserve(400)
    assert c.used_bytes() == 800
    with pytest.raises(CacheFullError):
        c.reserve(400, timeout=0.05)
    r1.release()
    r3 = c.reserve(400)  # reuses r1's interval
    assert r3.start == r1.start
    r2.release(); r3.release()
    assert c.used_bytes() == 0


def test_zero_copy_view():
    c = HostCache(1 << 16)
    r = c.reserve(256)
    arr = r.array(np.float32, (64,))
    arr[:] = np.arange(64, dtype=np.float32)
    # the same bytes are visible through a second view of the reservation
    again = np.frombuffer(r.view, dtype=np.float32)
    np.testing.assert_array_equal(again, np.arange(64, dtype=np.float32))


def test_oversized_request_raises():
    c = HostCache(100)
    with pytest.raises(CacheFullError, match="exceeds"):
        c.reserve(101)


def test_blocking_backpressure_unblocks():
    """A reserve that must wait is released when space frees (paper §V-A2:
    'the next checkpoint request needs to wait for previous tensors to get
    evicted')."""
    c = HostCache(100)
    r1 = c.reserve(80)
    got = {}

    def waiter():
        got["r"] = c.reserve(50, timeout=5)

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.05)
    assert "r" not in got          # still blocked
    r1.release()
    t.join(timeout=5)
    assert "r" in got
    got["r"].release()


def test_peak_usage_tracking():
    c = HostCache(1000)
    rs = [c.reserve(200) for _ in range(4)]
    assert c.peak_usage == 800
    for r in rs:
        r.release()
    c.reserve(100).release()
    assert c.peak_usage == 800  # historical peak


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 300), st.booleans()),
                min_size=1, max_size=40))
def test_property_intervals_never_overlap(ops):
    """Random reserve/release sequences keep allocated intervals disjoint."""
    c = HostCache(2048)
    live = []
    for size, release_one in ops:
        if release_one and live:
            live.pop(np.random.default_rng(size).integers(len(live))).release()
        else:
            try:
                live.append(c.reserve(size, timeout=0.01))
            except CacheFullError:
                if live:
                    live.pop(0).release()
        spans = sorted((r.start, r.start + r.nbytes) for r in live)
        for (s1, e1), (s2, _e2) in zip(spans, spans[1:]):
            assert e1 <= s2
        assert all(0 <= s and e <= 2048 for s, e in spans)
