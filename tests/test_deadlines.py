"""Deadline/clock correctness: the timing bugs the thread runtime masked.

Three latent bugs surfaced while building the process-per-rank runtime
(ISSUE 8), each with a regression test here:

* ``CollectiveBarrier.wait()`` / ``wait_generation()`` passed ``timeout``
  to every ``Condition.wait()`` inside the loop, so each wakeup that
  changed nothing (a ``poison→reset`` cycle, an adjacent generation
  completing) restarted the clock — under a wakeup storm the total wait
  was unbounded. Both now run against one ``time.monotonic()`` deadline.
* ``_cancel_watchdog`` vs ``_on_timeout``: a ``threading.Timer`` whose
  callback has already been scheduled survives ``.cancel()``, so a save
  completing right at the deadline could still be retro-failed by the
  late timer. ``_on_timeout`` now re-checks a done-flag set under the
  job lock *before* cancel.
* Orphan-grace ages compared wall-clock ``time.time()`` against marker
  contents — a clock stepping backwards made a crash orphan look
  eternally fresh (negative age). Negative ages now clamp to 0 with a
  warning.
"""

from __future__ import annotations

import logging
import os
import threading
import time

import pytest

from repro.core.engine import CheckpointFuture
from repro.dist.barrier import BarrierBroken, CollectiveBarrier
from repro.dist.coordinator import _SaveJob
from repro.storage.manifest import RankManifest
from repro.storage.repository import CheckpointRepository

# A storm waker that keeps notifying the barrier's condvar without ever
# completing the waiter's generation. ``reset()`` is the natural storm
# source: it notify_alls with generation/broken unchanged.
def _storm(barrier: CollectiveBarrier, duration_s: float,
           period_s: float = 0.02) -> None:
    end = time.monotonic() + duration_s
    while time.monotonic() < end:
        barrier.reset()
        time.sleep(period_s)


class TestBarrierDeadline:
    def test_wait_times_out_under_wakeup_storm(self):
        """A party's timeout must be one deadline, not per-wakeup: with
        a notify storm every 20ms, the old per-wakeup clock never
        elapsed (the storm runs 2s; the old code would ride it to ~2s+,
        failing the upper bound here)."""
        b = CollectiveBarrier(2)
        th = threading.Thread(target=_storm, args=(b, 2.0), daemon=True)
        t0 = time.monotonic()
        th.start()
        with pytest.raises(TimeoutError):
            b.wait(timeout=0.4)
        elapsed = time.monotonic() - t0
        th.join()
        assert 0.3 <= elapsed <= 1.0, \
            f"timeout fired after {elapsed:.3f}s for a 0.4s deadline"

    def test_wait_generation_times_out_under_wakeup_storm(self):
        """Observer waits had the same per-wakeup clock."""
        b = CollectiveBarrier(2)
        th = threading.Thread(target=_storm, args=(b, 2.0), daemon=True)
        t0 = time.monotonic()
        th.start()
        with pytest.raises(TimeoutError):
            b.wait_generation(0, timeout=0.4)
        elapsed = time.monotonic() - t0
        th.join()
        assert 0.3 <= elapsed <= 1.0, \
            f"timeout fired after {elapsed:.3f}s for a 0.4s deadline"

    def test_wait_without_timeout_still_blocks_and_completes(self):
        """The deadline refactor must not break the no-timeout path."""
        b = CollectiveBarrier(2)
        done = []
        th = threading.Thread(target=lambda: done.append(b.wait()),
                              daemon=True)
        th.start()
        time.sleep(0.05)
        assert b.wait() == 0
        th.join(timeout=5)
        assert done == [0]

    def test_poison_still_wakes_waiter_with_cause(self):
        b = CollectiveBarrier(2)
        errs = []

        def waiter():
            try:
                b.wait(timeout=30)
            except BarrierBroken as exc:
                errs.append(exc)

        th = threading.Thread(target=waiter, daemon=True)
        th.start()
        time.sleep(0.05)
        b.poison("rank 1 died", rank=1)
        th.join(timeout=5)
        assert len(errs) == 1 and errs[0].rank == 1


class TestWatchdogCancelRace:
    def test_late_timer_callback_cannot_retrofail_a_settled_save(
            self, tmp_path):
        """``Timer.cancel()`` cannot stop a callback that already began
        firing; the done-flag (set under the job lock before cancel) is
        what actually closes the window. Simulate the worst
        interleaving — the timeout callback running *inside* the cancel
        window of a fully-acked save — and require the save to stay
        successful."""
        sdir = str(tmp_path)
        fut = CheckpointFuture(5, sdir)
        job = _SaveJob(5, sdir, 1, writers=[0], nodes={0: [0]},
                       future=fut, ack_timeout_s=60.0,
                       checksum_votes=False)
        job.start_watchdog()
        RankManifest.build(sdir, rank=0, world=1, step=5, filenames=[],
                           checksum=False).write(sdir)
        orig_cancel = job._cancel_watchdog

        def cancel_with_late_callback():
            # the timer fires exactly in the cancel window
            job._on_timeout()
            orig_cancel()

        job._cancel_watchdog = cancel_with_late_callback
        job.rank_acked(0, None)
        # the save fully acked: the late callback must be a no-op
        fut.wait_persisted(timeout=5)
        assert fut.persisted and job.settled and not job.failed

    def test_timeout_still_fires_for_a_genuinely_stalled_save(
            self, tmp_path):
        fut = CheckpointFuture(6, str(tmp_path))
        job = _SaveJob(6, str(tmp_path), 2, writers=[0, 1],
                       nodes={0: [0, 1]}, future=fut, ack_timeout_s=0.2,
                       checksum_votes=False)
        job.start_watchdog()  # nobody ever acks
        with pytest.raises(Exception) as ei:
            fut.wait_persisted(timeout=5)
        assert "not all ranks acked" in str(ei.value.__cause__ or ei.value)


class TestOrphanGraceClockJump:
    def _future_dated_orphan(self, root: str, step: int) -> None:
        repo = CheckpointRepository(root, auto_cascade=False)
        sdir = repo.begin_step(step)
        with open(os.path.join(sdir, "rank00000.dsllm"), "wb") as f:
            f.write(os.urandom(64))
        # wall clock stepped backwards after the save began: the marker
        # timestamp is now in the future
        with open(repo._marker_path(step), "w") as f:
            f.write(str(time.time() + 3600.0))
        repo.close()

    def test_negative_age_clamps_to_fresh_and_warns(self, tmp_path,
                                                    caplog):
        self._future_dated_orphan(str(tmp_path), 7)
        repo = CheckpointRepository(str(tmp_path), auto_cascade=False)
        with caplog.at_level(logging.WARNING,
                             logger="repro.storage.repository"):
            age = repo._orphan_age_s(7)
        repo.close()
        assert age == 0.0
        assert any("future-dated" in r.getMessage()
                   for r in caplog.records)

    def test_gc_grace_spares_future_dated_orphan(self, tmp_path):
        """Age 0 must read as 'just started': inside any grace window.
        (Uncamped, -3600s < grace is *also* true — the dangerous case is
        the symmetric forward jump aging a live save out of its grace;
        clamping keeps the arithmetic on one side of zero.)"""
        self._future_dated_orphan(str(tmp_path), 8)
        repo = CheckpointRepository(str(tmp_path), auto_cascade=False)
        spared = repo.gc(include_orphans=True, orphan_grace_s=3600.0)
        assert spared.deleted_orphans == []
        reclaimed = repo.gc(include_orphans=True)
        assert reclaimed.deleted_orphans == [8]
        repo.close()

    def test_marker_less_orphan_future_mtime_also_clamps(self, tmp_path):
        repo = CheckpointRepository(str(tmp_path), auto_cascade=False)
        sdir = repo.begin_step(9)
        os.unlink(repo._marker_path(9))  # probe-failure orphan
        future_t = time.time() + 3600.0
        os.utime(sdir, (future_t, future_t))
        assert repo._orphan_age_s(9) == 0.0
        repo.close()
