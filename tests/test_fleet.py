"""Fleet warm-start fabric: read-through cache single-flight, capacity
pressure under concurrent readers, peer slice exchange (including peers
dying or corrupting slices mid-exchange), the shared-pipe object-store
throttle, and the end-to-end fabric path through
``load_params_for_serving`` + ``stats --fleet``."""

import json
import os
import shutil
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CheckpointManager, CheckpointPolicy, DeltaPolicy,
                        EnginePolicy, StoragePolicy)
from repro.fleet import (FLEET_STATS_KEY, ExchangeStats, FleetCache,
                         FleetFabric, PeerExchange)
from repro.fleet.peer import _digest
from repro.serving.engine import load_params_for_serving
from repro.storage import (BackendError, CheckpointRepository, MemoryBackend,
                           ObjectStoreBackend, Tier)
from repro.storage import cli as storage_cli


def _fan(n, fn):
    """Run ``fn(i)`` on n threads; re-raise the first failure."""
    errors = []

    def wrap(i):
        try:
            fn(i)
        except BaseException as exc:  # noqa: BLE001 — surfaced below
            errors.append(exc)

    threads = [threading.Thread(target=wrap, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]


# ------------------------------------------------------------- FleetCache
def test_cache_single_flight_dedup():
    """K concurrent restorers of one key cause exactly one remote read."""
    cache = FleetCache(capacity_bytes=1 << 20)
    calls = []

    def fetch():
        calls.append(1)
        time.sleep(0.05)  # hold the flight open while waiters pile up
        return b"x" * 1000

    out = [None] * 8
    _fan(8, lambda i: out.__setitem__(i, cache.get_through("k", fetch)))
    assert sum(calls) == 1
    assert all(o == b"x" * 1000 for o in out)
    assert cache.stats["misses"] == 1
    assert cache.stats["waits"] >= 1
    # stragglers after the flight closes hit the cache, no new fetch
    assert cache.get_through("k", fetch) == b"x" * 1000
    assert sum(calls) == 1 and cache.stats["hits"] >= 1


def test_cache_miss_fallthrough_and_lru_eviction():
    cache = FleetCache(capacity_bytes=1000)
    assert cache.peek("a") is None  # miss: no flight, no fabrication
    cache.get_through("a", lambda: b"a" * 400)
    cache.get_through("b", lambda: b"b" * 400)
    assert cache.peek("a") == b"a" * 400  # freshens a in LRU order
    cache.get_through("c", lambda: b"c" * 400)  # evicts b (LRU)
    assert cache.stats["evictions"] == 1
    assert cache.peek("b") is None
    assert cache.peek("a") == b"a" * 400
    assert cache.peek("c") == b"c" * 400
    assert cache.used_bytes() <= 1000


def test_cache_oversized_object_passes_through_uncached():
    cache = FleetCache(capacity_bytes=100)
    calls = []

    def fetch():
        calls.append(1)
        time.sleep(0.02)
        return b"z" * 5000

    out = [None] * 4
    _fan(4, lambda i: out.__setitem__(i, cache.get_through("big", fetch)))
    # waiters share the leader's bytes even though nothing was cached
    assert sum(calls) == 1
    assert all(o == b"z" * 5000 for o in out)
    assert cache.used_bytes() == 0
    assert cache.stats["uncached"] >= 1


def test_cache_failed_leader_wakes_waiters_who_retry():
    """A leader whose fetch raises must not wedge the flight: the waiter
    retries, becomes leader, and succeeds (cache-miss fallthrough)."""
    cache = FleetCache(capacity_bytes=1 << 20)
    first_in = threading.Event()
    boom = [True]

    def failing():
        first_in.set()
        time.sleep(0.05)
        if boom[0]:
            boom[0] = False
            raise BackendError("remote flaked")
        return b"ok"

    results, errors = [], []

    def caller(i):
        if i == 1:
            first_in.wait()  # guarantee thread 0 owns the flight
        try:
            results.append(cache.get_through("k", failing))
        except BackendError as exc:
            errors.append(exc)

    _fan(2, caller)
    assert len(errors) == 1        # the leader's caller sees the failure
    assert results == [b"ok"]      # the waiter retried and succeeded
    assert cache.get_through("k", failing) == b"ok"  # no stuck flight


def test_cache_capacity_pressure_under_concurrent_readers():
    """Readers racing evictions always see full, correct payloads — an
    entry evicted mid-read is re-fetched through the flight path, never
    returned torn."""
    payloads = {f"k{i}": bytes([i]) * 700 for i in range(8)}
    cache = FleetCache(capacity_bytes=2000)  # holds <3 entries: constant churn
    def reader(i):
        key = f"k{i % 8}"
        for _ in range(30):
            data = cache.get_through(key, lambda: payloads[key])
            assert data == payloads[key]

    _fan(8, reader)
    assert cache.stats["evictions"] > 0  # the pressure was real
    assert cache.used_bytes() <= 2000


def test_memory_backend_capacity_and_concurrent_readers():
    mem = MemoryBackend(capacity_bytes=1500)
    mem.put("a", b"a" * 700)
    with pytest.raises(BackendError, match="full"):
        mem.put("b", b"b" * 1000)  # would overflow
    mem.put("b", b"b" * 700)
    assert mem.used_bytes() == 1400

    stop = threading.Event()

    def churn():
        while not stop.is_set():
            mem.delete("b")
            try:
                mem.put("b", b"b" * 700)
            except BackendError:
                pass

    def reader(i):
        if i == 0:
            churn()
            return
        for _ in range(200):
            try:
                data = mem.get("b")
            except BackendError:
                continue  # clean miss mid-delete is fine
            assert data == b"b" * 700  # never torn
        if i == 3:
            stop.set()

    _fan(4, reader)
    stop.set()
    assert mem.get("a") == b"a" * 700


# ----------------------------------------------------------- PeerExchange
def test_peer_exchange_disjoint_slices_one_remote_copy():
    """R replicas exchanging one object read each remote byte once."""
    payload = os.urandom(1 << 20)
    px = PeerExchange(slice_bytes=64 << 10)
    served = [0]
    lock = threading.Lock()

    def read_range(off, nb):
        with lock:
            served[0] += nb
        time.sleep(0.001)  # let every replica join before slices run out
        return payload[off:off + nb]

    out = [None] * 8
    stats = [ExchangeStats() for _ in range(8)]

    def replica(i):
        out[i] = px.fetch("obj", len(payload), read_range, stats[i])

    _fan(8, replica)
    assert all(o == payload for o in out)
    assert served[0] == len(payload)  # exactly 1x the object, fleet-wide
    assert sum(s.remote_bytes for s in stats) == len(payload)
    assert sum(s.peer_bytes for s in stats) == 7 * len(payload)
    assert all(s.refetched_slices == 0 for s in stats)


def test_peer_dying_mid_exchange_degrades_to_remote_reads():
    """A peer that claims a slice and dies stops publishing; its claim
    expires and a live replica reclaims it — no hang, no missing bytes."""
    payload = os.urandom(256 << 10)
    px = PeerExchange(slice_bytes=64 << 10, claim_timeout_s=0.2)
    # the dying peer: joins the session, claims one slice, never publishes
    sess = px._session("obj", len(payload))
    dead_claim = sess.next_claim()
    assert dead_claim is not None and dead_claim >= 0

    def read_range(off, nb):
        return payload[off:off + nb]

    out = [None] * 2
    stats = [ExchangeStats() for _ in range(2)]
    t0 = time.monotonic()
    _fan(2, lambda i: out.__setitem__(
        i, px.fetch("obj", len(payload), read_range, stats[i])))
    assert time.monotonic() - t0 < 5.0  # bounded by the claim timeout
    assert all(o == payload for o in out)
    assert sum(s.reclaimed_slices for s in stats) >= 1


def test_peer_corrupt_slice_fails_digest_and_is_refetched():
    """Digests are verified on every exchanged slice: a torn/bit-flipped
    publish is discarded and that slice re-read from remote."""
    payload = os.urandom(256 << 10)
    px = PeerExchange(slice_bytes=64 << 10)
    sess = px._session("obj", len(payload))
    bad = sess.next_claim()
    off, nb = sess.slices[bad]
    good = payload[off:off + nb]
    corrupt = bytes([good[0] ^ 0xFF]) + good[1:]
    sess.publish(bad, corrupt, _digest(good))  # digest does not match bytes

    def read_range(off, nb):
        return payload[off:off + nb]

    stats = ExchangeStats()
    out = px.fetch("obj", len(payload), read_range, stats)
    assert out == payload  # corrupt slice never reached the assembly
    assert stats.refetched_slices == 1


def test_peer_failed_remote_read_releases_claim():
    """A claimer whose remote read raises gives the claim back, so a
    healthy peer can finish the session."""
    payload = os.urandom(128 << 10)
    px = PeerExchange(slice_bytes=32 << 10)
    fail_once = [True]

    def flaky(off, nb):
        if fail_once[0]:
            fail_once[0] = False
            raise BackendError("remote flaked")
        return payload[off:off + nb]

    with pytest.raises(BackendError, match="flaked"):
        px.fetch("obj", len(payload), flaky)
    out = px.fetch("obj", len(payload),
                   lambda off, nb: payload[off:off + nb])
    assert out == payload


def test_short_remote_read_rejected():
    payload = os.urandom(64 << 10)
    px = PeerExchange(slice_bytes=32 << 10)
    with pytest.raises(BackendError, match="returned"):
        px.fetch("obj", len(payload),
                 lambda off, nb: payload[off:off + nb - 1])


# -------------------------------------------------- shared-pipe throttle
@pytest.mark.slow
def test_object_store_shared_pipe_aggregates_concurrent_readers():
    """Concurrent reads split the configured bandwidth (one shared pipe),
    they do not each get a private copy of it."""
    be = ObjectStoreBackend(bandwidth_mbps=1.0)
    be.bandwidth_mbps = None
    be.put("blob", os.urandom(100_000))
    be.bandwidth_mbps = 1.0
    t0 = time.perf_counter()
    _fan(2, lambda i: be.get("blob"))
    wall = time.perf_counter() - t0
    # 2 x 100 KB through a 1 MB/s pipe needs >= ~0.2 s in aggregate; the
    # old per-request model finished in ~0.1 s
    assert wall >= 0.18
    assert be.stats["bytes_out"] == 200_000


# ------------------------------------------------------------ end-to-end
def _small_policy(remote, payload_bytes, delta=None):
    return CheckpointPolicy(
        engine=EnginePolicy(host_cache_bytes=payload_bytes * 3 + (32 << 20),
                            flush_threads=1),
        storage=StoragePolicy(tiers=(Tier("object", remote),)),
        delta=delta)


def _state(tag: float):
    return {"model": {"w0": jnp.arange(8192, dtype=jnp.float32) + tag,
                      "w1": jnp.ones((64, 64), jnp.float32) * tag},
            "meta": {"step": int(tag)}}


def test_fabric_end_to_end_amplification_and_ledger(tmp_path):
    """8 replicas with private local tiers warm-start through one fabric:
    remote egress stays ~1x one checkpoint, bytes are exact on every
    replica, a warmed replica re-resolves locally, and the per-step
    ledger reaches ``stats --fleet``."""
    remote = ObjectStoreBackend()
    state = _state(3.0)
    payload = sum(np.asarray(v).nbytes for v in state["model"].values())
    mgr = CheckpointManager.from_policy(
        str(tmp_path / "train"), _small_policy(remote, payload))
    mgr.save(3, state, blocking=True)
    mgr.repository.wait_cascaded()
    ckpt_bytes = mgr.repository.manifest(3).total_bytes
    mgr.close()

    fabric = FleetFabric(slice_bytes=16 << 10)
    b0 = remote.stats["bytes_out"]
    repos = []

    def replica(i):
        rdir = str(tmp_path / f"replica{i}")
        repo = CheckpointRepository(rdir, remote_tiers=[Tier("object", remote)],
                                    auto_cascade=False, auto_gc=False)
        repos.append(repo)
        tpl = {k: np.empty(np.asarray(v).shape, np.float32)
               for k, v in state["model"].items()}
        params, _ = load_params_for_serving(rdir, tpl, step=3, threads=1,
                                            repository=repo, fleet=fabric)
        for k, v in state["model"].items():
            np.testing.assert_array_equal(np.asarray(params[k]),
                                          np.asarray(v))

    _fan(8, replica)
    remote_bytes = remote.stats["bytes_out"] - b0
    assert remote_bytes <= ckpt_bytes * 1.25  # ~1x, not 8x
    st = fabric.step_stats()[3]
    assert st["replicas"] == 8 and not st["delta"]
    # the ledger counts fabric-moved bytes; the backend additionally sees
    # each replica's direct manifest read from the restore chain walk
    assert 0 < st["remote_bytes"] <= remote_bytes
    assert remote_bytes - st["remote_bytes"] < 4096 * 8

    # a warmed replica re-resolves locally: zero new remote bytes
    b1 = remote.stats["bytes_out"]
    assert repos[0].resolve_for_restore(3) is not None
    assert remote.stats["bytes_out"] == b1

    # the ledger landed in each replica's catalog for the admin CLI
    ldir = repos[0].root
    assert os.path.exists(os.path.join(ldir, FLEET_STATS_KEY))
    rc = storage_cli.main(["--root", ldir, "stats", "--fleet"])
    assert rc == 0
    for repo in repos:
        repo.close()


def test_fabric_cli_stats_fleet_output(tmp_path, capsys):
    remote = ObjectStoreBackend()
    state = _state(1.0)
    payload = sum(np.asarray(v).nbytes for v in state["model"].values())
    mgr = CheckpointManager.from_policy(
        str(tmp_path / "train"), _small_policy(remote, payload))
    mgr.save(1, state, blocking=True)
    mgr.repository.wait_cascaded()
    mgr.close()
    rdir = str(tmp_path / "replica")
    repo = CheckpointRepository(rdir, remote_tiers=[Tier("object", remote)],
                                auto_cascade=False, auto_gc=False)
    repo.attach_fleet(FleetFabric())
    assert repo.resolve_for_restore(1) is not None
    repo.close()
    capsys.readouterr()
    assert storage_cli.main(["--root", rdir, "stats", "--fleet"]) == 0
    out = capsys.readouterr().out
    assert "replicas=" in out and "remote=" in out and "peer=" in out
    # --step filter: present vs absent
    assert storage_cli.main(["--root", rdir, "stats", "--fleet",
                             "--step", "1"]) == 0
    assert storage_cli.main(["--root", rdir, "stats", "--fleet",
                             "--step", "99"]) == 1


def test_fabric_cli_stats_fleet_without_ledger(tmp_path, capsys):
    repo = CheckpointRepository(str(tmp_path), auto_cascade=False)
    repo.close()
    assert storage_cli.main(["--root", str(tmp_path),
                             "stats", "--fleet"]) == 0
    assert "no fleet transfer ledger" in capsys.readouterr().out


def test_fabric_delta_pull_moves_only_chain_bytes(tmp_path):
    """A fleet already on step 1 warming to delta step 2 transfers the
    delta chain only — never a fresh keyframe."""
    remote = ObjectStoreBackend()
    state = _state(1.0)
    payload = sum(np.asarray(v).nbytes for v in state["model"].values())
    mgr = CheckpointManager.from_policy(
        str(tmp_path / "train"),
        _small_policy(remote, payload, delta=DeltaPolicy(keyframe_every=4)))
    mgr.save(1, state, blocking=True)
    mgr.wait_for_commit(1)
    mgr.repository.wait_cascaded()
    # snapshot the fleet's "already on step 1" local tier
    seed = str(tmp_path / "fleet-at-1")
    shutil.copytree(str(tmp_path / "train"), seed)
    state2 = {"model": {k: v + np.float32(0.5)
                        for k, v in state["model"].items()},
              "meta": {"step": 2}}
    mgr.save(2, state2, blocking=True)
    mgr.wait_for_commit(2)
    mgr.repository.wait_cascaded()
    kf_bytes = mgr.repository.manifest(1).total_bytes
    delta_bytes = mgr.repository.manifest(2).total_bytes
    assert delta_bytes < kf_bytes  # the delta really is smaller
    mgr.close()

    fabric = FleetFabric(slice_bytes=16 << 10)
    b0 = remote.stats["bytes_out"]
    rdir = str(tmp_path / "replica")
    shutil.copytree(seed, rdir)
    repo = CheckpointRepository(rdir, remote_tiers=[Tier("object", remote)],
                                auto_cascade=False, auto_gc=False)
    tpl = {k: np.empty(np.asarray(v).shape, np.float32)
           for k, v in state["model"].items()}
    params, _ = load_params_for_serving(rdir, tpl, step=2, threads=1,
                                        repository=repo, fleet=fabric)
    for k, v in state2["model"].items():
        np.testing.assert_array_equal(np.asarray(params[k]), np.asarray(v))
    pulled = remote.stats["bytes_out"] - b0
    assert pulled < kf_bytes            # not a keyframe re-read
    assert pulled <= delta_bytes * 1.25 + 16384  # chain bytes + manifest
    assert fabric.step_stats()[2]["delta"] is True
    repo.close()


def test_fabric_falls_back_when_no_remote_tier_has_step(tmp_path):
    """A fabric with nothing to fetch defers to normal resolution (which
    raises the usual not-on-any-tier error) instead of masking it."""
    repo = CheckpointRepository(str(tmp_path), auto_cascade=False)
    repo.attach_fleet(FleetFabric())
    with pytest.raises(FileNotFoundError):
        repo.resolve_for_restore(42)
    repo.close()
