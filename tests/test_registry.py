"""StateProviderRegistry: rule precedence, routing errors, custom providers.

Covers the ISSUE 5 registry edge cases: overlapping-rule precedence
(first match wins), unmatched leaves under a strict registry (clear error
naming the state path), and a custom provider that raises mid-``chunks()``
(the engine must abort and unlink the partial file, never commit it).
"""

import glob
import os
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CheckpointError, CheckpointManager, CheckpointPolicy,
                        EnginePolicy, ProviderRule, QuantizedStateProvider,
                        RegistryError, StateProviderRegistry,
                        TensorStateProvider)


def small_state():
    return {"model": {"w": jnp.arange(64, dtype=jnp.float32)},
            "optimizer": {"m": jnp.ones((256, 256), jnp.float32),
                          "count": jnp.array(3, jnp.int32)},
            "meta": {"step": 3}}


# ------------------------------------------------------------- precedence
def test_first_matching_rule_wins_on_overlap():
    reg = (StateProviderRegistry()
           .add_rule(provider="quantized", domain="optimizer",
                     dtype="float32")
           .add_rule(provider="tensor", domain="optimizer")  # also matches
           .add_rule(provider="auto"))
    r = reg.route(domain="optimizer", path="state/optimizer/m",
                  dtype="float32", nbytes=1 << 20, kind="tensor")
    assert r.provider == "quantized" and r.rule_index == 0
    # the int32 counter falls past the dtype-scoped rule to the next match
    r2 = reg.route(domain="optimizer", path="state/optimizer/count",
                   dtype="int32", nbytes=4, kind="tensor")
    assert r2.provider == "tensor" and r2.rule_index == 1


def test_size_threshold_and_path_regex_predicates():
    reg = (StateProviderRegistry()
           .add_rule(provider="quantized", path_regex=r"moments?/",
                     min_nbytes=1 << 10)
           .add_rule(provider="auto"))
    big = reg.route(domain="opt", path="state/opt/moment/w", dtype="float32",
                    nbytes=1 << 20, kind="tensor")
    small = reg.route(domain="opt", path="state/opt/moment/b",
                      dtype="float32", nbytes=16, kind="tensor")
    other = reg.route(domain="opt", path="state/opt/scale", dtype="float32",
                      nbytes=1 << 20, kind="tensor")
    assert big.provider == "quantized"
    assert small.provider == "auto"   # below min_nbytes
    assert other.provider == "auto"   # regex miss


def test_overlap_precedence_lands_in_the_manifest(tmp_path):
    """End-to-end: with both rules matching the optimizer moments, the
    earlier (quantized) one decides what hits disk."""
    reg = (StateProviderRegistry()
           .add_rule(provider="quantized", domain="optimizer",
                     dtype="float32")
           .add_rule(provider="tensor"))
    pol = CheckpointPolicy(engine=EnginePolicy(host_cache_bytes=1 << 22),
                           providers=reg)
    with CheckpointManager.from_policy(str(tmp_path), pol) as mgr:
        mgr.save(1, small_state(), blocking=True)
        man = mgr.repository.manifest(1)
        doms = man.meta["domains"]
        assert doms["optimizer"]["providers"] == ["quantized", "tensor"]
        assert "int8q+zstd" in doms["optimizer"]["codecs"]
        assert doms["model"] == {"providers": ["tensor"],
                                 "codecs": ["raw"]}
        # per-file (domain, provider, codec) catalog entries
        [fe] = [f for f in man.files if f.name.endswith(".dsllm")]
        assert "quantized" in fe.domains["optimizer"]["providers"]


# ------------------------------------------------------- unmatched / bad
def test_strict_registry_names_the_unmatched_state_path(tmp_path):
    reg = (StateProviderRegistry(strict=True)
           .add_rule(provider="quantized", domain="optimizer",
                     dtype="float32")
           .add_rule(provider="object"))  # objects routed; tensors aren't
    with pytest.raises(RegistryError, match=r"state/model/w"):
        reg.route(domain="model", path="state/model/w", dtype="float32",
                  nbytes=256, kind="tensor")
    # and through the full save path: the error fires at plan time,
    # before any I/O, and the step is never committed
    pol = CheckpointPolicy(providers=reg)
    with CheckpointManager.from_policy(str(tmp_path), pol) as mgr:
        with pytest.raises(RegistryError, match=r"state/model/w"):
            mgr.save(1, small_state(), blocking=True)
        assert mgr.latest_step() is None


def test_unknown_provider_name_is_an_error():
    reg = StateProviderRegistry().add_rule(provider="zfp")
    with pytest.raises(RegistryError, match="unknown provider 'zfp'"):
        reg.route(domain="m", path="state/m/w", dtype="float32",
                  nbytes=4, kind="tensor")


def test_provider_implies_leaf_kind():
    """A provider only matches leaves it can serve: a tensor-provider
    catch-all skips object leaves (they fall through) and vice versa."""
    reg = (StateProviderRegistry()
           .add_rule(provider="quantized")    # tensor-only
           .add_rule(provider="object"))      # object-only
    t = reg.route(domain="m", path="state/m/w", dtype="float32",
                  nbytes=1 << 20, kind="tensor")
    o = reg.route(domain="meta", path="state/meta/step", kind="object")
    assert t.provider == "quantized" and o.provider == "object"


def test_explicit_kind_contradicting_provider_is_an_error():
    reg = StateProviderRegistry().add_rule(provider="quantized",
                                           kind="object")
    with pytest.raises(RegistryError, match="tensor state only"):
        reg.route(domain="meta", path="state/meta/step", kind="object")


def test_cannot_override_stock_provider():
    with pytest.raises(RegistryError, match="stock provider"):
        StateProviderRegistry().register("tensor", lambda rec, **kw: None)


def test_quantized_provider_rejects_non_f32(tmp_path):
    """Routing int state to the quantized provider is a hard error (with
    the fix named), not silent corruption."""
    reg = (StateProviderRegistry()
           .add_rule(provider="quantized", domain="optimizer")
           .add_rule(provider="auto"))
    pol = CheckpointPolicy(providers=reg)
    with CheckpointManager.from_policy(str(tmp_path), pol) as mgr:
        # provider construction happens in the blocking prologue, so the
        # error surfaces synchronously and the step is never committed
        with pytest.raises(ValueError, match="dtype='float32'"):
            mgr.save(1, small_state(), blocking=True)
        assert mgr.latest_step() is None


def test_baseline_engines_reject_encoded_routes(tmp_path):
    reg = (StateProviderRegistry()
           .add_rule(provider="quantized", domain="optimizer",
                     dtype="float32")
           .add_rule(provider="auto"))
    pol = CheckpointPolicy(engine=EnginePolicy(mode="sync"), providers=reg)
    with CheckpointManager.from_policy(str(tmp_path), pol) as mgr:
        with pytest.raises(ValueError, match="DataMovementEngine"):
            mgr.save(1, small_state(), blocking=True)


# ------------------------------------------------------ custom providers
class _ExplodingProvider(TensorStateProvider):
    """Streams one good chunk, then dies mid-iteration."""

    def chunks(self):
        it = super().chunks()
        yield next(it)
        raise RuntimeError("provider exploded mid-stream")


def test_custom_provider_roundtrip(tmp_path):
    """A well-behaved custom provider (here: a plain subclass) routes by
    name and round-trips."""
    made = []

    def factory(rec, **kw):
        made.append(rec.tensor_name)
        return TensorStateProvider(rec.tensor_name, **kw)

    reg = (StateProviderRegistry()
           .register("mirror", factory)
           .add_rule(provider="mirror", domain="model")
           .add_rule(provider="auto"))
    pol = CheckpointPolicy(providers=reg)
    state = small_state()
    with CheckpointManager.from_policy(str(tmp_path), pol) as mgr:
        mgr.save(1, state, blocking=True)
        assert any("model/w" in n for n in made)
        out = mgr.restore(state, step=1)
        np.testing.assert_array_equal(np.asarray(out["model"]["w"]),
                                      np.asarray(state["model"]["w"]))
        man = mgr.repository.manifest(1)
        assert man.meta["domains"]["model"]["providers"] == ["mirror"]


def test_custom_provider_raising_mid_chunks_aborts_and_unlinks(tmp_path):
    """ISSUE 5 edge case: the engine must abort the save, unlink the
    footer-less partial file, and never commit the step — and the next
    save must succeed (no leaked cache reservations)."""
    reg = (StateProviderRegistry()
           .register("exploding",
                     lambda rec, **kw: _ExplodingProvider(rec.tensor_name,
                                                          **kw))
           .add_rule(provider="exploding", path_regex=r"optimizer/m")
           .add_rule(provider="auto"))
    pol = CheckpointPolicy(engine=EnginePolicy(host_cache_bytes=1 << 22,
                                               chunk_bytes=1 << 14),
                           providers=reg)
    state = small_state()
    with CheckpointManager.from_policy(str(tmp_path), pol) as mgr:
        fut = mgr.save(1, state)
        with pytest.raises(CheckpointError):
            fut.wait_persisted()
        mgr.wait_for_commit(1)
        # never committed, and the partial rank file is gone
        assert mgr.latest_step() is None
        assert mgr.repository.steps() == []
        # the abort's unlink runs on the flush lanes — wait_persisted
        # raises as soon as the save *fails*, which can be a beat before
        # the lane finishes cleaning up its partial file
        pattern = str(tmp_path / "global_step1" / "*.dsllm")
        deadline = time.monotonic() + 5.0
        while glob.glob(pattern) and time.monotonic() < deadline:
            time.sleep(0.01)
        assert glob.glob(pattern) == []
        # engine lanes healthy: a clean registry save goes through
        clean = (StateProviderRegistry().add_rule(provider="auto"))
        mgr.registry = clean
        mgr.save(2, state, blocking=True)
        assert mgr.latest_step() == 2
        out = mgr.restore(state)
        np.testing.assert_array_equal(np.asarray(out["optimizer"]["m"]),
                                      np.asarray(state["optimizer"]["m"]))


def test_custom_factory_must_return_tensor_provider(tmp_path):
    reg = (StateProviderRegistry()
           .register("broken", lambda rec, **kw: object())
           .add_rule(provider="broken", kind="tensor")
           .add_rule(provider="auto"))
    pol = CheckpointPolicy(providers=reg)
    with CheckpointManager.from_policy(str(tmp_path), pol) as mgr:
        with pytest.raises(CheckpointError, match="TensorStateProvider"):
            mgr.save(1, small_state(), blocking=True)


def test_commit_fills_file_domains_without_footer_parse(tmp_path,
                                                        monkeypatch):
    """The per-file (domain, provider, codec) records come from the
    engine's plan, threaded through the committer — commit must not
    re-open and parse .dsllm footers for them (the probe is a fallback
    for files the engine map misses)."""
    import repro.storage.manifest as mf
    calls = []
    orig = mf.dsllm_file_meta
    monkeypatch.setattr(mf, "dsllm_file_meta",
                        lambda p: calls.append(p) or orig(p))
    with CheckpointManager.from_policy(str(tmp_path)) as mgr:
        mgr.save(1, small_state(), blocking=True)
        man = mgr.repository.manifest(1)
        [fe] = [f for f in man.files if f.name.endswith(".dsllm")]
        assert fe.domains["model"]["providers"] == ["tensor"]
        assert "file_domains" not in man.meta  # popped, never stored
    assert calls == []


def test_quantized_provider_direct_roundtrip_via_file(tmp_path):
    """Unit-level: QuantizedStateProvider chunks decode back within one
    quantization step per value."""
    from repro.core import codecs
    rng = np.random.default_rng(0)
    arr = rng.standard_normal((300, 70)).astype(np.float32)
    p = QuantizedStateProvider("t", dtype="float32", shape=arr.shape,
                               nbytes=arr.nbytes, host_array=arr,
                               chunk_bytes=1 << 12)
    out = np.empty(arr.nbytes, np.uint8)
    for ch in p.chunks():
        lo, hi = ch.raw_range
        out[lo:hi] = codecs.decode_chunk_payload(
            codecs.codec_base(ch.codec), bytes(ch.data), lo, hi)
    dec = out.view(np.float32).reshape(arr.shape)
    step = np.abs(arr).max() / 127 + 1e-7
    assert np.max(np.abs(dec - arr)) <= step
