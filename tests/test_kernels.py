"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.checksum import BLOCK as CK_BLOCK


# ------------------------------------------------------------------ checksum
@pytest.mark.parametrize("n,dtype", [
    (CK_BLOCK, np.uint32),
    (3 * CK_BLOCK, np.uint32),
    (100_000, np.float32),          # padded path
    (12_345, np.int16),             # odd bytes -> u32 padding
])
def test_checksum_matches_ref(n, dtype):
    rng = np.random.default_rng(42)
    if np.issubdtype(dtype, np.floating):
        x = rng.standard_normal(n).astype(dtype)
    else:
        x = rng.integers(0, np.iinfo(dtype).max, n, dtype=dtype)
    got = int(ops.tensor_checksum(jnp.asarray(x)))
    u = np.asarray(ops.as_u32(jnp.asarray(x)))
    padded = np.zeros((-(-len(u) // CK_BLOCK)) * CK_BLOCK, np.uint32)
    padded[:len(u)] = u
    assert got == int(ref.checksum_ref(padded))


def test_checksum_detects_corruption():
    x = jnp.arange(CK_BLOCK, dtype=jnp.uint32)
    good = int(ops.tensor_checksum(x))
    bad = int(ops.tensor_checksum(x.at[12345].set(99)))
    assert good != bad


def test_checksum_detects_block_swap():
    """Position weighting catches reordered blocks (plain sums would not)."""
    rng = np.random.default_rng(1)
    a = rng.integers(0, 2**31, CK_BLOCK, dtype=np.uint32)
    b = rng.integers(0, 2**31, CK_BLOCK, dtype=np.uint32)
    x1 = jnp.asarray(np.concatenate([a, b]))
    x2 = jnp.asarray(np.concatenate([b, a]))
    assert int(ops.tensor_checksum(x1)) != int(ops.tensor_checksum(x2))


# ------------------------------------------------------------------ quantize
@pytest.mark.parametrize("rows", [256, 512, 1024])
def test_quantize_int8_sweep(rows):
    x = jax.random.normal(jax.random.PRNGKey(rows), (rows, 256), jnp.float32)
    q, s = ops.quantize_int8(x)
    qr, sr = ref.quantize_int8_ref(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    # bounded reconstruction error: one scale step per element
    xd = ops.dequantize_int8(q, s)
    err = np.abs(np.asarray(xd) - np.asarray(x))
    assert (err <= np.asarray(s) + 1e-7).all()


def test_quantize_zero_rows():
    x = jnp.zeros((256, 256), jnp.float32)
    q, s = ops.quantize_int8(x)
    assert int(jnp.abs(q).max()) == 0


@pytest.mark.parametrize("shape", [(256, 256), (512, 512), (256, 1024)])
def test_downcast_bf16_sweep(shape):
    x = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32) * 100
    got = ops.downcast_bf16(x)
    want = ref.downcast_bf16_ref(x)
    np.testing.assert_array_equal(np.asarray(got, dtype=np.float32),
                                  np.asarray(want, dtype=np.float32))


# --------------------------------------------------------------------- delta
@pytest.mark.parametrize("n", [65_536, 70_000, 200_000])
def test_delta_xor_roundtrip(n):
    a = jax.random.normal(jax.random.PRNGKey(1), (n,), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(2), (n,), jnp.float32)
    d = ops.delta_xor(a, b)
    rec = np.bitwise_xor(np.asarray(d)[:n], np.asarray(b).view(np.uint32))
    np.testing.assert_array_equal(rec, np.asarray(a).view(np.uint32))


def test_delta_f32_matches_ref():
    a = jax.random.normal(jax.random.PRNGKey(3), (70_000,), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(4), (70_000,), jnp.float32)
    d = np.asarray(ops.delta_f32(a, b))[:70_000]
    np.testing.assert_allclose(d, np.asarray(ref.delta_f32_ref(a, b)),
                               rtol=1e-6)


def test_delta_identical_is_zero():
    a = jax.random.normal(jax.random.PRNGKey(5), (65_536,), jnp.float32)
    assert int(jnp.abs(ops.delta_xor(a, a)).max()) == 0


# ----------------------------------------------------------- flash attention
@pytest.mark.parametrize("kind,window,chunk", [
    ("full", 0, 0), ("window", 128, 0), ("chunked", 0, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(kind, window, chunk, dtype):
    B, S, H, KV, hd = 2, 512, 4, 2, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, hd), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, hd), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, hd), dtype)
    got = ops.flash_attention(q, k, v, kind=kind, window=window, chunk=chunk,
                              q_block=128, kv_block=128)
    rep = H // KV
    kr, vr = jnp.repeat(k, rep, 2), jnp.repeat(v, rep, 2)
    want = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3).reshape(B * H, S, hd),
        kr.transpose(0, 2, 1, 3).reshape(B * H, S, hd),
        vr.transpose(0, 2, 1, 3).reshape(B * H, S, hd),
        kind=kind, window=window, chunk=chunk
    ).reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, dtype=np.float32),
                               np.asarray(want, dtype=np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("S,qb,kvb", [(256, 64, 64), (256, 128, 64),
                                      (512, 256, 128)])
def test_flash_attention_block_shape_sweep(S, qb, kvb):
    B, H, hd = 1, 2, 32
    q = jax.random.normal(jax.random.PRNGKey(7), (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(8), (B, S, H, hd))
    v = jax.random.normal(jax.random.PRNGKey(9), (B, S, H, hd))
    got = ops.flash_attention(q, k, v, q_block=qb, kv_block=kvb)
    want = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3).reshape(B * H, S, hd),
        k.transpose(0, 2, 1, 3).reshape(B * H, S, hd),
        v.transpose(0, 2, 1, 3).reshape(B * H, S, hd),
    ).reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.slow
def test_flash_matches_model_blocked_sdpa():
    """The Pallas kernel and the pure-XLA production path agree."""
    from repro.models.layers import blocked_sdpa
    B, S, H, KV, hd = 2, 4096, 4, 2, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, hd))
    got = ops.flash_attention(q, k, v)
    want = blocked_sdpa(q, k, v, kv_block=1024).reshape(B, S, H, hd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)
