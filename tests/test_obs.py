"""ckpttrace suite: tracer semantics, Chrome-JSON schema, ring bounds,
the <1%-when-disabled overhead budget, the multi-rank lane/commit
ordering, and the metrics registry / SaveReport schema (ISSUE 7)."""

import json
import threading
import time

import numpy as np
import pytest

from repro.core import (CheckpointManager, CheckpointPolicy, DeltaPolicy,
                        DistPolicy, EnginePolicy, StoragePolicy)
from repro.obs import trace
from repro.obs.metrics import MetricsRegistry, SaveReport


@pytest.fixture(autouse=True)
def _clean_tracer():
    """Tracing is process-global state: never leak it across tests."""
    trace.disable()
    yield
    trace.disable()


# ---------------------------------------------------------------- recording
def test_span_nesting_and_thread_attribution():
    t = trace.enable()
    with trace.span("outer", step=1):
        with trace.span("inner"):
            time.sleep(0.001)

    def worker():
        with trace.span("in-thread"):
            pass

    th = threading.Thread(target=worker, name="obs-test-worker")
    th.start()
    th.join()
    spans = {s["name"]: s for s in t.spans()}
    assert set(spans) == {"outer", "inner", "in-thread"}
    outer, inner = spans["outer"], spans["inner"]
    assert outer["t0"] <= inner["t0"] and inner["t1"] <= outer["t1"]
    assert outer["args"] == {"step": 1}
    # lane defaults to the recording thread's name
    assert spans["in-thread"]["lane"] == "obs-test-worker"
    assert outer["lane"] == threading.current_thread().name
    assert spans["in-thread"]["tid"] != outer["tid"]


def test_disabled_recording_is_a_silent_noop():
    assert not trace.enabled()
    with trace.span("x", bytes=1):
        pass
    trace.add_span("y", 0.0, 1.0)
    trace.instant("z")
    trace.counter("c", 3)
    t = trace.enable()
    assert t.events() == []


def test_span_name_prefix_filter():
    t = trace.enable()
    trace.add_span("encode.delta", 0.0, 1.0)
    trace.add_span("encode.compress", 0.0, 1.0)
    trace.add_span("encoder", 0.0, 1.0)     # prefix must not match this
    assert {s["name"] for s in t.spans("encode")} == \
        {"encode.delta", "encode.compress"}


def test_tracing_ctx_restores_outer_tracer():
    outer = trace.enable()
    with trace.tracing() as inner:
        assert trace.get_tracer() is inner
        trace.add_span("inner-only", 0.0, 1.0)
    assert trace.get_tracer() is outer
    assert outer.spans() == []


def test_ring_wraparound_keeps_newest_and_counts_drops():
    t = trace.enable(capacity_per_thread=8)
    for i in range(20):
        trace.add_span(f"s{i:02d}", float(i), float(i) + 0.5)
    assert t.dropped() == 12
    names = [s["name"] for s in t.spans()]
    assert names == [f"s{i:02d}" for i in range(12, 20)]  # newest survive
    assert t.to_chrome()["otherData"]["dropped_events"] == 12


# ------------------------------------------------------------ Chrome export
def test_chrome_json_schema(tmp_path):
    t = trace.enable()
    flow = trace.flow_id("save", 3)
    trace.instant("save.request", flow=flow, flow_phase="start", step=3)
    with trace.span("d2h.stage", flow=flow, bytes=42):
        pass
    trace.add_span("flush", 0.5, 0.9, lane="rank00000-flush-0", flow=flow)
    trace.add_span("commit", 1.0, 1.1, flow=flow, flow_phase="end")
    trace.counter("host_cache.used_bytes", 1 << 20)
    out = tmp_path / "trace.json"
    trace.disable().export(str(out))
    doc = json.loads(out.read_text())

    assert set(doc) >= {"traceEvents", "displayTimeUnit"}
    events = doc["traceEvents"]
    phases = {e["ph"] for e in events}
    assert {"M", "X", "i", "C"} <= phases
    for e in events:
        assert {"name", "ph", "pid", "tid"} <= set(e)
        if e["ph"] in ("X", "i", "C", "s", "t", "f"):
            assert isinstance(e["ts"], (int, float)) and e["ts"] >= 0
        if e["ph"] == "X":
            assert isinstance(e["dur"], (int, float)) and e["dur"] >= 0
    # every lane used by a span has a thread_name metadata track
    named_tids = {e["tid"] for e in events
                  if e["ph"] == "M" and e["name"] == "thread_name"}
    used_tids = {e["tid"] for e in events if e["ph"] in ("X", "i")}
    assert used_tids <= named_tids
    lanes = {e["args"]["name"] for e in events
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert "rank00000-flush-0" in lanes
    # flow linkage: start/step/finish all share the id; finish binds
    # to the enclosing slice
    flows = [e for e in events if e["ph"] in ("s", "t", "f")]
    assert flows and {e["id"] for e in flows} == {flow}
    assert all(e.get("bp") == "e" for e in flows if e["ph"] == "f")


# --------------------------------------------------------- overhead budget
def test_disabled_overhead_below_one_percent_of_iteration():
    """The ISSUE 7 budget: with tracing off, the instrumentation's cost at
    the training loop's span density must stay under 1% of a real (tiny)
    training iteration."""
    from repro.configs import get_config, smoke_variant
    from repro.training.loop import Trainer

    assert not trace.enabled()
    tr = Trainer(smoke_variant(get_config("llama2-7b")), batch=2, seq_len=32)
    tr.run(2)                      # warm the jit caches
    tr.records.clear()
    tr.run(4)
    iter_s = sorted(r.iter_s for r in tr.records)[len(tr.records) // 2]

    n = 100_000
    t0 = time.perf_counter()
    for _ in range(n):
        with trace.span("x", step=1):
            pass
        trace.add_span("y", 0.0, 1.0, step=1)
    per_call = (time.perf_counter() - t0) / (2 * n)
    # generous density bound: the full save path records well under 40
    # spans per iteration at ckpt_interval=1
    assert 40 * per_call < 0.01 * iter_s, (
        f"disabled tracing costs {per_call * 1e9:.0f} ns/call — "
        f"{40 * per_call / iter_s:.2%} of a {iter_s * 1e3:.1f} ms iteration")


# ----------------------------------------------------- multi-rank ordering
def _world4_delta_manager(directory: str) -> CheckpointManager:
    return CheckpointManager.from_policy(
        str(directory), CheckpointPolicy(
            engine=EnginePolicy(host_cache_bytes=64 << 20, flush_threads=1),
            storage=StoragePolicy(manifest_checksums=False),
            dist=DistPolicy(world=4),
            delta=DeltaPolicy(keyframe_every=2)))


def test_world4_delta_save_lanes_and_commit_ordering(tmp_path):
    """A coordinated world=4 differential save sequence must give every
    rank its own lane set (vote + engine lanes), and the commit span may
    only start once every rank's phase-1 vote span has ended."""
    rng = np.random.default_rng(0)
    state = {"model": {f"w{i}": rng.standard_normal(32768).astype(np.float32)
                       for i in range(8)},
             "meta": {"step": 0}}
    t = trace.enable()
    mgr = _world4_delta_manager(tmp_path)
    try:
        for s in (1, 2):
            state = {"model": {k: v + np.float32(s) / 256
                               for k, v in state["model"].items()},
                     "meta": {"step": s}}
            mgr.save(s, state).wait_persisted()
            mgr.wait_for_commit(s)
    finally:
        mgr.close()
    spans = t.spans()
    rank_lanes = {f"rank{r:05d}" for r in range(4)}

    votes = [s for s in spans if s["name"] == "vote"]
    assert {v["lane"] for v in votes} == rank_lanes
    commits = {s["args"]["step"]: s for s in spans if s["name"] == "commit"}
    assert set(commits) == {1, 2}
    for step, commit in commits.items():
        step_votes = [v for v in votes if v["args"]["step"] == step]
        assert len(step_votes) == 4
        assert commit["t0"] >= max(v["t1"] for v in step_votes), (
            f"step {step}: commit span started before every vote ended")
    # delta save (step 2) ran the XOR encoders on per-rank producer lanes
    delta_lanes = {s["lane"] for s in spans if s["name"] == "encode.delta"}
    assert delta_lanes and all(ln.startswith("rank") for ln in delta_lanes)
    # the Chrome export gives each rank lane its own named track
    lanes = {e["args"]["name"] for e in t.to_chrome()["traceEvents"]
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert rank_lanes <= lanes
    for r in range(4):
        assert any(ln.startswith(f"rank{r:05d}-") for ln in lanes), (
            f"rank {r} engine lanes missing from trace")


# ------------------------------------------------------------------ metrics
def test_metrics_registry_counters_gauges_histograms():
    m = MetricsRegistry()
    m.inc("bytes", 10)
    m.inc("bytes", 5)
    m.set_gauge("used", 7)
    for v in (0.1, 0.2, 0.3):
        m.observe("wait_s", v)
    snap = m.snapshot()
    assert snap["counters"]["bytes"] == 15
    assert snap["gauges"]["used"] == 7
    h = snap["histograms"]["wait_s"]
    assert h["count"] == 3
    assert h["min"] == pytest.approx(0.1)
    assert h["max"] == pytest.approx(0.3)
    assert h["mean"] == pytest.approx(0.2)
    m.reset()
    assert m.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}
    assert json.dumps(snap)  # snapshot is always JSON-serializable


def test_save_report_unifies_future_stats(tmp_path):
    state = {"model": {"w": np.arange(4096, dtype=np.float32)},
             "meta": {"step": 0}}
    mgr = CheckpointManager.from_policy(str(tmp_path), None)
    try:
        fut = mgr.save(1, state)
        fut.wait_persisted()
        mgr.wait_for_commit(1)
        rep = SaveReport.from_future(fut)
    finally:
        mgr.close()
    assert rep.step == 1 and rep.kind == "save"
    assert rep.phases["blocking_s"] >= 0
    assert rep.phases["persist_s"] > 0
    assert rep.phases["commit_s"] > 0
    d = rep.to_dict()
    assert json.dumps(d)
    assert d["kind"] == "save" and d["step"] == 1


def test_save_spans_carry_flow_links(tmp_path):
    """Single-rank save: the capture→flush→commit spans share one flow id
    so Perfetto can draw the cross-lane arrows."""
    state = {"model": {"w": np.arange(65536, dtype=np.float32)},
             "meta": {"step": 0}}
    t = trace.enable()
    mgr = CheckpointManager.from_policy(str(tmp_path), None)
    try:
        mgr.save(3, state).wait_persisted()
        mgr.wait_for_commit(3)
    finally:
        mgr.close()
    fid = trace.flow_id("save", 3)
    linked = {s["name"] for s in t.spans() if s["flow"] == fid}
    assert {"flush", "commit"} <= linked
    ends = [s for s in t.spans() if s["flow"] == fid
            and s["flow_phase"] == "end"]
    assert [s["name"] for s in ends] == ["commit"]
