"""Offline shard consolidation (paper §VII future work): fewer files, same
restore semantics."""

import glob
import os

import jax.numpy as jnp
import numpy as np

from repro.core import CheckpointManager, step_dir
from repro.core.consolidate import consolidate_step_dir, file_count
from conftest import run_in_subprocess


def test_consolidate_singlefile_noop_safe(tmp_path):
    state = {"a": jnp.arange(100, dtype=jnp.float32),
             "meta": {"step": 1}}
    mgr = CheckpointManager(str(tmp_path), mode="datastates")
    mgr.save(1, state, blocking=True)
    sdir = step_dir(str(tmp_path), 1)
    n0 = file_count(sdir)
    written = consolidate_step_dir(sdir, group=8)
    assert len(written) == 1 and file_count(sdir) == 1
    out = mgr.restore(state, step=1)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(state["a"]))
    assert out["meta"] == state["meta"]
    mgr.close()


def test_consolidate_sharded_many_ranks():
    out = run_in_subprocess(r"""
import glob, os, tempfile
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import CheckpointManager, step_dir
from repro.core.consolidate import consolidate_step_dir, file_count
from repro.launch.mesh import make_mesh

mesh = make_mesh((8,), ("data",))
w = jax.device_put(jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16),
                   NamedSharding(mesh, P("data", None)))
state = {"w": w, "meta": {"step": 2, "note": "consolidate me"}}
tmp = tempfile.mkdtemp()
mgr = CheckpointManager(tmp, mode="datastates")
mgr.save(2, state, blocking=True)
sdir = step_dir(tmp, 2)
assert file_count(sdir) == 8, file_count(sdir)     # one per rank
written = consolidate_step_dir(sdir, group=4)
assert len(written) == 2 and file_count(sdir) == 2  # 8 -> 2 aggregates

# restore (same + different sharding) still works
r = mgr.restore(state, step=2)
np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(w))
assert r["meta"]["note"] == "consolidate me"
tpl = {"w": jax.ShapeDtypeStruct((8, 16), jnp.float32,
        sharding=NamedSharding(mesh, P(None, "data"))),
       "meta": {}}
r2 = mgr.restore(tpl, step=2)
np.testing.assert_array_equal(np.asarray(r2["w"]), np.asarray(w))
mgr.close()
print("CONSOLIDATE-OK")
""")
    assert "CONSOLIDATE-OK" in out
