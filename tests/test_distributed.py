"""Sharded checkpointing under a real (virtual-device) mesh: dedup, per-rank
files, elastic restore. Runs in subprocesses with 8 CPU devices."""

import pytest

from conftest import run_in_subprocess

# Whole-module slow marker: subprocess runs with 8 virtual devices; the
# fast lane (scripts/run_tests.sh --fast) deselects these.
pytestmark = pytest.mark.slow


def test_sharded_save_dedup_and_elastic_restore():
    out = run_in_subprocess(r"""
import jax, jax.numpy as jnp, numpy as np, tempfile, os, glob
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import CheckpointManager, FileReader
from repro.launch.mesh import make_mesh

mesh = make_mesh((4, 2), ("data", "model"))
w = jax.device_put(jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32),
                   NamedSharding(mesh, P("data", "model")))
zero1 = jax.device_put(jnp.ones((64, 32)), NamedSharding(mesh, P("data", None)))
repl = jax.device_put(jnp.arange(16.0), NamedSharding(mesh, P()))
state = {"params": {"w": w}, "opt": {"m": zero1}, "repl": repl,
         "meta": {"step": 3}}
tmp = tempfile.mkdtemp()
mgr = CheckpointManager(tmp, mode="datastates")
mgr.save(3, state, blocking=True)
files = sorted(glob.glob(os.path.join(tmp, "global_step3", "*.dsllm")))
assert len(files) == 8, files   # one per rank (Fig 1(c,d))

# dedup: the replicated array is stored exactly once
n_repl = sum(1 for f in files for n in FileReader(f).tensors
             if n.startswith("state/repl"))
assert n_repl == 1, n_repl
# ZeRO-1-style array: 4 unique shards (data axis), not 8
n_zero1 = sum(1 for f in files for n in FileReader(f).tensors
              if n.startswith("state/opt/m"))
assert n_zero1 == 4, n_zero1

# same-sharding restore
out = mgr.restore(state, step=3)
np.testing.assert_array_equal(np.asarray(out["params"]["w"]), np.asarray(w))

# elastic restore to a different mesh/sharding
mesh2 = make_mesh((2, 4), ("data", "model"))
tpl = {"params": {"w": jax.ShapeDtypeStruct((64, 32), jnp.float32,
        sharding=NamedSharding(mesh2, P("model", "data")))},
       "opt": {"m": jax.ShapeDtypeStruct((64, 32), jnp.float32,
        sharding=NamedSharding(mesh2, P(None, "model")))},
       "repl": jax.ShapeDtypeStruct((16,), jnp.float32,
        sharding=NamedSharding(mesh2, P())),
       "meta": {"step": 0}}
r2 = mgr.restore(tpl, step=3)
np.testing.assert_array_equal(np.asarray(r2["params"]["w"]), np.asarray(w))
np.testing.assert_array_equal(np.asarray(r2["opt"]["m"]), np.asarray(zero1))
assert r2["meta"]["step"] == 3
mgr.close()
print("DISTRIBUTED-OK")
""")
    assert "DISTRIBUTED-OK" in out


def test_sharded_train_step_and_checkpoint_under_mesh():
    out = run_in_subprocess(r"""
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, smoke_variant
from repro.core import CheckpointManager
from repro.models import model as M
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.sharding import context as shctx
from repro.sharding.partition import param_pspecs, opt_pspecs, shardings_for
from repro.training.loop import make_train_step
from repro.data.pipeline import SyntheticTokenPipeline
from repro.launch.mesh import make_mesh
import dataclasses

cfg = smoke_variant(get_config("llama3.2-1b"))
cfg = dataclasses.replace(cfg, d_model=128, d_ff=256, vocab=256)
mesh = make_mesh((2, 4), ("data", "model"))
with shctx.activate(mesh):
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    pshard = shardings_for(param_pspecs(cfg, params, mesh), mesh)
    params = jax.device_put(params, pshard)
    opt = init_opt_state(params)
    oshard = shardings_for(opt_pspecs(cfg, params, mesh), mesh)
    opt = jax.device_put(opt, oshard)
    pipe = SyntheticTokenPipeline(cfg, 4, 32)
    batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
    step = jax.jit(make_train_step(cfg, AdamWConfig()))
    new_params, new_opt, loss = step(params, opt, batch)
    assert np.isfinite(float(loss)), loss

    # checkpoint the *sharded* training state and restore it
    tmp = tempfile.mkdtemp()
    mgr = CheckpointManager(tmp, mode="datastates")
    state = {"model": new_params, "optimizer": new_opt, "meta": {"step": 1}}
    mgr.save(1, state, blocking=True)
    restored = mgr.restore(state, step=1)
    w_a = jax.tree_util.tree_leaves(new_params)[0]
    w_b = jax.tree_util.tree_leaves(restored["model"])[0]
    np.testing.assert_array_equal(np.asarray(w_a, dtype=np.float32),
                                  np.asarray(w_b, dtype=np.float32))
    mgr.close()
print("MESH-TRAIN-OK")
""")
    assert "MESH-TRAIN-OK" in out


def test_zero1_optimizer_sharding_reduces_per_rank_bytes():
    out = run_in_subprocess(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.distributed import plan_shards, group_by_rank
from repro.launch.mesh import make_mesh

mesh = make_mesh((8, 1), ("data", "model"))
opt = jax.device_put(jnp.zeros((1024, 64), jnp.float32),
                     NamedSharding(mesh, P("data", None)))
records, _ = plan_shards({"m": opt}, group="state")
by_rank = group_by_rank(records)
assert len(by_rank) == 8
sizes = {r: sum(rec.nbytes for rec in recs) for r, recs in by_rank.items()}
total = 1024 * 64 * 4
assert all(abs(s - total / 8) < 1 for s in sizes.values()), sizes
print("ZERO1-OK")
""")
    assert "ZERO1-OK" in out
