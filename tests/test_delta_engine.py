"""Differential checkpointing on the main engine path (ISSUE 4 tentpole).

Covers the save-side DeltaStateProvider (keyframe/delta modes, snapshot
cache inside the host-cache budget), the codec-aware flush stage (file
sizes shrink), chain metadata in the catalog, chain-aware retention GC,
whole-chain cascade, and bit-exact chain replay through RestoreEngine —
including hypothesis property tests over arbitrary dtypes/shapes and
chain lengths 1..2·keyframe_every.
"""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import HealthCheck, given, settings, st

from repro.core import (CheckpointManager, DeltaPolicy, FileReader,
                        RestoreEngine, RestoreError)
from repro.core.state_provider import DELTA_CODEC
from repro.storage import MemoryBackend
from repro.storage.backend import BackendError
from repro.storage.repository import RetentionPolicy, Tier


def make_state(arrays, step=0):
    return {"model": dict(arrays), "meta": {"step": step, "tag": "delta"}}


def template_for(state):
    return {"model": {k: np.empty(np.asarray(v).shape, np.asarray(v).dtype)
                      for k, v in state["model"].items()},
            "meta": {"step": -1, "tag": ""}}


def mutate(state, step, frac=13):
    """Small sparse change — the slowly-moving-optimizer-state workload."""
    model = {}
    for k, v in state["model"].items():
        arr = np.array(np.asarray(v), copy=True)
        flat = arr.reshape(-1)
        if flat.size:
            if np.issubdtype(arr.dtype, np.floating):
                flat[::frac] += np.asarray(0.001, arr.dtype)
            else:
                flat[::frac] += 1
        model[k] = jnp.asarray(arr)
    return {"model": model, "meta": {"step": step, "tag": "delta"}}


def base_arrays():
    rng = np.random.default_rng(0)
    return {f"w{i}": jnp.asarray(rng.standard_normal(500 + 7 * i)
                                 .astype(np.float32))
            for i in range(3)}


def assert_bit_exact(restored, expected):
    for k, v in expected["model"].items():
        a = np.asarray(restored["model"][k])
        b = np.asarray(v)
        np.testing.assert_array_equal(a.view(np.uint8).reshape(-1),
                                      b.view(np.uint8).reshape(-1))


# ---------------------------------------------------------------- policy
def test_delta_policy_validation(tmp_path):
    with pytest.raises(ValueError, match="keyframe_every"):
        DeltaPolicy(keyframe_every=0)
    with pytest.raises(ValueError, match="DataMovementEngine"):
        CheckpointManager(str(tmp_path), mode="sync", delta=DeltaPolicy())


def test_chain_cadence_and_catalog_metadata(tmp_path):
    """keyframe_every=3 ⇒ k,d,d,k,d,... with base_step/chain_depth/codec
    recorded per step and per file in the catalog."""
    state = make_state(base_arrays())
    with CheckpointManager(str(tmp_path),
                           delta=DeltaPolicy(keyframe_every=3)) as mgr:
        for s in range(1, 6):
            state = mutate(state, s)
            mgr.save(s, state, blocking=True)
        expect = {1: (True, None, 0), 2: (False, 1, 1), 3: (False, 2, 2),
                  4: (True, None, 0), 5: (False, 4, 1)}
        for s, (kf, base, depth) in expect.items():
            d = mgr.repository.manifest(s).meta["delta"]
            assert d["keyframe"] is kf
            assert d["base_step"] == base
            assert d["chain_depth"] == depth
            assert d["codec"] == DELTA_CODEC
            codecs = {f.codec for f in mgr.repository.manifest(s).files
                      if f.name.endswith(".dsllm")}
            assert codecs == ({"raw"} if kf else {DELTA_CODEC})


def test_delta_files_smaller_and_restore_bit_exact(tmp_path):
    """Sparse mutations ⇒ delta steps far smaller than keyframes, and
    every step of the chain restores bit-exactly through the manager."""
    state = make_state(base_arrays())
    states = {}
    with CheckpointManager(str(tmp_path),
                           delta=DeltaPolicy(keyframe_every=4)) as mgr:
        for s in range(1, 7):
            state = mutate(state, s)
            states[s] = state
            mgr.save(s, state, blocking=True)
        key_bytes = mgr.repository.manifest(1).total_bytes
        delta_bytes = mgr.repository.manifest(2).total_bytes
        assert delta_bytes < key_bytes / 3
        for s in range(1, 7):
            out = mgr.restore(template_for(states[s]), step=s)
            assert_bit_exact(out, states[s])
            assert out["meta"]["step"] == s  # objects ride every save


def test_delta_step_cannot_be_restored_alone(tmp_path):
    state = make_state(base_arrays())
    with CheckpointManager(str(tmp_path),
                           delta=DeltaPolicy(keyframe_every=4)) as mgr:
        for s in (1, 2):
            state = mutate(state, s)
            mgr.save(s, state, blocking=True)
        sdir = mgr.repository.step_dir(2)
        with pytest.raises(RestoreError, match="delta-encoded"):
            RestoreEngine(threads=1).restore(sdir, template_for(state))
        # ...and FileReader refuses to hand out XOR-domain bytes as values
        f = [n for n in os.listdir(sdir) if n.endswith(".dsllm")][0]
        rd = FileReader(os.path.join(sdir, f))
        enc = [n for n, e in rd.tensors.items() if e.codec != "raw"]
        assert enc
        with pytest.raises(ValueError, match="chain"):
            rd.read_tensor(enc[0])


def test_reshard_forces_keyframe(tmp_path):
    """Changing the shard set / shapes between saves must break the chain
    with a fresh keyframe (elastic reshard rule)."""
    state = make_state(base_arrays())
    with CheckpointManager(str(tmp_path),
                           delta=DeltaPolicy(keyframe_every=10)) as mgr:
        mgr.save(1, mutate(state, 1), blocking=True)
        mgr.save(2, mutate(state, 2), blocking=True)
        assert not mgr.repository.manifest(2).meta["delta"]["keyframe"]
        rng = np.random.default_rng(1)
        resharded = make_state(
            {"w0": jnp.asarray(rng.standard_normal(777).astype(np.float32))},
            step=3)
        mgr.save(3, resharded, blocking=True)
        d = mgr.repository.manifest(3).meta["delta"]
        assert d["keyframe"] is True and d["base_step"] is None
        out = mgr.restore(template_for(resharded), step=3)
        assert_bit_exact(out, resharded)


def test_failed_save_invalidates_chain(tmp_path):
    """An engine failure mid-chain forces the next save back to a
    keyframe (the snapshot cache can no longer be trusted as a base)."""
    from repro.core import CheckpointError
    state = make_state(base_arrays())
    with CheckpointManager(str(tmp_path), host_cache_bytes=64 << 20,
                           delta=DeltaPolicy(keyframe_every=10)) as mgr:
        mgr.save(1, mutate(state, 1), blocking=True)
        mgr.save(2, mutate(state, 2), blocking=True)
        huge = make_state({"w0": np.zeros(200 << 20, np.uint8)})
        with pytest.raises(CheckpointError):
            mgr.save(3, huge, blocking=True)
        mgr.save(4, mutate(state, 4), blocking=True)
        assert mgr.repository.manifest(4).meta["delta"]["keyframe"] is True


# ------------------------------------------------------------ GC/cascade
def test_gc_keeps_whole_chain_of_retained_step(tmp_path):
    state = make_state(base_arrays())
    with CheckpointManager(str(tmp_path),
                           delta=DeltaPolicy(keyframe_every=4)) as mgr:
        states = {}
        for s in range(1, 7):  # k1 d2 d3 d4 k5 d6
            state = mutate(state, s)
            states[s] = state
            mgr.save(s, state, blocking=True)
        rep = mgr.repository.gc(retention=RetentionPolicy(keep_last_n=1))
        # keep-last-1 retains step 6; its chain pins keyframe 5 too
        assert rep.deleted_steps == [1, 2, 3, 4]
        assert mgr.repository.local_steps() == [5, 6]
        out = mgr.restore(template_for(states[6]), step=6)
        assert_bit_exact(out, states[6])


def test_pinned_delta_step_pins_whole_chain(tmp_path):
    state = make_state(base_arrays())
    with CheckpointManager(str(tmp_path),
                           delta=DeltaPolicy(keyframe_every=4)) as mgr:
        states = {}
        for s in range(1, 8):  # k1 d2 d3 d4 k5 d6 d7
            state = mutate(state, s)
            states[s] = state
            mgr.save(s, state, blocking=True)
        mgr.repository.pin(3)  # a mid-chain delta
        rep = mgr.repository.gc(retention=RetentionPolicy(keep_last_n=1))
        # pinned 3 pins 2 and keyframe 1; kept 7 pins 6 and keyframe 5
        assert set(rep.deleted_steps) == {4}
        assert mgr.repository.local_steps() == [1, 2, 3, 5, 6, 7]
        out = mgr.restore(template_for(states[3]), step=3)
        assert_bit_exact(out, states[3])


def test_cascade_ships_whole_chains_or_nothing(tmp_path):
    """A delta step only lands on a remote tier together with its
    ancestors; with the base gone everywhere, nothing ships."""
    from repro.storage import CheckpointRepository
    state = make_state(base_arrays())
    tier = Tier("mem", MemoryBackend())
    with CheckpointManager(str(tmp_path),
                           delta=DeltaPolicy(keyframe_every=4)) as mgr:
        for s in range(1, 4):  # k1 d2 d3
            state = mutate(state, s)
            mgr.save(s, state, blocking=True)
    repo = CheckpointRepository(str(tmp_path), remote_tiers=[tier],
                                auto_cascade=False)
    repo.cascade_step(3)  # ships 1 (keyframe), 2, 3
    assert repo.tier_steps(tier) == [1, 2, 3]
    # wipe the tier and the local keyframe: the chain can no longer ship
    for s in (1, 2, 3):
        repo._delete_tier_step(tier, s)
    repo._delete_local_step(1)
    with pytest.raises(BackendError, match="chain base"):
        repo.cascade_step(2)
    assert repo.tier_steps(tier) == []
    repo.close()


# ------------------------------------------------------- property tests
_DTYPES = (np.float32, np.float16, np.int32, np.uint8)


def _random_arrays(seed, n_tensors, odd):
    rng = np.random.default_rng(seed)
    out = {}
    for i in range(n_tensors):
        dtype = _DTYPES[int(rng.integers(len(_DTYPES)))]
        nd = int(rng.integers(0, 3))
        shape = tuple(int(rng.integers(1, 9)) for _ in range(nd))
        if odd and nd:  # force the odd-size u32-padding path
            shape = shape[:-1] + (shape[-1] * 2 + 1,)
        if np.issubdtype(dtype, np.floating):
            arr = rng.standard_normal(shape).astype(dtype)
        else:
            arr = rng.integers(0, 100, size=shape).astype(dtype)
        out[f"t{i}"] = jnp.asarray(arr)
    return out


def _chain_roundtrip(d, seed, n_tensors, keyframe_every, odd, n_saves):
    state = make_state(_random_arrays(seed, n_tensors, odd))
    states = {}
    with CheckpointManager(
            str(d), delta=DeltaPolicy(keyframe_every=keyframe_every),
            manifest_checksums=False) as mgr:
        for s in range(1, n_saves + 1):
            state = mutate(state, s, frac=3)
            states[s] = state
            mgr.save(s, state, blocking=True)
        for s in (1, n_saves):
            out = mgr.restore(template_for(states[s]), step=s)
            assert_bit_exact(out, states[s])


@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(seed=st.integers(0, 2**31 - 1), n_tensors=st.integers(1, 4),
       keyframe_every=st.integers(1, 3), odd=st.booleans(),
       data=st.data())
def test_property_chain_replay_bit_exact(tmp_path_factory, seed, n_tensors,
                                         keyframe_every, odd, data):
    """Arbitrary dtypes/shapes (incl. odd sizes), chain lengths
    1..2·keyframe_every: every save restores bit-exactly."""
    n_saves = data.draw(st.integers(1, 2 * keyframe_every))
    _chain_roundtrip(tmp_path_factory.mktemp("delta-prop"), seed, n_tensors,
                     keyframe_every, odd, n_saves)


@pytest.mark.parametrize(
    "seed,n_tensors,keyframe_every,odd,n_saves",
    [(0, 3, 1, False, 2), (1, 2, 2, True, 4), (2, 4, 3, True, 6),
     (3, 1, 3, False, 1)])
def test_chain_replay_fixed_cases(tmp_path, seed, n_tensors, keyframe_every,
                                  odd, n_saves):
    """The property above pinned to fixed cases, so minimal installs
    (no hypothesis) keep the coverage."""
    _chain_roundtrip(tmp_path, seed, n_tensors, keyframe_every, odd, n_saves)


@pytest.mark.slow
def test_chain_restore_elastic_onto_sharded_mesh(tmp_path):
    """A delta chain saved single-device restores bit-exactly onto an
    8-way sharded target (multi-region buffers: every delta shard folds
    into several target regions)."""
    from conftest import run_in_subprocess
    run_in_subprocess(r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import CheckpointManager, DeltaPolicy
from repro.launch.mesh import make_mesh

d = %r
rng = np.random.default_rng(0)
state = {"model": {f"w{i}": jnp.asarray(
    rng.standard_normal((16, 24)).astype(np.float32)) for i in range(3)},
    "meta": {"step": 0}}
with CheckpointManager(d, delta=DeltaPolicy(keyframe_every=3)) as mgr:
    for s in range(1, 6):  # k d d k d
        state = {"model": {k: v.at[::5].add(0.25)
                           for k, v in state["model"].items()},
                 "meta": {"step": s}}
        mgr.save(s, state, blocking=True)
    mesh = make_mesh((8,), ("data",))
    shard = NamedSharding(mesh, P("data", None))
    tpl = {"model": {k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=shard)
                     for k, v in state["model"].items()},
           "meta": {"step": 0}}
    out = mgr.restore(tpl, step=5)
    for k, v in state["model"].items():
        got = np.asarray(out["model"][k])
        np.testing.assert_array_equal(got.view(np.uint8),
                                      np.asarray(v).view(np.uint8))
    assert len(out["model"]["w0"].sharding.device_set) == 8
print("elastic delta chain OK")
""" % str(tmp_path))
