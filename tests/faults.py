"""Deterministic fault injection for multi-rank checkpoint saves.

The :class:`~repro.dist.coordinator.Coordinator` calls its ``fault_hook``
at named protocol points, per rank, with the save context. The
:class:`FaultInjector` here is that hook: armed with a (point, rank, step)
triple it deterministically kills or stalls exactly that rank exactly
there, so tests can walk every window of the two-phase commit:

* ``"mid_file"``   — fired after the rank's engine persisted its file;
  the injector *truncates the file* before dying, leaving the footer-less
  partial a real SIGKILL mid-write leaves on disk;
* ``"after_upload"`` — data file complete and durable, but the rank dies
  before casting its phase-1 vote (no rank manifest);
* ``"before_ack"`` — vote written, rank dies before the ack collective:
  every byte of the step is on disk, yet phase 2 must never run.

``action="stall"`` blocks the rank on an event instead of killing it
(the straggler case — the coordinator's ack timeout must fire); call
:meth:`release` to let the stalled rank finish so engines can drain.

Differential-checkpoint faults: the same protocol points cover delta
saves (a rank killed mid-delta-save must leave the *chain* restorable at
the previous committed step), and :func:`tamper_file` models post-commit
bitrot — flip payload bytes of a committed keyframe/delta in place, so
chain-aware ``storage.cli verify`` must fail every dependent step.

Process-runtime faults: ``FaultInjector`` is a closure and cannot cross
a process boundary; the process-per-rank runtime takes a *picklable*
:class:`~repro.dist.ipc.ProcessFaultSpec` (re-exported here) instead and
fires it child-side with a real ``SIGKILL`` — same protocol windows,
plus ``"after_vote"``, with an actual corpse instead of an exception.
"""

from __future__ import annotations

import os
import threading
from typing import Any, Dict, Optional

from repro.dist.ipc import (PROCESS_FAULT_POINTS, ProcessDied,
                            ProcessFaultSpec)

__all__ = ["FaultInjector", "InjectedFault", "PROCESS_FAULT_POINTS",
           "ProcessDied", "ProcessFaultSpec", "tamper_file"]


class InjectedFault(RuntimeError):
    """The deterministic 'kill' raised inside a writer rank."""


def tamper_file(path: str, *, offset: int = 64, nbytes: int = 8) -> None:
    """Flip ``nbytes`` payload bytes of ``path`` in place (post-commit
    bitrot). The file length is unchanged, so only checksum audits — not
    size checks — can catch it; delta-chain tests use this on keyframes
    and intermediate deltas."""
    size = os.path.getsize(path)
    offset = max(0, min(offset, size - nbytes))
    with open(path, "r+b") as f:
        f.seek(offset)
        data = f.read(nbytes)
        f.seek(offset)
        f.write(bytes(b ^ 0xFF for b in data))


class FaultInjector:
    """Arm one fault at one protocol point of one rank (optionally one
    step); pass the instance as ``Coordinator(fault_hook=...)``."""

    def __init__(self, point: str, rank: int, *, step: Optional[int] = None,
                 action: str = "die"):
        assert action in ("die", "stall"), action
        self.point = point
        self.rank = rank
        self.step = step
        self.action = action
        self.fired = threading.Event()
        self._release = threading.Event()
        self.log = []  # every (point, rank, step) the hook saw

    def __call__(self, point: str, rank: int, info: Dict[str, Any]) -> None:
        self.log.append((point, rank, info["step"]))
        if point != self.point or rank != self.rank:
            return
        if self.step is not None and info["step"] != self.step:
            return
        self.fired.set()
        if self.action == "stall":
            self._release.wait()
            return
        if point == "mid_file":
            # leave what a kill -9 mid-write leaves: a footer-less partial
            for path in info["files"]:
                if os.path.exists(path):
                    with open(path, "r+b") as f:
                        f.truncate(max(os.path.getsize(path) // 2, 1))
        raise InjectedFault(
            f"injected fault: rank {rank} killed at {point!r} "
            f"(step {info['step']})")

    def release(self) -> None:
        """Un-stall the rank (so engines/queues can drain at teardown)."""
        self._release.set()
