"""ckptlint analyzer tests: golden fixtures per rule family, the
clean-tree merge gate, suppression handling, CLI exit codes, and the
runtime lock-order witness."""

import os
import re
import threading

import pytest

from repro.analysis import __main__ as cli
from repro.analysis import linter, witness
from repro.analysis.locks import declares_lock, named_lock

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.abspath(os.path.join(HERE, ".."))
FIXTURES = os.path.join(HERE, "fixtures", "ckptlint")

_EXPECT_RE = re.compile(r"EXPECT:(CKPT\d+)")

VIOLATION_FIXTURES = [
    "lockorder_violation.py",
    "blocking_violation.py",
    "commit_violation.py",
    "snapshot_violation.py",
    "hygiene_violation.py",
]


def expected_findings(path):
    """(rule, line) pairs from the fixture's inline EXPECT markers."""
    out = set()
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            for rule in _EXPECT_RE.findall(line):
                out.add((rule, lineno))
    return out


def run_fixture(name):
    path = os.path.join(FIXTURES, name)
    active, suppressed = linter.run([path], root=REPO)
    rel = os.path.relpath(path, REPO).replace(os.sep, "/")
    for f in active + suppressed:
        assert f.path == rel
    return active, suppressed


# ---------------------------------------------------------------- static pass
@pytest.mark.parametrize("name", VIOLATION_FIXTURES)
def test_rule_family_detected_with_exact_locations(name):
    """Each seeded violation is found at its exact file:line — and nothing
    else in the fixture is flagged (false-positive guard)."""
    active, suppressed = run_fixture(name)
    assert not suppressed
    found = {(f.rule, f.line) for f in active}
    assert found == expected_findings(os.path.join(FIXTURES, name))


def test_all_five_rule_families_are_covered_by_fixtures():
    families = set()
    for name in VIOLATION_FIXTURES:
        for rule, _line in expected_findings(os.path.join(FIXTURES, name)):
            families.add(rule[:5])  # CKPT + family digit
    assert families >= {"CKPT1", "CKPT2", "CKPT3", "CKPT4", "CKPT5"}


def test_clean_fixture_has_no_findings():
    active, suppressed = run_fixture("clean_ok.py")
    assert active == [] and suppressed == []


def test_suppression_comments_silence_but_record():
    active, suppressed = run_fixture("suppressed_ok.py")
    assert active == []
    assert {f.rule for f in suppressed} == {"CKPT201", "CKPT301"}
    assert all(f.suppressed for f in suppressed)


def test_clean_tree_merge_gate():
    """The repo's own src/ must lint clean — new violations fail tier-1,
    and every silenced finding is an explicit, justified suppression."""
    active, suppressed = linter.run([os.path.join(REPO, "src")], root=REPO)
    assert active == [], "\n".join(f.format() for f in active)
    # the known justified suppressions; growing this list is a review event
    assert {(f.path, f.rule) for f in suppressed} == {
        ("src/repro/core/baselines.py", "CKPT301"),
        ("src/repro/core/reduction.py", "CKPT301"),
        ("src/repro/storage/repository.py", "CKPT302"),
    }


def test_finding_format_is_file_line_col():
    active, _ = run_fixture("commit_violation.py")
    line = active[0].format()
    assert re.match(r"^tests/fixtures/ckptlint/commit_violation\.py:"
                    r"\d+:\d+: CKPT\d+ ", line)


def test_syntax_error_is_a_finding(tmp_path):
    bad = tmp_path / "broken.py"
    bad.write_text("def oops(:\n")
    active, _ = linter.run([str(bad)], root=str(tmp_path))
    assert len(active) == 1 and active[0].rule == "CKPT000"


# ----------------------------------------------------------------------- CLI
def test_cli_exit_codes(capsys):
    assert cli.main([FIXTURES]) == 1
    assert cli.main([os.path.join(FIXTURES, "clean_ok.py")]) == 0
    assert cli.main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    assert "CKPT101" in out and "CKPT501" in out


def test_cli_select_restricts_rules(capsys):
    rc = cli.main(["--select", "CKPT4",
                   os.path.join(FIXTURES, "lockorder_violation.py")])
    assert rc == 0  # no snapshot findings in the lock-order fixture
    rc = cli.main(["--select", "CKPT1",
                   os.path.join(FIXTURES, "lockorder_violation.py")])
    assert rc == 1


def test_cli_json_output(capsys):
    import json
    rc = cli.main(["--format", "json",
                   os.path.join(FIXTURES, "snapshot_violation.py")])
    assert rc == 1
    payload = json.loads(capsys.readouterr().out)
    assert {f["rule"] for f in payload["findings"]} == {"CKPT401"}


# ------------------------------------------------------------ runtime witness
def test_witness_records_out_of_order_acquisition():
    with witness.recording() as w:
        outer = named_lock("tw.order.outer", rank=10)
        inner = named_lock("tw.order.inner", rank=20)
        with inner:
            with outer:  # rank 10 under rank 20: violation
                pass
    assert len(w.violations) == 1
    v = w.violations[0]
    assert v.name == "tw.order.outer" and v.held[-1][0] == "tw.order.inner"
    with pytest.raises(AssertionError):
        w.assert_clean()


def test_witness_clean_on_correct_order():
    with witness.recording() as w:
        outer = named_lock("tw.clean.outer", rank=10)
        inner = named_lock("tw.clean.inner", rank=20)
        with outer:
            with inner:
                pass
        with inner:  # non-nested reacquisition is always fine
            pass
    assert w.violations == []
    assert ("tw.clean.outer", "tw.clean.inner") in w.edges
    w.assert_clean()


def test_witness_ignores_reentrant_alias():
    # exercised at the witness API level: a real threading.Lock would
    # self-deadlock on nested acquisition, which is exactly why the alias
    # case (Condition over the same lock, RLock reentry) must not be
    # counted as a hierarchy violation
    w = witness.LockWitness()
    w.note_acquire("tw.alias.cond", 30)
    w.note_acquire("tw.alias.cond", 30)
    assert w.violations == []
    w.note_release("tw.alias.cond")
    w.note_release("tw.alias.cond")


def test_declares_lock_wraps_only_while_recording():
    @declares_lock("tw.box", rank=5, attrs=("_lock",))
    class Box:
        def __init__(self):
            self._lock = threading.Lock()

        def poke(self):
            with self._lock:
                return 1

    plain = Box()
    assert isinstance(plain._lock, type(threading.Lock()))
    with witness.recording() as w:
        box = Box()
        assert isinstance(box._lock, witness.WitnessLock)
        assert box.poke() == 1
    assert w.acquisitions == 1 and w.violations == []
    # recording over: new instances get plain locks again
    assert isinstance(Box()._lock, type(threading.Lock()))


def test_witness_on_real_host_cache():
    from repro.core.host_cache import HostCache

    with witness.recording() as w:
        hc = HostCache(1 << 16)
        res = hc.reserve(1 << 10)
        res.release()
    assert w.acquisitions >= 2
    w.assert_clean()


def test_hierarchy_is_consistent_at_runtime():
    from repro.analysis.locks import declared_hierarchy
    # importing the runtime modules registers every declaration; ranks in
    # the table must be conflict-free (declared_hierarchy raises otherwise)
    import repro.core.checkpoint  # noqa: F401
    import repro.dist.coordinator  # noqa: F401
    ranks = declared_hierarchy()
    for name in ("coordinator.job", "barrier.cond", "repository.state",
                 "engine.file_state", "writer.append", "host_cache.alloc"):
        assert name in ranks
    assert ranks["coordinator.job"] < ranks["repository.state"] \
        < ranks["host_cache.alloc"]
