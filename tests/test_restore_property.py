"""Property tests: arbitrary heterogeneous pytrees round-trip through every
engine format (the system invariant behind 'globally consistent state'),
and multi-rank saves round-trip across mesh/world shapes (elastic
restore — ISSUE 3 satellite)."""

import numpy as np
import pytest
from conftest import (HealthCheck, given, run_in_subprocess, settings,
                      st)  # hypothesis optional

from repro.core import ENGINES, CheckpointManager

DTYPES = [np.float32, np.float16, np.int32, np.int8, np.uint8, np.bool_]


@st.composite
def arrays(draw):
    dtype = draw(st.sampled_from(DTYPES))
    ndim = draw(st.integers(0, 3))
    shape = tuple(draw(st.integers(1, 5)) for _ in range(ndim))
    n = int(np.prod(shape)) if shape else 1
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    if dtype == np.bool_:
        return rng.integers(0, 2, size=shape).astype(np.bool_)
    if np.issubdtype(dtype, np.integer):
        info = np.iinfo(dtype)
        return rng.integers(info.min, info.max, size=shape).astype(dtype)
    return rng.normal(size=shape).astype(dtype)


scalars = st.one_of(st.integers(-2**31, 2**31), st.text(max_size=12),
                    st.floats(allow_nan=False), st.booleans(), st.none())

trees = st.recursive(
    st.one_of(arrays(), scalars),
    lambda kids: st.dictionaries(
        st.text(st.characters(categories=("Ll",)), min_size=1, max_size=6),
        kids, min_size=1, max_size=3),
    max_leaves=8)


def _assert_tree_equal(tree, out):
    import jax
    la, ta = jax.tree_util.tree_flatten(tree)
    lb, tb = jax.tree_util.tree_flatten(out)
    assert ta == tb
    for x, y in zip(la, lb):
        if isinstance(x, np.ndarray):
            np.testing.assert_array_equal(np.asarray(y), x)
            assert np.asarray(y).dtype == x.dtype
        elif isinstance(x, float):
            assert y == pytest.approx(x, nan_ok=True)
        else:
            assert y == x


@pytest.mark.parametrize("mode", sorted(ENGINES))
@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(tree=st.dictionaries(st.sampled_from(["a", "b", "c"]), trees,
                            min_size=1, max_size=3))
def test_roundtrip_any_tree(tmp_path_factory, mode, tree):
    d = tmp_path_factory.mktemp(f"prop_{mode}")
    with CheckpointManager(str(d), mode=mode) as mgr:
        mgr.save(1, tree, blocking=True)
        out = mgr.restore(tree, step=1)
    _assert_tree_equal(tree, out)


@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(tree=st.dictionaries(st.sampled_from(["a", "b", "c"]), trees,
                            min_size=1, max_size=3),
       save_world=st.integers(1, 4), restore_world=st.integers(1, 4))
def test_roundtrip_any_tree_across_worlds(tmp_path_factory, tree,
                                          save_world, restore_world):
    """Multi-rank saves are format-compatible with any restore world: a
    tree saved by N writer ranks restores bit-exact under a manager with
    M ranks (restore is world-agnostic by construction)."""
    d = tmp_path_factory.mktemp(f"prop_w{save_world}_{restore_world}")
    with CheckpointManager(str(d), world=save_world,
                           manifest_checksums=False) as mgr:
        mgr.save(1, tree, blocking=True)
    with CheckpointManager(str(d), world=restore_world,
                           manifest_checksums=False) as mgr:
        out = mgr.restore(tree, step=1)
    _assert_tree_equal(tree, out)


@pytest.mark.slow
def test_reshard_roundtrip_mesh_grid():
    """Elastic multi-rank round-trip over a DP×TP mesh grid: save under
    one world shape (multi-rank coordinator), restore under a different
    mesh and sharding, assert bit-exact params (ISSUE 3 acceptance:
    N-rank save onto an M-rank mesh)."""
    out = run_in_subprocess(r"""
import itertools, tempfile
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import CheckpointManager
from repro.launch.mesh import make_mesh

SHAPES = [(8, 1), (4, 2), (2, 4), (1, 8)]

def state_for(mesh):
    return {
        "w2d": jax.device_put(
            jnp.arange(64.0 * 48).reshape(64, 48),
            NamedSharding(mesh, P("data", "model"))),
        "zero1": jax.device_put(jnp.arange(96.0).reshape(32, 3) * 2,
                                NamedSharding(mesh, P("data", None))),
        "repl": jax.device_put(jnp.arange(40.0),
                               NamedSharding(mesh, P())),
        "meta": {"step": 1},
    }

def template_for(mesh):
    return {
        "w2d": jax.ShapeDtypeStruct((64, 48), jnp.float32,
                sharding=NamedSharding(mesh, P("model", "data"))),
        "zero1": jax.ShapeDtypeStruct((32, 3), jnp.float32,
                sharding=NamedSharding(mesh, P(None, None))),
        "repl": jax.ShapeDtypeStruct((40,), jnp.float32,
                sharding=NamedSharding(mesh, P("data"))),
        "meta": {"step": 0},
    }

for (sdp, stp), (rdp, rtp) in itertools.permutations(SHAPES, 2):
    if (sdp, stp) in ((2, 4), (1, 8)) and (rdp, rtp) not in ((4, 2), (8, 1)):
        continue  # trim the grid: keep every save shape + varied restores
    save_mesh = make_mesh((sdp, stp), ("data", "model"))
    restore_mesh = make_mesh((rdp, rtp), ("data", "model"))
    tmp = tempfile.mkdtemp()
    world = max(2, sdp // 2)
    with CheckpointManager(tmp, world=world,
                           manifest_checksums=False) as mgr:
        state = state_for(save_mesh)
        mgr.save(1, state, blocking=True)
        got = mgr.restore(template_for(restore_mesh), step=1)
        for key in ("w2d", "zero1", "repl"):
            np.testing.assert_array_equal(
                np.asarray(got[key], dtype=np.float32),
                np.asarray(state[key], dtype=np.float32),
                err_msg=f"{key}: save {(sdp, stp)}xW{world} "
                        f"-> restore {(rdp, rtp)}")
        assert got["meta"]["step"] == 1
print("RESHARD-GRID-OK")
""", timeout=900)
    assert "RESHARD-GRID-OK" in out
