"""Property test: arbitrary heterogeneous pytrees round-trip through every
engine format (the system invariant behind 'globally consistent state')."""

import numpy as np
import pytest
from conftest import HealthCheck, given, settings, st  # hypothesis, optional

from repro.core import ENGINES, CheckpointManager

DTYPES = [np.float32, np.float16, np.int32, np.int8, np.uint8, np.bool_]


@st.composite
def arrays(draw):
    dtype = draw(st.sampled_from(DTYPES))
    ndim = draw(st.integers(0, 3))
    shape = tuple(draw(st.integers(1, 5)) for _ in range(ndim))
    n = int(np.prod(shape)) if shape else 1
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    if dtype == np.bool_:
        return rng.integers(0, 2, size=shape).astype(np.bool_)
    if np.issubdtype(dtype, np.integer):
        info = np.iinfo(dtype)
        return rng.integers(info.min, info.max, size=shape).astype(dtype)
    return rng.normal(size=shape).astype(dtype)


scalars = st.one_of(st.integers(-2**31, 2**31), st.text(max_size=12),
                    st.floats(allow_nan=False), st.booleans(), st.none())

trees = st.recursive(
    st.one_of(arrays(), scalars),
    lambda kids: st.dictionaries(
        st.text(st.characters(categories=("Ll",)), min_size=1, max_size=6),
        kids, min_size=1, max_size=3),
    max_leaves=8)


@pytest.mark.parametrize("mode", sorted(ENGINES))
@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(tree=st.dictionaries(st.sampled_from(["a", "b", "c"]), trees,
                            min_size=1, max_size=3))
def test_roundtrip_any_tree(tmp_path_factory, mode, tree):
    d = tmp_path_factory.mktemp(f"prop_{mode}")
    with CheckpointManager(str(d), mode=mode) as mgr:
        mgr.save(1, tree, blocking=True)
        out = mgr.restore(tree, step=1)
    import jax
    la, ta = jax.tree_util.tree_flatten(tree)
    lb, tb = jax.tree_util.tree_flatten(out)
    assert ta == tb
    for x, y in zip(la, lb):
        if isinstance(x, np.ndarray):
            np.testing.assert_array_equal(np.asarray(y), x)
            assert np.asarray(y).dtype == x.dtype
        elif isinstance(x, float):
            assert y == pytest.approx(x, nan_ok=True)
        else:
            assert y == x
