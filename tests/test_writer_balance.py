"""Balanced writer assignment: the replica-group LPT bound + dedup
invariant (ISSUE 3 satellite), at both planning layers — device-level
(``plan_shards``) and simulated-rank-level (``partition_records``)."""

import math

import numpy as np
import pytest

from conftest import run_in_subprocess

from repro.core.distributed import ShardRecord, assign_replica_writers
from repro.dist import partition_records


def _rec(name: str, nbytes: int, dev: int = 0) -> ShardRecord:
    return ShardRecord(
        leaf_path=name, tensor_name=f"{name}@[0:1]", rank=dev,
        index=((0, 1),), global_shape=(1,), shape=(1,), dtype="uint8",
        nbytes=nbytes, data=np.zeros(1, np.uint8), device_resident=False)


# ------------------------------------------------------- unit: group balance
def test_assign_replica_writers_lpt_bound():
    """Within one replica group no member exceeds ⌈total/size⌉ + one
    shard's bytes, and each shard gets exactly one writer."""
    group = {d: None for d in (3, 5, 9)}
    sizes = [700, 400, 400, 300, 200, 100, 100, 50]
    shards = [(f"s{i}", nb, dict(group)) for i, nb in enumerate(sizes)]
    owners = assign_replica_writers(shards)
    assert sorted(owners) == sorted(f"s{i}" for i in range(len(sizes)))
    assert set(owners.values()) <= {3, 5, 9}
    load = {}
    for key, dev in owners.items():
        load[dev] = load.get(dev, 0) + sizes[int(key[1:])]
    fair = math.ceil(sum(sizes) / len(group))
    assert max(load.values()) <= fair + max(sizes), load


def test_assign_replica_writers_deterministic_and_group_scoped():
    """Two disjoint replica groups balance independently; repeated calls
    produce the identical plan."""
    shards = [("a0", 100, {0: None, 1: None}),
              ("a1", 100, {0: None, 1: None}),
              ("b0", 100, {2: None, 3: None}),
              ("b1", 100, {2: None, 3: None})]
    owners = assign_replica_writers(shards)
    assert owners == assign_replica_writers(list(reversed(shards)))
    assert {owners["a0"], owners["a1"]} == {0, 1}
    assert {owners["b0"], owners["b1"]} == {2, 3}


# ----------------------------------------------- unit: rank-level partition
def test_partition_records_spreads_bytes_when_devices_scarce():
    """One owning device, four simulated ranks: records spread ~evenly by
    bytes and every rank is present (it must cast a vote)."""
    recs = [_rec(f"t{i}", nb) for i, nb in
            enumerate([800, 500, 500, 300, 200, 200, 100, 100])]
    parts = partition_records(recs, 4)
    assert sorted(parts) == [0, 1, 2, 3]
    loads = {r: sum(x.nbytes for x in rs) for r, rs in parts.items()}
    fair = math.ceil(sum(loads.values()) / 4)
    assert max(loads.values()) <= fair + 800
    names = sorted(x.tensor_name for rs in parts.values() for x in rs)
    assert names == sorted(r.tensor_name for r in recs)  # exactly once


def test_partition_records_keeps_device_groups_together():
    recs = [_rec(f"t{i}", 100, dev=i % 8) for i in range(16)]
    parts = partition_records(recs, 4)
    # 8 device groups onto 4 ranks: positions 0..7 mod 4
    for r, rs in parts.items():
        assert {x.rank % 4 for x in rs} == {r}
    with pytest.raises(ValueError):
        partition_records(recs, 0)


# -------------------------------------------------- system: real mesh plans
def test_replica_balance_under_mesh():
    out = run_in_subprocess(r"""
import math
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.distributed import plan_shards
from repro.launch.mesh import make_mesh

mesh = make_mesh((4, 2), ("data", "model"))

# fully replicated: replica group = all 8 devices
full = {f"r{i}": jax.device_put(jnp.arange(128.0 * (i + 1)),
                                NamedSharding(mesh, P()))
        for i in range(10)}
# partially replicated: each unique shard lives on 2 devices (model axis)
part = {f"p{i}": jax.device_put(jnp.ones((64, 32)) * i,
                                NamedSharding(mesh, P("data", None)))
        for i in range(5)}

records, _ = plan_shards({"full": full, "part": part}, group="state")

# dedup invariant: every unique (leaf, index) written exactly once
keys = [(r.leaf_path, r.index) for r in records]
assert len(keys) == len(set(keys)), "replicated shard written twice"

# fully-replicated group: LPT bound over all 8 devices
floads = {}
fsizes = []
for r in records:
    if r.leaf_path.startswith("state/full"):
        floads[r.rank] = floads.get(r.rank, 0) + r.nbytes
        fsizes.append(r.nbytes)
fair = math.ceil(sum(fsizes) / 8)
assert len(floads) == 8, f"idle ranks: {sorted(floads)}"   # all lanes used
assert max(floads.values()) <= fair + max(fsizes), (floads, fair)

# partially-replicated groups: bound within each 2-device replica group
from collections import defaultdict
group_loads = defaultdict(lambda: defaultdict(int))
group_sizes = defaultdict(list)
for r in records:
    if r.leaf_path.startswith("state/part"):
        g = r.index  # same index => same replica group on this mesh
        group_loads[g][r.rank] += r.nbytes
        group_sizes[g].append(r.nbytes)
for g, loads in group_loads.items():
    fair = math.ceil(sum(group_sizes[g]) / 2)
    assert max(loads.values()) <= fair + max(group_sizes[g]), (g, loads)
    assert len(loads) == 2, f"group {g} drained by one writer: {loads}"

# the old rule would put every fully-replicated byte on device 0
assert floads[0] < sum(fsizes), "rank 0 still owns all replicated bytes"
print("BALANCE-OK")
""")
    assert "BALANCE-OK" in out
