"""Hybrid fixed-offset / log-append file layout: roundtrips + invariants."""

import os

import numpy as np
import pytest
from conftest import given, settings, st  # hypothesis, optional

from repro.core.layout import (ALIGN, FileLayout, FileReader, FileWriter,
                               align_up)


def _write_file(path, tensors, objects):
    specs = [(name, arr.nbytes, str(arr.dtype), arr.shape, None, None)
             for name, arr in tensors.items()]
    layout = FileLayout.plan(specs)
    w = FileWriter(path, layout)
    for entry, (name, arr) in zip(layout.tensors, tensors.items()):
        w.write_at(entry.offset, memoryview(np.ascontiguousarray(arr)).cast("B"))
    for name, obj in objects.items():
        import pickle
        w.append_object(name, pickle.dumps(obj))
    w.finalize()
    return layout


def test_roundtrip(tmp_path):
    path = str(tmp_path / "f.dsllm")
    tensors = {
        "a": np.arange(1000, dtype=np.float32).reshape(10, 100),
        "b": np.ones((3, 5, 7), dtype=np.float16),
        "c": np.array(3.14, dtype=np.float64).reshape(()),
    }
    objects = {"meta": {"step": 7, "cfg": [1, 2, 3]}, "empty": None}
    _write_file(path, tensors, objects)
    r = FileReader(path)
    for name, arr in tensors.items():
        np.testing.assert_array_equal(r.read_tensor(name), arr)
    assert r.read_object("meta") == {"step": 7, "cfg": [1, 2, 3]}
    assert r.read_object("empty") is None


def test_alignment_and_region_separation(tmp_path):
    path = str(tmp_path / "f.dsllm")
    tensors = {"a": np.zeros(17, np.uint8), "b": np.zeros(5000, np.uint8)}
    layout = _write_file(path, tensors, {"o": "x" * 10000})
    for e in layout.tensors:
        assert e.offset % ALIGN == 0
    ends = [e.offset + e.nbytes for e in layout.tensors]
    assert layout.tensor_region_end >= max(ends)
    assert layout.tensor_region_end % ALIGN == 0
    r = FileReader(path)
    for o in r.objects.values():
        assert o.offset >= layout.tensor_region_end


def test_bad_magic(tmp_path):
    path = str(tmp_path / "junk")
    with open(path, "wb") as f:
        f.write(b"\0" * 64)
    with pytest.raises(ValueError, match="magic"):
        FileReader(path)


def test_planned_offsets_do_not_overlap():
    specs = [(f"t{i}", sz, "uint8", (sz,), None, None)
             for i, sz in enumerate([1, 4095, 4096, 4097, 100, 0, 7])]
    layout = FileLayout.plan(specs)
    spans = sorted((e.offset, e.offset + e.nbytes) for e in layout.tensors)
    for (s1, e1), (s2, _e2) in zip(spans, spans[1:]):
        assert e1 <= s2


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=100_000),
                min_size=1, max_size=20))
def test_property_layout_no_overlap_and_aligned(sizes):
    specs = [(f"t{i}", sz, "uint8", (sz,), None, None)
             for i, sz in enumerate(sizes)]
    layout = FileLayout.plan(specs)
    spans = sorted((e.offset, e.offset + e.nbytes) for e in layout.tensors)
    prev_end = 0
    for s, e in spans:
        assert s % ALIGN == 0
        assert s >= prev_end
        prev_end = e
    assert layout.tensor_region_end == align_up(max(e for _s, e in spans))


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_property_tensor_roundtrip(tmp_path_factory, data):
    dtypes = [np.float32, np.float16, np.int32, np.uint8, np.int64]
    n = data.draw(st.integers(1, 5))
    tensors = {}
    for i in range(n):
        dt = data.draw(st.sampled_from(dtypes))
        dims = data.draw(st.lists(st.integers(1, 8), min_size=0, max_size=3))
        arr = np.random.default_rng(i).integers(0, 100, size=dims).astype(dt)
        tensors[f"t{i}"] = arr
    objects = {"o": data.draw(st.dictionaries(
        st.text(max_size=5), st.integers(), max_size=4))}
    path = str(tmp_path_factory.mktemp("prop") / "f.dsllm")
    _write_file(path, tensors, objects)
    r = FileReader(path)
    for name, arr in tensors.items():
        got = r.read_tensor(name)
        np.testing.assert_array_equal(got, arr)
        assert got.dtype == arr.dtype
    assert r.read_object("o") == objects["o"]
