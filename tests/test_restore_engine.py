"""Parallel streaming RestoreEngine: parity with serial, elastic re-shard,
ranged sub-tree reads, and corruption handling."""

import glob
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CheckpointManager, ENGINES, RestoreEngine,
                        RestoreError, step_dir)
from conftest import run_in_subprocess


def make_state():
    rng = np.random.default_rng(7)
    return {
        "model": {
            "w1": jnp.asarray(rng.normal(size=(96, 48)).astype(np.float32)),
            "w2": jnp.asarray(rng.normal(size=(33, 7)).astype(np.float32)
                              ).astype(jnp.bfloat16),
            "scalar": jnp.asarray(3.5, jnp.float32),
        },
        "optimizer": {"m": jnp.asarray(
            rng.normal(size=(96, 48)).astype(np.float32))},
        "host": rng.integers(0, 100, size=(17, 3)).astype(np.int16),
        "meta": {"step": 11, "note": "restore-engine"},
    }


def assert_trees_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        if isinstance(x, (jax.Array, np.ndarray)):
            np.testing.assert_array_equal(
                np.asarray(x, dtype=np.float64) if hasattr(x, "dtype") else x,
                np.asarray(y, dtype=np.float64) if hasattr(y, "dtype") else y)
        else:
            assert x == y


@pytest.mark.parametrize("mode", sorted(ENGINES))
def test_parallel_bit_identical_to_serial_all_formats(tmp_path, mode):
    """threads=N and threads=1 must produce byte-identical trees for every
    engine format (native .dsllm, snapshot chunk manifests, sync pickle)."""
    state = make_state()
    with CheckpointManager(str(tmp_path), mode=mode) as mgr:
        mgr.save(11, state, blocking=True)
        sdir = step_dir(str(tmp_path), 11)
    serial, s_stats = RestoreEngine(threads=1).restore(sdir, state)
    parallel, p_stats = RestoreEngine(threads=8).restore(sdir, state)
    assert_trees_equal(serial, state)
    assert_trees_equal(parallel, state)
    for a, b in zip(jax.tree_util.tree_leaves(serial),
                    jax.tree_util.tree_leaves(parallel)):
        if isinstance(a, (jax.Array, np.ndarray)):
            assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
    assert s_stats.bytes_read == p_stats.bytes_read
    assert p_stats.n_files > 0 and p_stats.read_s >= 0


def test_elastic_restore_across_mesh_shapes():
    out = run_in_subprocess(r"""
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import CheckpointManager, RestoreEngine, step_dir
from repro.launch.mesh import make_mesh

mesh_a = make_mesh((4, 2), ("data", "model"))
w = jax.device_put(jnp.arange(128 * 64, dtype=jnp.float32).reshape(128, 64),
                   NamedSharding(mesh_a, P("data", "model")))
state = {"w": w, "meta": {"step": 5}}
tmp = tempfile.mkdtemp()
mgr = CheckpointManager(tmp, mode="datastates")
mgr.save(5, state, blocking=True)

mesh_b = make_mesh((2, 4), ("data", "model"))
for spec in (P("model", "data"), P(None, "data"), P()):
    tpl = {"w": jax.ShapeDtypeStruct((128, 64), jnp.float32,
                                     sharding=NamedSharding(mesh_b, spec)),
           "meta": {"step": 0}}
    r = mgr.restore(tpl, step=5)
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(w))
    assert r["meta"]["step"] == 5
    stats = mgr.last_restore_stats
    assert stats.bytes_read >= w.nbytes        # every byte needed, once+
    assert stats.n_files == 8                  # indexed once per rank file

# serial vs parallel parity on the re-sharded target
sdir = step_dir(tmp, 5)
tpl = {"w": jax.ShapeDtypeStruct((128, 64), jnp.float32,
                                 sharding=NamedSharding(mesh_b,
                                                        P("model", "data"))),
       "meta": {"step": 0}}
a, _ = RestoreEngine(threads=1).restore(sdir, tpl)
b, _ = RestoreEngine(threads=8).restore(sdir, tpl)
np.testing.assert_array_equal(np.asarray(a["w"]), np.asarray(b["w"]))
mgr.close()
print("ELASTIC-RESTORE-OK")
""")
    assert "ELASTIC-RESTORE-OK" in out


def test_subtree_restore_reads_fewer_bytes(tmp_path):
    """Restoring a sub-tree (serving: params only) must read fewer bytes
    than the checkpoint holds — the ranged-read win over whole-file loads."""
    state = make_state()
    with CheckpointManager(str(tmp_path), mode="datastates") as mgr:
        mgr.save(11, state, blocking=True)
        sdir = step_dir(str(tmp_path), 11)
    file_bytes = sum(os.path.getsize(p)
                     for p in glob.glob(os.path.join(sdir, "*.dsllm")))
    tree, stats = RestoreEngine(threads=4).restore(
        sdir, {"model": {"w1": state["model"]["w1"]}})
    np.testing.assert_array_equal(np.asarray(tree["model"]["w1"]),
                                  np.asarray(state["model"]["w1"]))
    assert 0 < stats.bytes_read < file_bytes
    assert stats.bytes_read == state["model"]["w1"].nbytes


def test_snapshot_restore_not_quadratic(tmp_path):
    """The snapshot path must read ~checkpoint-size bytes, not
    O(files x tensors) whole-rank re-reads (the seed's behavior)."""
    state = make_state()
    with CheckpointManager(str(tmp_path), mode="snapshot") as mgr:
        mgr.save(11, state, blocking=True)
        sdir = step_dir(str(tmp_path), 11)
    tensor_bytes = sum(
        np.asarray(l).nbytes for l in jax.tree_util.tree_leaves(state)
        if isinstance(l, (jax.Array, np.ndarray)))
    tree, stats = RestoreEngine(threads=4).restore(sdir, state)
    assert_trees_equal(tree, state)
    total_on_disk = sum(os.path.getsize(p)
                        for p in glob.glob(os.path.join(sdir, "*")))
    # tensor chunk bytes once + manifest/objects overhead; nowhere near
    # n_tensors * full-checkpoint
    assert stats.bytes_read <= total_on_disk + tensor_bytes
    assert stats.bytes_read < 2 * total_on_disk


def test_dtype_converting_restore_casts_values(tmp_path):
    """A template whose dtype differs from the stored dtype must get
    value-cast data (like the seed's numpy assignment), never a raw-byte
    reinterpretation."""
    w = jnp.asarray(np.linspace(-4.0, 4.0, 64, dtype=np.float32))
    state = {"w": w, "meta": {"step": 1}}
    with CheckpointManager(str(tmp_path)) as mgr:
        mgr.save(1, state, blocking=True)
        sdir = step_dir(str(tmp_path), 1)
    for threads in (1, 8):
        tpl = {"w": jax.ShapeDtypeStruct((64,), jnp.bfloat16),
               "meta": {"step": 0}}
        tree, _ = RestoreEngine(threads=threads).restore(sdir, tpl)
        np.testing.assert_allclose(
            np.asarray(tree["w"], dtype=np.float32), np.asarray(w),
            rtol=2e-2)
        tpl32 = {"w": np.empty((64,), np.int32), "meta": {"step": 0}}
        tree32, _ = RestoreEngine(threads=threads).restore(sdir, tpl32)
        np.testing.assert_array_equal(tree32["w"],
                                      np.asarray(w).astype(np.int32))


def test_corrupt_footer_raises_clear_error(tmp_path):
    state = make_state()
    with CheckpointManager(str(tmp_path), mode="datastates") as mgr:
        mgr.save(11, state, blocking=True)
        sdir = step_dir(str(tmp_path), 11)
    [path] = glob.glob(os.path.join(sdir, "*.dsllm"))
    with open(path, "r+b") as f:         # chop the footer off
        f.truncate(os.path.getsize(path) - 24)
    with pytest.raises(RestoreError, match="corrupt or truncated"):
        RestoreEngine().restore(sdir, state)
    # the manager surfaces the same error
    with CheckpointManager(str(tmp_path)) as mgr:
        with pytest.raises(RestoreError, match=os.path.basename(path)):
            mgr.restore(state, step=11)


def test_missing_region_raises_restore_error(tmp_path):
    """A template bigger than the stored array is a planning-time error."""
    state = {"a": jnp.arange(32, dtype=jnp.float32), "meta": {"step": 1}}
    with CheckpointManager(str(tmp_path)) as mgr:
        mgr.save(1, state, blocking=True)
        big = {"a": jax.ShapeDtypeStruct((64,), jnp.float32),
               "meta": {"step": 0}}
        with pytest.raises(RestoreError, match="does not cover"):
            mgr.restore(big, step=1)


def test_restore_stats_phases_populated(tmp_path):
    state = make_state()
    with CheckpointManager(str(tmp_path)) as mgr:
        mgr.save(3, state, blocking=True)
        mgr.restore(state, step=3)
        stats = mgr.last_restore_stats
    assert stats is not None
    assert stats.index_s >= 0 and stats.read_s >= 0 and stats.assemble_s >= 0
    assert stats.n_ranges > 0
    assert stats.n_leaves == 5
    assert stats.bytes_read > 0
